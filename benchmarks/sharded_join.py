"""Sharded distributed-join benchmark (DESIGN.md §10) — the perf gate.

Workload: FK-shaped join of two n-row tables on a single int64 key
drawn sparsely from a span 16x the row count — wide enough that the
vectorized backend's direct-address bincount heuristic refuses it
(span > 4*(nl+nr)+1024) and it falls back to sort + whole-table binary
search, which cache-misses on every probe at 1e6+ rows. The sharded
backend radix-partitions the key space across the device mesh and
probes per-shard sorted runs, which is exactly the regime the ROADMAP
item targets.

Correctness gates before any timing: fingerprints of the sharded and
``auto`` outputs must equal ``reference`` bit for bit (joins gather,
they never sum — so not even the float carve-out applies here). A fast
wrong answer fails the benchmark, not production.

Perf gate: sharded >= 2x over vectorized at n >= 1e6 on an 8-device
forced-host mesh (>= 1.3x at the smoke size CI runs). Emits a BENCH
JSON line and, with ``--json PATH``, the same document to disk.

Run: ``PYTHONPATH=src python -m benchmarks.sharded_join
[--smoke] [--json PATH]``. Must be started fresh (it forces
``--xla_force_host_platform_device_count=8`` before JAX imports);
``benchmarks/run.py`` launches it as a subprocess for exactly that
reason.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

N_DEVICES = 8

# must precede any jax import (including transitively via repro.exec)
if "jax" not in sys.modules and "--xla_force_host_platform" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}").strip()

import numpy as np  # noqa: E402

MIN_SPEEDUP = 2.0
MIN_SPEEDUP_SMOKE = 1.3


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of_interleaved(reps, fns):
    """Best-of timing with the candidates interleaved per rep, so a
    throttled / noisy host (CI runners, cgroup cpu shares) degrades
    every candidate's reps alike instead of whichever ran last."""
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _tables(n: int):
    from repro.data.tables import Table

    rng = np.random.default_rng(0)
    span = 16 * n       # sparse: defeats the single-host bincount path
    keys = rng.integers(0, span, n).astype(np.int64)
    left = Table({"k": keys, "x": rng.normal(size=n)})
    right = Table({"k": keys[rng.permutation(n)],
                   "w": rng.normal(size=n)})
    return left, right, span


def bench_sharded_join(smoke: bool = False,
                       json_path: str | None = None,
                       reps: int | None = None) -> dict:
    import jax

    from repro import exec as exec_backends

    n_dev = jax.device_count()
    if n_dev < N_DEVICES:
        raise SystemExit(
            f"sharded_join needs a {N_DEVICES}-device mesh, found "
            f"{n_dev}: run fresh (module sets XLA_FLAGS) or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{N_DEVICES}")

    # smoke still uses 1e6 rows: below ~1e6 the vectorized backend's
    # whole-table binary search fits in cache and the sharded
    # advantage (which is precisely about NOT missing cache) shrinks
    # toward noise — the gate would measure scheduler luck, not the
    # regression it guards. The full gate doubles n, where the
    # cache-miss regime is unambiguous.
    n = 1_000_000 if smoke else 2_000_000
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    reps = reps if reps is not None else (5 if smoke else 4)
    left, right, span = _tables(n)

    def join(be):
        return left.join(right, on=["k"], backend=be)

    # correctness first: bit-for-bit vs the reference oracle (row
    # order, masks, fills — everything Table.fingerprint hashes).
    want = join("reference").fingerprint()
    checked = ["vectorized", "sharded", "auto"]
    for be in checked:
        got = join(be).fingerprint()
        assert got == want, (
            f"hash_join: backend {be!r} diverges from reference "
            f"({got} != {want})")

    timings = _best_of_interleaved(
        reps, {be: (lambda b=be: join(b))
               for be in ("vectorized", "sharded")})
    for be, t in timings.items():
        row("sharded_join", f"join_{be}", t * 1e3, "ms/call",
            f"n={n} span={span} mesh={n_dev}")
    speedup = timings["vectorized"] / timings["sharded"]
    row("sharded_join", "speedup", speedup, "x",
        f"sharded over vectorized; gate >= {floor}x")

    # auto must route this exact workload to the sharded backend
    from repro.exec.auto import choose_join
    from repro.exec.stats import collect_stats
    chosen = choose_join(
        collect_stats(left._to_cols(), ["k"]),
        collect_stats(right._to_cols(), ["k"]),
        n_devices=n_dev, sharded_available=True)
    row("sharded_join", "auto_choice", float(chosen == "sharded"), "",
        f"auto picked {chosen!r}")

    doc = {
        "bench": "sharded_join",
        "n_rows": n,
        "key_span": span,
        "smoke": smoke,
        "mesh_devices": n_dev,
        "backends_checked": checked,
        "timings_s": timings,
        "speedup": speedup,
        "auto_choice": chosen,
        "gate_min_speedup": floor,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    assert chosen == "sharded", (
        f"auto-selection must route the large sparse-key join to "
        f"'sharded' on a multi-device mesh, picked {chosen!r}")
    assert speedup >= floor, (
        f"sharded join must be >= {floor}x over vectorized at n={n} "
        f"on a {n_dev}-device mesh, got {speedup:.2f}x "
        f"({timings['vectorized'] * 1e3:.0f}ms vs "
        f"{timings['sharded'] * 1e3:.0f}ms)")
    assert exec_backends.get_backend("sharded").cache_token() \
        != exec_backends.get_backend("vectorized").cache_token()
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller n, relaxed 1.3x gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_sharded_join(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
