"""Concurrent publication benchmark (DESIGN.md §7).

K threads each publish R transactional runs against `main`:

- ``disjoint``  — private tables: every run must publish (rebasing past
  the others); measures publication throughput + mean CAS attempts.
- ``contended`` — all runs fight over one table: exactly one winner per
  wave; measures clean-abort overhead.

Also compares per-node commits vs one ``write_tables`` multi-table
commit (the commit-churn cut: log entries per run -> 1).

Run: ``PYTHONPATH=src python -m benchmarks.concurrent_publication``
"""
from __future__ import annotations

import threading
import time

from repro.core.catalog import Catalog
from repro.core.errors import TransactionAborted
from repro.core.transactions import TransactionalRun


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _publish_wave(cat: Catalog, k: int, runs_each: int, *,
                  disjoint: bool) -> tuple[float, int, int, int]:
    committed = [0] * k
    attempts = [0] * k
    aborted = [0] * k
    barrier = threading.Barrier(k)

    def worker(i):
        barrier.wait()
        for r in range(runs_each):
            txn = TransactionalRun(cat, "main",
                                   max_publish_attempts=4 * k).begin()
            table = f"t{i}" if disjoint else "hot"
            txn.write_table(table, f"s{i}.{r}")
            txn.verify(lambda read: read(table))
            try:
                txn.commit()
                committed[i] += 1
            except TransactionAborted:
                aborted[i] += 1
            attempts[i] += txn.publish_attempts

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(k)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return dt, sum(committed), sum(attempts), sum(aborted)


def bench_concurrent_publication(k: int = 8, runs_each: int = 25) -> None:
    cat = Catalog()
    dt, ok, att, ab = _publish_wave(cat, k, runs_each, disjoint=True)
    row("concurrent", f"disjoint_{k}x{runs_each}", ok / dt, "runs/s",
        f"all published; {att / max(ok, 1):.2f} CAS attempts/run")
    assert ab == 0, "disjoint runs must all publish"

    cat = Catalog()
    dt, ok, att, ab = _publish_wave(cat, k, runs_each, disjoint=False)
    row("concurrent", f"contended_{k}x{runs_each}", ok / dt, "runs/s",
        f"{ok} committed / {ab} clean aborts on one hot table")

    # commit churn: N write_table commits vs ONE write_tables commit
    n_tables = 10
    cat = Catalog()
    for t in range(n_tables):
        cat.write_table("main", f"t{t}", "s")
    per_node = len(cat.log("main", limit=1000)) - 1
    cat2 = Catalog()
    cat2.write_tables("main", {f"t{t}": "s" for t in range(n_tables)})
    per_run = len(cat2.log("main", limit=1000)) - 1
    row("concurrent", "commits_per_run", per_run, "commits",
        f"multi-table commit; was {per_node} per-node commits")


def main() -> None:
    print("name,metric,value,unit,notes")
    bench_concurrent_publication()


if __name__ == "__main__":
    main()
