"""Concurrent publication benchmark (DESIGN.md §7).

K threads each publish R transactional runs against `main`:

- ``disjoint``  — private tables: every run must publish (rebasing past
  the others); measures publication throughput + mean CAS attempts.
- ``contended`` — all runs fight over one table: exactly one winner per
  wave; measures clean-abort overhead.

Also compares per-node commits vs one ``write_tables`` multi-table
commit (the commit-churn cut: log entries per run -> 1), and — since
the wave engine (DESIGN.md §8) — measures how many nodes a publication
rebase re-executes: with the content-addressed cache, rebasing past
concurrent runs that did NOT move this run's inputs re-executes ZERO
nodes (O(changed subgraph), not O(full DAG)).

Run: ``PYTHONPATH=src python -m benchmarks.concurrent_publication``
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.core import schema as S
from repro.core.catalog import Catalog
from repro.core.dag import Pipeline
from repro.core.errors import TransactionAborted
from repro.core.planner import plan
from repro.core.runner import Client
from repro.core.transactions import TransactionalRun
from repro.data.tables import Table, col


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


# module scope: PEP-563 string annotations resolve against the defining
# frame, so node schemas cannot live inside the bench function.
SrcSchema = S.Schema.of("SrcSchema", v=int)
OutSchema = S.Schema.of("OutSchema", v=int, w=int)


def _publish_wave(cat: Catalog, k: int, runs_each: int, *,
                  disjoint: bool) -> tuple[float, int, int, int]:
    committed = [0] * k
    attempts = [0] * k
    aborted = [0] * k
    barrier = threading.Barrier(k)

    def worker(i):
        barrier.wait()
        for r in range(runs_each):
            txn = TransactionalRun(cat, "main",
                                   max_publish_attempts=4 * k).begin()
            table = f"t{i}" if disjoint else "hot"
            txn.write_table(table, f"s{i}.{r}")
            txn.verify(lambda read: read(table))
            try:
                txn.commit()
                committed[i] += 1
            except TransactionAborted:
                aborted[i] += 1
            attempts[i] += txn.publish_attempts

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(k)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    return dt, sum(committed), sum(attempts), sum(aborted)


def bench_concurrent_publication(k: int = 8, runs_each: int = 25) -> None:
    cat = Catalog()
    dt, ok, att, ab = _publish_wave(cat, k, runs_each, disjoint=True)
    row("concurrent", f"disjoint_{k}x{runs_each}", ok / dt, "runs/s",
        f"all published; {att / max(ok, 1):.2f} CAS attempts/run")
    assert ab == 0, "disjoint runs must all publish"

    cat = Catalog()
    dt, ok, att, ab = _publish_wave(cat, k, runs_each, disjoint=False)
    row("concurrent", f"contended_{k}x{runs_each}", ok / dt, "runs/s",
        f"{ok} committed / {ab} clean aborts on one hot table")

    # commit churn: N write_table commits vs ONE write_tables commit
    n_tables = 10
    cat = Catalog()
    for t in range(n_tables):
        cat.write_table("main", f"t{t}", "s")
    per_node = len(cat.log("main", limit=1000)) - 1
    cat2 = Catalog()
    cat2.write_tables("main", {f"t{t}": "s" for t in range(n_tables)})
    per_run = len(cat2.log("main", limit=1000)) - 1
    row("concurrent", "commits_per_run", per_run, "commits",
        f"multi-table commit; was {per_node} per-node commits")


def bench_rebase_reexecution(k: int = 8) -> None:
    """K full Client runs (plan -> waves -> publish) with disjoint
    outputs over ONE shared source: every CAS conflict rebases past a
    sibling's commit that did not move the inputs, so every rebase must
    re-execute 0 nodes (all cache hits)."""
    def pipeline(i: int) -> Pipeline:
        p = Pipeline(f"worker{i}")
        p.source("src", SrcSchema)

        @p.node(name=f"out_{i}")
        def out_node(df: SrcSchema = "src") -> OutSchema:
            return df.select([col("v"), (col("v") * (i + 1)).alias("w")])

        return p

    client = Client()
    client.write_source_table(
        "main", "src", Table({"v": np.arange(64, dtype=np.int64)}))
    plans = [plan(pipeline(i)) for i in range(k)]
    barrier = threading.Barrier(k)
    results: dict[int, object] = {}

    def worker(i):
        barrier.wait()
        results[i] = client.run(plans[i], "main",
                                max_publish_attempts=4 * k)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(k)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0

    rebases = sum(len(r.rebase_reexecutions) for r in results.values())
    reexecuted = sum(sum(r.rebase_reexecutions) for r in results.values())
    attempts = sum(r.state.publish_attempts for r in results.values())
    row("concurrent", f"client_disjoint_{k}", k / dt, "runs/s",
        f"{attempts} CAS attempts; {rebases} rebases")
    row("concurrent", "reexecuted_nodes_per_attempt",
        reexecuted / max(attempts, 1), "nodes",
        f"{reexecuted} node re-executions across {rebases} rebases "
        f"(cache makes rebase O(changed subgraph))")
    assert all(r.state.status == "committed" for r in results.values())
    assert reexecuted == 0, \
        "rebases past disjoint runs must not re-execute unchanged nodes"


def main() -> None:
    print("name,metric,value,unit,notes")
    bench_concurrent_publication()
    bench_rebase_reexecution()


if __name__ == "__main__":
    main()
