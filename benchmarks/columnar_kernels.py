"""Columnar execution-backend benchmark (DESIGN.md §9) — the perf gate.

Times the registered backends on the two table-layer hot paths the
worker moment runs for every pipeline node:

1. **hash join** (FK shape: 1e6-row fact table joined to a 1e5-row
   dim table with unique keys);
2. **group_by_sum** (1e6 rows, 1e4 groups, int64 values);

and asserts the ``vectorized`` backend beats ``reference`` by >= 10x on
both (>= 5x in ``--smoke`` mode, where n shrinks 5x for CI runners and
scheduler noise eats into the Python-loop constant). Outputs are
cross-checked via ``Table.fingerprint`` before timing — a fast wrong
answer must fail here, not in production. The ``jax`` backend is timed
when available (reported, not gated: CPU containers run XLA/interpret).

Emits a BENCH JSON line (``BENCH {...}``) and, with ``--json PATH``,
writes the same document to disk so CI can upload it as an artifact —
the perf trajectory finally has data.

Run: ``PYTHONPATH=src python -m benchmarks.columnar_kernels
[--smoke] [--json PATH]``
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

MIN_SPEEDUP = 10.0
MIN_SPEEDUP_SMOKE = 5.0


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of(n, fn):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _tables(n: int):
    from repro.data.tables import Table

    rng = np.random.default_rng(0)
    n_dim = max(n // 10, 1)
    n_groups = max(n // 100, 1)
    left = Table({
        "k": rng.integers(0, n_dim, n).astype(np.int64),
        "x": rng.normal(size=n),
    })
    right = Table({
        "k": rng.permutation(n_dim).astype(np.int64),
        "w": rng.normal(size=n_dim),
    })
    grouped = Table({
        "k": rng.integers(0, n_groups, n).astype(np.int64),
        "v": rng.integers(-100, 100, n).astype(np.int64),
    })
    return left, right, grouped


def bench_columnar(smoke: bool = False, json_path: str | None = None,
                   reps: int | None = None) -> dict:
    from repro import exec as exec_backends

    n = 200_000 if smoke else 1_000_000
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    reps = reps if reps is not None else (2 if smoke else 3)
    left, right, grouped = _tables(n)
    backends = exec_backends.available_backends()

    ops = {
        "join": lambda be: left.join(right, on=["k"], backend=be),
        "group_by_sum": lambda be: grouped.group_by_sum(
            ["k"], "v", out="s", backend=be),
    }

    results: dict[str, dict[str, float]] = {}
    for op_name, op in ops.items():
        # correctness first: a fast wrong answer must fail the bench
        want = op("reference").fingerprint()
        for be in backends:
            got = op(be).fingerprint()
            assert got == want, (
                f"{op_name}: backend {be!r} diverges from reference "
                f"({got} != {want})")
        timings: dict[str, float] = {}
        for be in backends:
            timings[be] = _best_of(reps, lambda b=be: op(b))
            row("columnar", f"{op_name}_{be}", timings[be] * 1e3,
                "ms/call", f"n={n}")
        results[op_name] = timings

    speedups = {}
    for op_name, timings in results.items():
        s = timings["reference"] / timings["vectorized"]
        speedups[op_name] = s
        row("columnar", f"{op_name}_speedup", s, "x",
            f"vectorized over reference; gate >= {floor}x")

    doc = {
        "bench": "columnar_kernels",
        "n_rows": n,
        "smoke": smoke,
        "backends": backends,
        "timings_s": results,
        "speedups": speedups,
        "gate_min_speedup": floor,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    for op_name, s in speedups.items():
        assert s >= floor, (
            f"{op_name}: vectorized must be >= {floor}x over reference "
            f"at n={n}, got {s:.1f}x "
            f"({results[op_name]['reference'] * 1e3:.0f}ms vs "
            f"{results[op_name]['vectorized'] * 1e3:.0f}ms)")
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: 5x smaller n, relaxed 5x gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_columnar(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
