"""Render the EXPERIMENTS.md roofline tables from dry-run JSON rows.

    PYTHONPATH=src python -m benchmarks.roofline_table results/dryrun_opt
"""
from __future__ import annotations

import glob
import json
import sys


def load(outdir: str, mesh: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(f"{outdir}/*.{mesh}.json")):
        rows.append(json.load(open(f)))
    return rows


def render(outdir: str = "results/dryrun_opt") -> str:
    lines = []
    for mesh, label in (("single", "single-pod (16,16) = 256 chips"),
                        ("multi", "multi-pod (2,16,16) = 512 chips")):
        rows = load(outdir, mesh)
        if not rows:
            continue
        lines.append(f"\n### {label}\n")
        lines.append("| cell | compute_s | memory_s | collective_s | "
                     "bottleneck | roofline | useful | GiB/dev | fits |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            cell = f"{r['arch']}.{r['shape']}"
            if r["status"] == "skipped":
                lines.append(f"| {cell} | — | — | — | skip | — | — | — | "
                             f"n/a |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {cell} | FAILED: {r['error'][:40]} "
                             f"| | | | | | | |")
                continue
            lines.append(
                f"| {cell} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                f"| {r['collective_s']:.3f} | {r['bottleneck']} "
                f"| {r['roofline_fraction']:.2f} "
                f"| {r['useful_ratio']:.2f} "
                f"| {r['bytes_per_device'] / 2**30:.1f} "
                f"| {'yes' if r['hbm_ok'] else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else
                 "results/dryrun_opt"))
