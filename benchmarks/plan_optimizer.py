"""Plan-optimizer gate (DESIGN.md §11): optimized vs unoptimized
execution of a pushdown-heavy three-table pipeline.

The workload is shaped so every rewrite the optimizer owns has teeth:
a selective filter authored ABOVE a two-join chain (pushdown + probe
fusion move it into the users-side masked probe), wide fact/users
tables whose payload columns the output never references (dead-column
elision skips gathering them — including an object-dtype column, the
expensive one), and a final three-column projection. Join sizes keep
the greedy reorder at the authored order, so the timed delta is
pushdown + fusion + pruning — not the ``Reorder`` restoration lexsort.

Correctness first, speed second: before timing, the optimized plan's
published table must fingerprint identically to the unoptimized one
(the differential-suite obligation, re-checked at benchmark scale).
The gate asserts ``optimized >= 1.5x`` (``--smoke``: 1.2x) and that
the optimizer actually rewrote the plan — a silently pass-free
optimizer must fail the gate, not coast on equality.

Run: ``PYTHONPATH=src python -m benchmarks.plan_optimizer [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

MIN_SPEEDUP = 1.5
MIN_SPEEDUP_SMOKE = 1.2

N_DEAD_COLS = 8


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of_interleaved(reps, fns):
    """Best-of timing with the candidates interleaved per rep, so a
    throttled / noisy host (CI runners, cgroup cpu shares) degrades
    every candidate's reps alike instead of whichever ran last."""
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _pipeline():
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.data.tables import col

    fact_cols = {"user_id": int, "item_id": int, "amount": float}
    fact_cols.update({f"pay{i}": float for i in range(N_DEAD_COLS)})
    Fact = S.Schema.of("Fact", **fact_cols)
    Users = S.Schema.of("Users", user_id=int, segment=int, bio=str)
    Items = S.Schema.of("Items", item_id=int, weight=float)
    Out = S.Schema.of("Out", user_id=int, amount=float, weight=float)

    p = Pipeline("pushdown_heavy")
    p.source("fact", Fact)
    p.source("users", Users)
    p.source("items", Items)
    p.sql(name="out", inputs={"f": "fact", "u": "users", "i": "items"},
          input_schemas={"f": Fact, "u": Users, "i": Items},
          output_schema=Out,
          joins=[("users", ["user_id"]), ("items", ["item_id"])],
          filter_expr=(col("segment") == 3),
          exprs=[col("user_id"), col("amount"), col("weight")])
    return p


def _sources(n_fact, n_users, n_items):
    from repro.data.tables import Table

    rng = np.random.default_rng(0)
    fact = {"user_id": rng.integers(0, n_users, n_fact),
            "item_id": rng.integers(0, n_items, n_fact),
            "amount": rng.normal(size=n_fact)}
    for i in range(N_DEAD_COLS):
        fact[f"pay{i}"] = rng.normal(size=n_fact)
    users = {"user_id": np.arange(n_users, dtype=np.int64),
             "segment": (np.arange(n_users) % 64).astype(np.int64),
             "bio": np.array([f"user-{i}-bio" for i in range(n_users)],
                             dtype=object)}
    items = {"item_id": np.arange(n_items, dtype=np.int64),
             "weight": rng.normal(size=n_items)}
    return {"fact": Table(fact), "users": Table(users),
            "items": Table(items)}


def bench_plan_optimizer(smoke: bool = False,
                         json_path: str | None = None,
                         reps: int | None = None) -> dict:
    from repro import exec as exec_backends
    from repro.core.planner import plan
    from repro.exec.stats import collect_stats
    from repro.optimizer import DEFAULT_PASSES, optimize

    n_fact = 120_000 if smoke else 400_000
    n_users, n_items = ((30_000, 15_000) if smoke
                       else (100_000, 50_000))
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    reps = reps if reps is not None else (5 if smoke else 4)

    tables = _sources(n_fact, n_users, n_items)
    stats = {t: collect_stats(tab._to_cols())
             for t, tab in tables.items()}
    pl = plan(_pipeline(), table_stats=stats)
    opt = optimize(pl)

    rewrites = [m for s in opt.steps for m in s.provenance]
    assert rewrites, "optimizer fired no rewrite on the gate workload"
    row("plan_optimizer", "rewrites", len(rewrites), "count",
        "; ".join(m.split(":")[1].strip()[:40] for m in rewrites))

    def run(p):
        return p.steps[0].execute(tables)

    # correctness first: bit-for-bit at benchmark scale, on the
    # default (vectorized) backend AND the auto policy backend.
    want = run(pl).fingerprint()
    for be in ("vectorized", "auto"):
        with exec_backends.use_backend(be):
            got = run(opt).fingerprint()
        assert got == want, (
            f"optimized plan diverges from unoptimized on {be!r} "
            f"({got} != {want})")

    timings = _best_of_interleaved(
        reps, {"unoptimized": lambda: run(pl),
               "optimized": lambda: run(opt)})
    for name, t in timings.items():
        row("plan_optimizer", name, t * 1e3, "ms/run",
            f"fact={n_fact} users={n_users} items={n_items}")
    speedup = timings["unoptimized"] / timings["optimized"]
    row("plan_optimizer", "speedup", speedup, "x",
        f"optimized over unoptimized; gate >= {floor}x")

    doc = {
        "bench": "plan_optimizer",
        "smoke": smoke,
        "n_fact": n_fact,
        "n_users": n_users,
        "n_items": n_items,
        "passes": list(DEFAULT_PASSES),
        "rewrites": len(rewrites),
        "timings_s": timings,
        "speedup": speedup,
        "gate_min_speedup": floor,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    assert speedup >= floor, (
        f"optimized plan must be >= {floor}x over unoptimized at "
        f"fact={n_fact}, got {speedup:.2f}x "
        f"({timings['unoptimized'] * 1e3:.0f}ms vs "
        f"{timings['optimized'] * 1e3:.0f}ms)")
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller tables, relaxed 1.2x gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_plan_optimizer(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
