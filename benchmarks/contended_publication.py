"""Contended publication under chaos (DESIGN.md §15).

K writers race disjoint-table publications against one ``main`` head:
every CAS conflict forces a rebase, so head contention — not data
conflict — is the bottleneck being measured. Three questions, one
BENCH document:

1. **Throughput + tail latency.** commits/s and p50/p99 publish
   latency at 8/64/256 writers (smoke: 8/64). Backoff sleeps go
   through a shared :class:`~repro.chaos.clock.FakeClock`, so the
   *virtual* backoff seconds are reported separately from wall time.
2. **Success under a fault budget.** A seeded
   :class:`~repro.chaos.faults.FaultPlan` injects publication-seam
   failures capped by a fixed budget; the success-rate gate
   ``(total - budget) / total`` must hold — injected faults are the
   ONLY acceptable losses.
3. **Jittered vs linear backoff.** The same contended wave under the
   legacy linear schedule and the seeded decorrelated-jitter schedule
   (DESIGN.md §15): wasted CAS attempts and virtual backoff time,
   side by side.

A chaos-smoke section replays a handful of hostile swarm seeds through
the linearizability checker — the cheap CI echo of the 240-seed tier-1
gate.

Run: ``PYTHONPATH=src python -m benchmarks.contended_publication
[--smoke] [--json PATH]``
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import threading
import time

import numpy as np

from repro.chaos import (FakeClock, FaultPlan, FaultRule, InjectedCrash,
                         InjectedFault, SwarmConfig, check_swarm,
                         fault_injection, run_swarm)
from repro.core.catalog import Catalog
from repro.core.errors import TransactionAborted
from repro.core.transactions import TransactionalRun


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _wave(k: int, runs_each: int, *, backoff: str = "decorrelated",
          seed="bench", rules: tuple[FaultRule, ...] = (),
          budget: int | None = None) -> dict:
    """One publication wave: K threads x runs_each disjoint-table runs
    against a single head. Returns the wave's metrics dict."""
    cat = Catalog()
    clock = FakeClock()
    plan = FaultPlan(seed, rules, budget=budget)
    committed = [0] * k
    failed = [0] * k
    attempts = [0] * k
    latencies: list[list[float]] = [[] for _ in range(k)]
    barrier = threading.Barrier(k)

    def worker(i):
        barrier.wait()
        for r in range(runs_each):
            t0 = time.perf_counter()
            txn = TransactionalRun(
                cat, "main", run_id=f"w{i}r{r}",
                max_publish_attempts=4 * k, backoff=backoff,
                backoff_seed=f"{seed}:w{i}r{r}", clock=clock)
            txn.begin()
            txn.write_table(f"t{i}.{r}", f"s{i}.{r}")
            txn.verify(lambda read, _t=f"t{i}.{r}": read(_t))
            try:
                txn.commit()
                committed[i] += 1
            except (TransactionAborted, InjectedFault, InjectedCrash):
                failed[i] += 1
                try:
                    txn.abort()
                except Exception:       # noqa: BLE001 - already dead
                    pass
            attempts[i] += txn.publish_attempts
            latencies[i].append(time.perf_counter() - t0)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(k)]
    with fault_injection(plan):
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        cat.gc(live_runs=(), grace_s=0.0)   # recovery sweep always runs

    lats = np.array(sorted(x for per in latencies for x in per))
    total = k * runs_each
    ok = sum(committed)
    return {
        "writers": k,
        "runs": total,
        "committed": ok,
        "failed": sum(failed),
        "success_rate": round(ok / total, 4),
        "commits_per_s": round(ok / wall, 2),
        "wall_s": round(wall, 4),
        "p50_latency_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p99_latency_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
        "mean_cas_attempts": round(sum(attempts) / total, 3),
        "backoff_virtual_s": round(clock.now_s, 4),
        "backoff_sleeps": clock.sleep_count,
        "fault_budget": budget,
        "faults_injected": plan.faults_injected,
    }


# the pre_merge delay holds publishers between verification and CAS so
# concurrent heads actually move in the window — contention is real,
# not just theoretical (same trick as the tier-1 contended regime).
FAULT_RULES = (FaultRule("txn.commit.pre_merge", "fail", 0.04),
               FaultRule("txn.commit.pre_rebase", "fail", 0.02),
               FaultRule("txn.commit.pre_merge", "delay", 0.5,
                         delay_s=0.002))

CONTENTION_RULES = (FaultRule("txn.commit.pre_merge", "delay", 0.9,
                              delay_s=0.002),)

SMOKE_SWARM = SwarmConfig(
    n_agents=6, runs_per_agent=2, use_store=True, gc_every=2,
    p_violate=0.2, p_abandon=0.15, p_reuse=0.2,
    fault_rules=(FaultRule("txn.commit.post_merge", "crash", 0.10),
                 FaultRule("txn.begin.post_branch", "crash", 0.03),
                 FaultRule("store.put", "fail", 0.08)),
    fault_budget=10)


def bench_contended_publication_chaos(smoke: bool = False) -> dict:
    writer_counts = (8, 64) if smoke else (8, 64, 256)
    runs_each = 2 if smoke else 4
    waves = {}
    for k in writer_counts:
        # fixed fault budget scales with the wave so the gate stays
        # meaningful: the budget is the ONLY tolerated loss.
        budget = max(2, (k * runs_each) // 16)
        w = _wave(k, runs_each, seed=f"wave-{k}",
                  rules=FAULT_RULES, budget=budget)
        gate = (w["runs"] - budget) / w["runs"]
        w["success_gate"] = round(gate, 4)
        assert w["success_rate"] >= gate, (
            f"{k} writers: success {w['success_rate']} below gate {gate} "
            f"— losses beyond the injected-fault budget")
        waves[str(k)] = w
        row("contended_pub", f"throughput_{k}w", w["commits_per_s"],
            "commits/s", f"p99 {w['p99_latency_ms']}ms; "
            f"success {w['success_rate']} >= {gate:.3f}")

    # jittered vs linear, same contended wave, no faults: every run
    # must land; the schedules differ in retry churn + virtual sleep.
    kc = 16 if smoke else 32
    comparison = {}
    for mode in ("linear", "decorrelated"):
        w = _wave(kc, runs_each, backoff=mode, seed="backoff-cmp",
                  rules=CONTENTION_RULES)
        assert w["failed"] == 0, f"{mode}: contended wave lost runs"
        comparison[mode] = {
            "wasted_cas_attempts": round(
                w["mean_cas_attempts"] * w["runs"] - w["committed"]),
            "mean_cas_attempts": w["mean_cas_attempts"],
            "backoff_virtual_s": w["backoff_virtual_s"],
            "backoff_sleeps": w["backoff_sleeps"],
            "p99_latency_ms": w["p99_latency_ms"],
        }
        row("contended_pub", f"backoff_{mode}_{kc}w",
            w["mean_cas_attempts"], "attempts/run",
            f"virtual backoff {w['backoff_virtual_s']}s over "
            f"{w['backoff_sleeps']} sleeps")
    comparison["writers"] = kc

    # chaos smoke: hostile swarm seeds through the full checker — the
    # CI echo of the 240-seed tier-1 gate.
    n_seeds = 4 if smoke else 12
    outcomes: dict[str, int] = {}
    injected = 0
    for i in range(n_seeds):
        res = run_swarm(dataclasses.replace(SMOKE_SWARM,
                                            seed=f"ci-smoke-{i}"))
        violations = check_swarm(res)
        assert not violations, (
            f"seed 'ci-smoke-{i}' (replayable): {violations}")
        injected += res.plan.faults_injected
        for o, n in res.outcomes().items():
            outcomes[o] = outcomes.get(o, 0) + n
    row("contended_pub", "chaos_smoke_seeds", n_seeds, "seeds",
        f"0 violations; {injected} faults injected; {outcomes}")

    return {
        "bench": "contended_publication",
        "smoke": smoke,
        "waves": waves,
        "backoff_comparison": comparison,
        "chaos_smoke": {"seeds": n_seeds, "violations": 0,
                        "faults_injected": injected,
                        "outcomes": outcomes},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    print("name,metric,value,unit,notes")
    doc = bench_contended_publication_chaos(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
    else:
        json.dump(doc, sys.stdout, indent=2)
        print()


if __name__ == "__main__":
    main()
