"""Benchmark harness — one benchmark per paper claim/figure.

The paper is correctness-focused; its quantitative claims are about
*overheads* (§3.3: "branch creation or metadata updates" must be small
next to storage I/O and compute) and about the cost of the three
checking moments. Each benchmark prints a CSV row:

    name,metric,value,unit,notes

Benchmarks that emit a BENCH JSON document (columnar kernels, the
sharded join) additionally have their documents written to canonical
``BENCH_<name>.json`` files at the repo root — committed per PR, so
``BENCH_*.json`` records the perf trajectory over time, not just in
ephemeral CI artifacts.

Run: ``PYTHONPATH=src python -m benchmarks.run``
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def write_bench_doc(doc: dict) -> str:
    """Persist one benchmark's BENCH JSON to BENCH_<name>.json at the
    repo root (the perf trajectory; see module docstring)."""
    path = os.path.join(_REPO_ROOT, f"BENCH_{doc['bench']}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def bench_mesh_subprocess(module: str) -> "dict | None":
    """The forced-8-device mesh gates (sharded join, sharded group-by)
    need XLA_FLAGS set before jax imports, which this process has long
    passed — run the benchmark module as a subprocess (smoke size) and
    collect its BENCH document."""
    out = os.path.join(_REPO_ROOT, f"bench_{module}.tmp.json")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    # the forced-host mesh only multiplies the CPU platform: on
    # accelerator hosts the child must also pin jax to cpu, or the
    # default gpu/tpu backend keeps device_count()==1 and the gate
    # aborts.
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(_REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    try:
        r = subprocess.run(
            [sys.executable, "-m", f"benchmarks.{module}",
             "--smoke", "--json", out],
            cwd=_REPO_ROOT, env=env, capture_output=True, text=True,
            timeout=1800)
        sys.stdout.write(r.stdout)
        if r.returncode != 0:
            raise RuntimeError(
                f"{module} gate failed:\n{r.stderr[-2000:]}")
        with open(out) as f:
            return json.load(f)
    finally:
        if os.path.exists(out):
            os.remove(out)


def _t(fn, n=100, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


# ---------------------------------------------------------------------------
# 1. Contract composition (paper §3.1 — moment 2 must be cheap enough
#    to run on every plan, long before any data is touched)
# ---------------------------------------------------------------------------

def bench_contracts():
    from repro.core import schema as S
    from repro.core.contracts import CastDecl, check_node

    Up = S.Schema.of("Up", **{f"c{i}": int for i in range(50)})
    Down = S.Schema.of("Down", **{f"c{i}": float for i in range(50)})
    us = _t(lambda: check_node({"up": Up}, Down)) * 1e6
    row("contracts", "check_node_50cols", us, "us/call",
        "moment-2 edge check; widening 50 columns")

    DownN = S.Schema.of("DownN", **{f"c{i}": S.INT32 for i in range(50)})
    casts = [CastDecl(f"c{i}", S.INT32) for i in range(50)]
    us = _t(lambda: check_node({"up": Up}, DownN, casts=casts)) * 1e6
    row("contracts", "check_node_50casts", us, "us/call",
        "50 declared narrowing casts")


# ---------------------------------------------------------------------------
# 2. Git-for-data (paper §3.2 — zero-copy branching must be O(1) in the
#    size of the data; merges are logical)
# ---------------------------------------------------------------------------

def bench_catalog():
    from repro.core.catalog import Catalog

    cat = Catalog()
    for i in range(100):
        cat.write_table("main", f"t{i}", f"s{i}")

    us = _t(lambda: cat.write_table("main", "hot", "snap")) * 1e6
    row("catalog", "write_table_commit", us, "us/call",
        "commit + head advance; 100-table lake")

    i = [0]

    def mk():
        cat.create_branch(f"b{i[0]}", "main")
        i[0] += 1
    us = _t(mk) * 1e6
    row("catalog", "create_branch", us, "us/call",
        "zero-copy: independent of data size")

    cat2 = Catalog()
    for k in range(10):
        cat2.write_table("main", f"t{k}", f"s{k}")
    j = [0]

    def merge_cycle():
        b = f"f{j[0]}"
        j[0] += 1
        cat2.create_branch(b, "main")
        cat2.write_table(b, f"new{j[0]}", "s")
        cat2.merge(b, into="main")
    us = _t(merge_cycle, n=50) * 1e6
    row("catalog", "branch_write_merge", us, "us/cycle",
        "fast-forward merge is a ref move")


# ---------------------------------------------------------------------------
# 3. Transactional runs vs direct writes (paper §3.3 trade-off claim)
# ---------------------------------------------------------------------------

def bench_txn_overhead():
    from repro.core.catalog import Catalog
    from repro.core.transactions import TransactionalRun

    for n_tables in (1, 3, 10, 30):
        cat = Catalog()

        def direct():
            for t in range(n_tables):
                cat.write_table("main", f"t{t}", "s")

        def txn():
            with TransactionalRun(cat, "main") as x:
                for t in range(n_tables):
                    x.write_table(f"t{t}", "s")

        d = _t(direct, n=30) * 1e6
        x = _t(txn, n=30) * 1e6
        row("txn", f"direct_{n_tables}t", d, "us/run", "")
        row("txn", f"transactional_{n_tables}t", x, "us/run",
            f"overhead {x / d:.2f}x — amortized by table count")


# ---------------------------------------------------------------------------
# 4. Worker-side validation + Appendix A elision speedup
# ---------------------------------------------------------------------------

def bench_validation():
    from repro.core import schema as S
    from repro.core.contracts import validate_table
    from repro.data.tables import Table

    n = 1_000_000
    raw = {
        "a": np.arange(n, dtype=np.int64),
        "b": np.arange(n, dtype=np.float64),
        "c": np.array(["x"] * n, dtype=object),
    }
    Sch = S.Schema.of("Sch", a=int, b=float, c=str)
    # the physical null scan happens at table materialization (object
    # columns get a validity mask); validation itself reads precomputed
    # state — measure both, since "worker moment" = materialize+check.
    ms_ingest = _t(lambda: Table(raw), n=10) * 1e3
    row("validation", "materialize_1M_rows", ms_ingest, "ms/call",
        "includes the physical null scan of the str column")
    t = Table(raw)
    us = _t(lambda: validate_table(t, Sch), n=50) * 1e6
    row("validation", "validate_1M_rows", us, "us/call",
        "dtype + precomputed-nullability checks (O(cols))")
    us_elided = _t(lambda: validate_table(
        t, Sch, elide=frozenset({"a", "b", "c"})), n=50) * 1e6
    row("validation", "validate_1M_rows_elided", us_elided, "us/call",
        "Dafny-style static discharge skips the null checks")


# ---------------------------------------------------------------------------
# 5. End-to-end pipeline run (Fig. 1 path: plan -> worker -> txn commit)
# ---------------------------------------------------------------------------

def bench_pipeline_run():
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.planner import plan
    from repro.core.runner import Client
    from repro.data.tables import Table, col

    class Raw(S.Schema):
        k: str
        v: int

    class Out(S.Schema):
        k: str
        v: int

    n = 100_000
    client = Client()
    client.write_source_table("main", "raw_table", Table({
        "k": np.array(["a"] * n, dtype=object),
        "v": np.arange(n, dtype=np.int64)}))

    p = Pipeline("bench")
    p.source("raw_table", Raw)

    @p.node()
    def out_table(df: Raw = "raw_table") -> Out:
        return df.select([col("k"), col("v")])

    pl = plan(p)
    ms = _t(lambda: plan(p), n=20) * 1e3
    row("pipeline", "plan", ms, "ms/call", "control-plane only")
    ms = _t(lambda: client.run(pl, "main", cache=False), n=5, warmup=1) * 1e3
    row("pipeline", "run_100k_rows", ms, "ms/run",
        "execute+validate+snapshot+txn-commit (cache off)")
    ms = _t(lambda: client.run(pl, "main"), n=5, warmup=1) * 1e3
    row("pipeline", "run_100k_rows_cached", ms, "ms/run",
        "content-addressed cache hit (validate+publish only)")


# ---------------------------------------------------------------------------
# 6. Training / serving substrate (tokens/sec on the smoke config — CPU
#    numbers are for regression tracking, not roofline claims)
# ---------------------------------------------------------------------------

def bench_train_step():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model as MDL
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_loop import TrainConfig, make_train_step

    for arch in ("xlstm_350m", "phi4_mini_3b", "granite_moe_3b"):
        cfg = get_smoke_config(arch)
        params = MDL.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        B, S = 4, 64
        toks = jnp.zeros((B, S), jnp.int32)
        step = jax.jit(make_train_step(
            cfg, AdamWConfig(), TrainConfig(remat=None,
                                            block_q=32, block_kv=32)))
        p, o, m = step(params, opt, toks, toks)      # compile
        jax.block_until_ready(m["loss"])
        state = {"p": p, "o": o}

        def run():
            state["p"], state["o"], mm = step(state["p"], state["o"],
                                              toks, toks)
            jax.block_until_ready(mm["loss"])

        s = _t(run, n=5, warmup=1)
        row("train_step", arch, B * S / s, "tokens/s",
            f"smoke cfg; CPU; {s * 1e3:.1f} ms/step")


def bench_decode_step():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.models import model as MDL

    cfg = get_smoke_config("phi4_mini_3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    B = 8
    caches = MDL.init_cache(cfg, B, 128)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(lambda p, t, c: MDL.decode_step(p, cfg, t, c))
    lg, caches = step(params, tok, caches)
    jax.block_until_ready(lg)
    state = {"c": caches}

    def run():
        lg, state["c"] = step(params, tok, state["c"])
        jax.block_until_ready(lg)

    s = _t(run, n=10, warmup=2)
    row("decode_step", "phi4_mini_3b", B / s, "tokens/s",
        f"batch {B}; smoke cfg; CPU")


def main() -> None:
    import repro.obs as obs
    from benchmarks.columnar_kernels import bench_columnar
    from benchmarks.concurrent_publication import (
        bench_concurrent_publication)

    print("name,metric,value,unit,notes")
    bench_contracts()
    bench_catalog()
    bench_txn_overhead()
    bench_concurrent_publication()
    bench_validation()

    # tracing-overhead gate (DESIGN.md §14): the flight recorder must
    # cost <= 2% disabled / <= 10% enabled on the 1e6-row columnar
    # workload. Runs FIRST, untraced — it measures tracing itself.
    from benchmarks.tracing_overhead import bench_tracing_overhead
    write_bench_doc(bench_tracing_overhead(smoke=True))

    # Every remaining gate runs under one flight recorder: each gets a
    # "benchmark" span whose wall time is folded into its committed
    # BENCH doc (the per-phase trajectory), and the whole session's
    # span tree lands in bench_trace.json (Chrome trace-event format —
    # load in chrome://tracing or Perfetto; CI uploads it as an
    # artifact). Gates compare candidates that are BOTH traced, so
    # their speedup ratios are unperturbed.
    with obs.tracing() as rec:
        def gated(name, fn):
            with rec.span("benchmark", name=name) as sp:
                doc = fn()
            doc["phase_wall_s"] = round(sp.duration_s, 6)
            doc["phase_spans"] = len(rec.subtree(sp))
            write_bench_doc(doc)

        # execution-backend gate (DESIGN.md §9): asserts the vectorized
        # backend's speedup over the row-loop reference, smoke-sized.
        gated("columnar_kernels", lambda: bench_columnar(smoke=True))
        # distributed-join gate (DESIGN.md §10): asserts the sharded
        # backend's speedup over vectorized on the forced 8-device mesh
        # (subprocess: the mesh must exist before jax initializes — the
        # child's spans stay in the child; the span here times the
        # phase).
        gated("sharded_join",
              lambda: bench_mesh_subprocess("sharded_join"))
        # sharded group-by gate (DESIGN.md §12): asserts the
        # pre-exchange partial-aggregation speedup over the vectorized
        # single-sort path on the same forced mesh, all five agg fns
        # fingerprint-checked against reference first.
        gated("sharded_groupby",
              lambda: bench_mesh_subprocess("sharded_groupby"))
        # plan-optimizer gate (DESIGN.md §11): optimized plans must
        # match unoptimized bit-for-bit and beat them on the
        # pushdown-heavy three-table pipeline, smoke-sized.
        from benchmarks.plan_optimizer import bench_plan_optimizer
        gated("plan_optimizer", lambda: bench_plan_optimizer(smoke=True))
        # SQL front-door gate (DESIGN.md §13): text-to-result star
        # query through Client.sql — optimizer passes must fire on the
        # compiled tree, a repeated query at the same commit must
        # execute zero nodes, and optimized must beat unoptimized,
        # smoke-sized.
        from benchmarks.sql_front_door import bench_sql_front_door
        gated("sql_front_door", lambda: bench_sql_front_door(smoke=True))
        # chaos tier gate (DESIGN.md §15): contended publication under a
        # fixed injected-fault budget — success-rate floor at every
        # writer count, jittered-vs-linear backoff comparison, and a
        # hostile-swarm linearizability smoke, smoke-sized.
        from benchmarks.contended_publication import (
            bench_contended_publication_chaos)
        gated("contended_publication",
              lambda: bench_contended_publication_chaos(smoke=True))

    trace_path = os.path.join(_REPO_ROOT, "bench_trace.json")
    obs.write_chrome_trace(trace_path, rec.spans())
    row("trace", "spans", len(rec.spans()), "spans", trace_path)

    bench_pipeline_run()
    bench_train_step()
    bench_decode_step()


if __name__ == "__main__":
    main()
