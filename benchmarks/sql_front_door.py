"""SQL front-door gate (DESIGN.md §13): text-to-result queries must
inherit the whole execution stack's performance properties, not just
its correctness.

The workload is a star schema queried through ``Client.sql``: a
selective WHERE on a dimension column over a two-join chain (filter
pushdown + probe fusion have teeth), join keys spelled the same on
both sides (no rename projection, so join reordering stays legal) and
dead fact payload columns the query never references (column pruning
skips gathering them). The gate asserts:

  1. the optimizer actually fires — >= 2 distinct passes leave
     provenance on the compiled query's step;
  2. re-running the query at the same commit executes ZERO nodes (the
     content-addressed cache keys on the logical tree, so the second
     run — any spelling — is a metadata-only hit);
  3. optimized execution is >= 1.5x unoptimized (``--smoke``: 1.2x),
     fingerprint-verified equal first.

Run: ``PYTHONPATH=src python -m benchmarks.sql_front_door [--smoke]``
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

MIN_SPEEDUP = 1.5
MIN_SPEEDUP_SMOKE = 1.2
MIN_DISTINCT_PASSES = 2

N_DEAD_COLS = 8

QUERY = ("SELECT f.user_id, f.amount, i.weight "
         "FROM fact f "
         "JOIN users u ON f.user_id = u.user_id "
         "JOIN items i ON f.item_id = i.item_id "
         "WHERE u.segment = 3")


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of_interleaved(reps, fns):
    """Best-of timing with candidates interleaved per rep (see
    benchmarks.plan_optimizer): host noise degrades all candidates
    alike instead of whichever happened to run last."""
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _write_star_schema(client, n_fact, n_users, n_items):
    from repro.data.tables import Table

    rng = np.random.default_rng(0)
    fact = {"user_id": rng.integers(0, n_users, n_fact),
            "item_id": rng.integers(0, n_items, n_fact),
            "amount": rng.normal(size=n_fact)}
    for i in range(N_DEAD_COLS):
        fact[f"pay{i}"] = rng.normal(size=n_fact)
    users = {"user_id": np.arange(n_users, dtype=np.int64),
             "segment": (np.arange(n_users) % 64).astype(np.int64),
             "bio": np.array([f"user-{i}-bio" for i in range(n_users)],
                             dtype=object)}
    items = {"item_id": np.arange(n_items, dtype=np.int64),
             "weight": rng.normal(size=n_items)}
    client.write_source_table("main", "fact", Table(fact))
    client.write_source_table("main", "users", Table(users))
    client.write_source_table("main", "items", Table(items))


def bench_sql_front_door(smoke: bool = False,
                         json_path: str | None = None,
                         reps: int | None = None) -> dict:
    from repro.core.runner import Client

    n_fact = 120_000 if smoke else 1_000_000
    n_users, n_items = ((30_000, 15_000) if smoke
                       else (100_000, 50_000))
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    reps = reps if reps is not None else (5 if smoke else 3)

    client = Client()
    _write_star_schema(client, n_fact, n_users, n_items)

    # gate 1: the compiled query's plan is actually rewritten.
    first = client.sql(QUERY)
    passes_fired = {m.split(":", 1)[0]
                    for s in first.plan.steps for m in s.provenance}
    row("sql_front_door", "distinct_passes", len(passes_fired),
        "count", "; ".join(sorted(passes_fired)))
    assert len(passes_fired) >= MIN_DISTINCT_PASSES, (
        f"expected >= {MIN_DISTINCT_PASSES} optimizer passes to fire "
        f"on the star query, got {sorted(passes_fired)}")

    # gate 2: same commit, repeated query (respelled, even) -> a pure
    # cache hit executing zero nodes.
    respelled = " ".join(QUERY.lower().split())
    t0 = time.perf_counter()
    rerun = client.sql(respelled)
    hit_s = time.perf_counter() - t0
    row("sql_front_door", "cached_rerun", hit_s * 1e3, "ms/query",
        f"executed={len(rerun.executed)} cached={len(rerun.cached)}")
    assert rerun.executed == (), (
        f"repeated query at an unchanged commit must execute zero "
        f"nodes, executed={rerun.executed}")
    assert rerun.fingerprint() == first.fingerprint()

    # gate 3: optimized >= floor x unoptimized — equal results first.
    raw = client.sql(QUERY, optimizer_passes=(), cache=False)
    assert raw.fingerprint() == first.fingerprint(), (
        "optimized SQL execution diverges from unoptimized "
        f"({first.fingerprint()} != {raw.fingerprint()})")

    timings = _best_of_interleaved(reps, {
        "unoptimized": lambda: client.sql(
            QUERY, optimizer_passes=(), cache=False),
        "optimized": lambda: client.sql(QUERY, cache=False)})
    for name, t in timings.items():
        row("sql_front_door", name, t * 1e3, "ms/query",
            f"fact={n_fact} users={n_users} items={n_items}")
    speedup = timings["unoptimized"] / timings["optimized"]
    row("sql_front_door", "speedup", speedup, "x",
        f"optimized over unoptimized; gate >= {floor}x")

    doc = {
        "bench": "sql_front_door",
        "smoke": smoke,
        "n_fact": n_fact,
        "n_users": n_users,
        "n_items": n_items,
        "query": QUERY,
        "distinct_passes": sorted(passes_fired),
        "cached_rerun_ms": hit_s * 1e3,
        "cached_rerun_executed": len(rerun.executed),
        "timings_s": timings,
        "speedup": speedup,
        "gate_min_speedup": floor,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    assert speedup >= floor, (
        f"optimized SQL execution must be >= {floor}x over "
        f"unoptimized at fact={n_fact}, got {speedup:.2f}x "
        f"({timings['unoptimized'] * 1e3:.0f}ms vs "
        f"{timings['optimized'] * 1e3:.0f}ms)")
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller tables, relaxed 1.2x gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_sql_front_door(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
