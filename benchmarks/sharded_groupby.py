"""Sharded partial-aggregation benchmark (DESIGN.md §12) — the perf
gate for the aggregation refactor.

Workload: multi-function group-by (SUM, COUNT, MIN, MAX, MEAN in one
pass) over n rows with ~4096 distinct dense int32 keys — the regime
the ``partial_agg`` optimizer rewrite targets. Each device reduces its
shard to at most 4096 partial rows *before* the all-to-all exchange,
so the exchange moves O(devices x groups) partials instead of O(n)
rows; the single-host vectorized backend must instead sort-or-scatter
the full n rows once per aggregate family.

Values are int32, so every aggregate — including MEAN, finalized as an
exact float64 division of exact int sums — is bit-for-bit across
backends: not even the float summation-order carve-out applies, and
the correctness gate is plain fingerprint equality against the
``reference`` row-loop oracle. A fast wrong answer fails the
benchmark, not production.

Perf gate: sharded >= 1.5x over vectorized at n = 2e6 on an 8-device
forced-host mesh (>= 1.2x at the 1e6-row smoke size CI runs). Emits a
BENCH JSON line and, with ``--json PATH``, the same document to disk.

Run: ``PYTHONPATH=src python -m benchmarks.sharded_groupby
[--smoke] [--json PATH]``. Must be started fresh (it forces
``--xla_force_host_platform_device_count=8`` before JAX imports);
``benchmarks/run.py`` launches it as a subprocess for exactly that
reason.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

N_DEVICES = 8

# must precede any jax import (including transitively via repro.exec)
if "jax" not in sys.modules and "--xla_force_host_platform" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_DEVICES}").strip()

import numpy as np  # noqa: E402

MIN_SPEEDUP = 1.5
MIN_SPEEDUP_SMOKE = 1.2

N_KEYS = 4096
SPECS = (("sum", "v"), ("count", "v"), ("min", "v"), ("max", "v"),
         ("mean", "v"))


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of_interleaved(reps, fns):
    """Best-of timing with the candidates interleaved per rep, so a
    throttled / noisy host (CI runners, cgroup cpu shares) degrades
    every candidate's reps alike instead of whichever ran last."""
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _table(n: int):
    from repro.data.tables import Table

    rng = np.random.default_rng(0)
    return Table({
        "k": rng.integers(0, N_KEYS, n).astype(np.int32),
        "v": rng.integers(-1_000_000, 1_000_000, n).astype(np.int32),
    })


def bench_sharded_groupby(smoke: bool = False,
                          json_path: str | None = None,
                          reps: int | None = None) -> dict:
    import jax

    from repro import exec as exec_backends

    n_dev = jax.device_count()
    if n_dev < N_DEVICES:
        raise SystemExit(
            f"sharded_groupby needs a {N_DEVICES}-device mesh, found "
            f"{n_dev}: run fresh (module sets XLA_FLAGS) or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{N_DEVICES}")

    n = 1_000_000 if smoke else 2_000_000
    floor = MIN_SPEEDUP_SMOKE if smoke else MIN_SPEEDUP
    reps = reps if reps is not None else (5 if smoke else 4)
    t = _table(n)

    def agg(be):
        return t.group_by(["k"]).agg(*SPECS, backend=be)

    # correctness first: bit-for-bit vs the reference oracle. int32
    # values => every fn (mean included) is carve-out-free.
    want = agg("reference").fingerprint()
    checked = ["vectorized", "jax", "sharded", "auto"]
    for be in checked:
        got = agg(be).fingerprint()
        assert got == want, (
            f"group_by_agg: backend {be!r} diverges from reference "
            f"({got} != {want})")

    timings = _best_of_interleaved(
        reps, {be: (lambda b=be: agg(b))
               for be in ("vectorized", "sharded")})
    for be, tt in timings.items():
        row("sharded_groupby", f"agg_{be}", tt * 1e3, "ms/call",
            f"n={n} keys={N_KEYS} fns={len(SPECS)} mesh={n_dev}")
    speedup = timings["vectorized"] / timings["sharded"]
    row("sharded_groupby", "speedup", speedup, "x",
        f"sharded over vectorized; gate >= {floor}x")

    # auto must route this exact workload to the sharded backend
    from repro.exec.auto import choose_group_by_agg
    from repro.exec.stats import collect_stats
    chosen = choose_group_by_agg(
        collect_stats(t._to_cols(), ["k"]),
        (t.column("v").dtype,),
        n_devices=n_dev, sharded_available=True, jax_available=True)
    row("sharded_groupby", "auto_choice", float(chosen == "sharded"),
        "", f"auto picked {chosen!r}")

    doc = {
        "bench": "sharded_groupby",
        "n_rows": n,
        "n_keys": N_KEYS,
        "agg_fns": sorted({fn for fn, _v in SPECS}),
        "smoke": smoke,
        "mesh_devices": n_dev,
        "backends_checked": checked,
        "timings_s": timings,
        "speedup": speedup,
        "auto_choice": chosen,
        "gate_min_speedup": floor,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    assert chosen == "sharded", (
        f"auto-selection must route the large dense-int-key "
        f"aggregation to 'sharded' on a multi-device mesh, picked "
        f"{chosen!r}")
    assert speedup >= floor, (
        f"sharded group-by must be >= {floor}x over vectorized at "
        f"n={n} on a {n_dev}-device mesh, got {speedup:.2f}x "
        f"({timings['vectorized'] * 1e3:.0f}ms vs "
        f"{timings['sharded'] * 1e3:.0f}ms)")
    assert exec_backends.get_backend("auto").cache_token() \
        .startswith("auto[v2")
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: smaller n, relaxed 1.2x gate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_sharded_groupby(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
