"""Wave-parallel + content-addressed incremental run benchmark (§8).

Three claims, each asserted (the benchmark doubles as a regression
gate — CI runs it in ``--smoke`` mode):

1. **wave parallelism**: an 8-wide diamond DAG (src -> 8 mids -> sink,
   per-node work ``WORK_S``) runs > 1.5x faster with wave scheduling
   than sequentially (``max_workers=1``);
2. **full cache hit**: re-running the identical plan over identical
   sources executes 0 nodes and publishes 0 new commits;
3. **incremental subgraph**: after touching ONE of two sources, only
   the dependent half of the DAG re-executes.

Run: ``PYTHONPATH=src python -m benchmarks.incremental_runs [--smoke]``
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, col

WIDTH = 8

Src = S.Schema.of("Src", x=int)
Mid = S.Schema.of("Mid", x=int, y=int)
Total = S.Schema.of("Total", total=int)


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _add_mid(p: Pipeline, i: int, work_s: float, src: str) -> None:
    @p.node(name=f"mid_{i}")
    def mid(df: Src = src) -> Mid:
        time.sleep(work_s)          # per-node work (I/O-shaped: yields)
        return df.select([col("x"), (col("x") * (i + 1)).alias("y")])


def diamond(work_s: float, *, two_roots: bool = False) -> Pipeline:
    """src[,src2] -> mid_0..mid_7 (one wave) -> sink (second wave)."""
    p = Pipeline("diamond8")
    p.source("src", Src)
    if two_roots:
        p.source("src2", Src)
    for i in range(WIDTH):
        root = "src2" if (two_roots and i >= WIDTH // 2) else "src"
        _add_mid(p, i, work_s, root)

    @p.node()
    def sink(a0: Mid = "mid_0", a1: Mid = "mid_1", a2: Mid = "mid_2",
             a3: Mid = "mid_3", a4: Mid = "mid_4", a5: Mid = "mid_5",
             a6: Mid = "mid_6", a7: Mid = "mid_7") -> Total:
        total = sum(int(t.column("y").sum())
                    for t in (a0, a1, a2, a3, a4, a5, a6, a7))
        return Table({"total": np.array([total], dtype=np.int64)})

    return p


def _client(*, two_roots: bool = False) -> Client:
    c = Client()
    c.write_source_table("main", "src",
                         Table({"x": np.arange(32, dtype=np.int64)}))
    if two_roots:
        c.write_source_table("main", "src2",
                             Table({"x": np.arange(32, dtype=np.int64)}))
    return c


def _best_of(n: int, fn) -> float:
    # min-of-n: one scheduler stall on a noisy CI runner must not fail
    # the regression gate.
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_incremental(work_s: float) -> None:
    pl = plan(diamond(work_s))

    # 1) wave-parallel speedup over sequential execution
    t_seq = _best_of(2, lambda: _client().run(
        pl, "main", max_workers=1, cache=False))
    t_par = _best_of(2, lambda: _client().run(
        pl, "main", max_workers=WIDTH, cache=False))
    speedup = t_seq / t_par
    row("incremental", f"wave_speedup_{WIDTH}wide", speedup, "x",
        f"seq {t_seq * 1e3:.1f}ms vs {WIDTH} workers {t_par * 1e3:.1f}ms")
    assert speedup > 1.5, (
        f"wave scheduling must beat sequential by >1.5x, got {speedup:.2f}")

    # 2) content-addressed cache: second identical run executes nothing
    client = _client()
    r1 = client.run(pl, "main")
    commits = len(client.catalog.log("main", limit=1000))
    t0 = time.perf_counter()
    r2 = client.run(pl, "main")
    t_hit = time.perf_counter() - t0
    row("incremental", "cached_rerun_nodes", len(r2.executed), "nodes",
        f"first run executed {len(r1.executed)}; re-run {t_hit * 1e3:.1f}ms")
    assert r2.executed == (), "fully-cached re-run must execute 0 nodes"
    assert len(client.catalog.log("main", limit=1000)) == commits, \
        "fully-cached re-run must publish no new commit"

    # 3) touch one of two roots: only its half of the DAG re-executes
    pl2 = plan(diamond(work_s, two_roots=True))
    client = _client(two_roots=True)
    client.run(pl2, "main")
    client.write_source_table("main", "src2",
                              Table({"x": np.arange(7, dtype=np.int64)}))
    r3 = client.run(pl2, "main")
    row("incremental", "changed_subgraph_nodes", len(r3.executed), "nodes",
        f"{sorted(r3.executed)} after touching src2 "
        f"({len(r3.cached)} cached)")
    assert set(r3.executed) == {"mid_4", "mid_5", "mid_6", "mid_7",
                                "sink"}, r3.executed


def main() -> None:
    smoke = "--smoke" in sys.argv
    print("name,metric,value,unit,notes")
    # smoke keeps per-node work large enough that the sleep term (not
    # scheduler noise) dominates the speedup measurement on CI runners.
    bench_incremental(work_s=0.02 if smoke else 0.05)


if __name__ == "__main__":
    main()
