"""Tracing-overhead gate (DESIGN.md §14): the flight recorder must be
near-free when disabled and cheap when enabled.

Workload: a 1e6-row columnar pipeline (filter + arithmetic projection
into a narrowing select) executed end-to-end through ``Client.run``
with the node cache off, so every rep pays execute + validate +
snapshot + transactional publish — the realistic denominator for an
"overhead" claim.

Two gates:

* **enabled <= 10%** — best-of A/B of the identical run traced (fresh
  ``TraceRecorder`` per rep, manifest stored on commit) vs untraced.
  Interleaved reps so host noise degrades both candidates alike.
* **disabled <= 2%** — there is no uninstrumented build to A/B
  against, so the disabled bound is cost-accounted from first
  principles: the disabled path's only residue is ``get_recorder()``
  + an ``.enabled`` attribute test at each instrumentation site (the
  call-site discipline: no span objects, no attr dicts, no string
  formatting unless enabled). We measure that primitive's cost in a
  tight loop and charge a deliberately generous 100 sites per node
  plus 1000 per run — an order of magnitude above the real count —
  and the bill must still be <= 2% of the untraced run.

Run: ``PYTHONPATH=src python -m benchmarks.tracing_overhead [--smoke]
[--json PATH] [--trace PATH]`` — ``--trace`` dumps one traced rep's
span tree as a Chrome trace-event file (load in ``chrome://tracing``
or Perfetto; uploaded as a CI artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

MAX_ENABLED_OVERHEAD = 0.10
MAX_DISABLED_OVERHEAD = 0.02

N_ROWS = 1_000_000

# deliberately generous accounting for the disabled-path bill (the
# real engine touches get_recorder()/.enabled a handful of times per
# node; we charge two orders of magnitude more headroom).
SITES_PER_NODE = 100
SITES_PER_RUN = 1000


def row(name, metric, value, unit, notes=""):
    print(f"{name},{metric},{value:.6g},{unit},{notes}")


def _best_of_interleaved(reps, fns):
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def _workload():
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.planner import plan
    from repro.core.runner import Client
    from repro.data.tables import Table, col

    Raw = S.Schema.of("Raw", k=int, v=float, w=float)
    Scored = S.Schema.of("Scored", k=int, score=float)
    Top = S.Schema.of("Top", k=int, score=float)

    rng = np.random.default_rng(0)
    client = Client()
    client.write_source_table("main", "raw_events", Table({
        "k": rng.integers(0, 1 << 16, N_ROWS),
        "v": rng.normal(size=N_ROWS),
        "w": rng.normal(size=N_ROWS)}))

    p = Pipeline("tracing_overhead")
    p.source("raw_events", Raw)

    @p.node()
    def scored(df: Raw = "raw_events") -> Scored:
        return df.select([col("k"), (col("v") * col("w")).alias("score")])

    @p.node()
    def top(df: Scored = "scored") -> Top:
        return df.filter(col("score") > 0.0).select(
            [col("k"), col("score")])

    return client, plan(p)


def _disabled_primitive_cost() -> float:
    """Per-site cost of the disabled path's entire residue: fetch the
    ambient recorder and test .enabled."""
    from repro.obs import get_recorder

    assert not get_recorder().enabled, (
        "gate must run with the null recorder installed")
    n = 200_000
    hits = 0
    t0 = time.perf_counter()
    for _ in range(n):
        if get_recorder().enabled:      # the real call-site shape
            hits += 1
    per_site = (time.perf_counter() - t0) / n
    assert hits == 0
    return per_site


def bench_tracing_overhead(smoke: bool = False,
                           json_path: str | None = None,
                           trace_path: str | None = None,
                           reps: int | None = None) -> dict:
    import repro.obs as obs

    reps = reps if reps is not None else (5 if smoke else 8)
    client, pl = _workload()
    n_nodes = len(pl.steps)

    def untraced():
        client.run(pl, "main", cache=False)

    def traced():
        with obs.tracing():
            client.run(pl, "main", cache=False)

    untraced()                          # warm (jit-free, but allocators)
    timings = _best_of_interleaved(
        reps, {"untraced": untraced, "traced": traced})
    for name, t in timings.items():
        row("tracing_overhead", name, t * 1e3, "ms/run",
            f"{N_ROWS} rows, {n_nodes} nodes, cache off")

    enabled_overhead = timings["traced"] / timings["untraced"] - 1.0
    row("tracing_overhead", "enabled_overhead", enabled_overhead * 100,
        "%", f"gate <= {MAX_ENABLED_OVERHEAD * 100:.0f}%")

    per_site = _disabled_primitive_cost()
    sites = SITES_PER_RUN + SITES_PER_NODE * n_nodes
    disabled_bill = per_site * sites
    disabled_overhead = disabled_bill / timings["untraced"]
    row("tracing_overhead", "disabled_site_cost", per_site * 1e9,
        "ns/site", "get_recorder() + .enabled test")
    row("tracing_overhead", "disabled_overhead",
        disabled_overhead * 100, "%",
        f"{sites} sites charged (generous); "
        f"gate <= {MAX_DISABLED_OVERHEAD * 100:.0f}%")

    if trace_path:
        with obs.tracing() as rec:
            client.run(pl, "main", cache=False)
        obs.write_chrome_trace(trace_path, rec.spans())
        row("tracing_overhead", "trace_spans", len(rec.spans()),
            "spans", trace_path)

    doc = {
        "bench": "tracing_overhead",
        "smoke": smoke,
        "n_rows": N_ROWS,
        "n_nodes": n_nodes,
        "timings_s": timings,
        "enabled_overhead": enabled_overhead,
        "disabled_site_cost_ns": per_site * 1e9,
        "disabled_sites_charged": sites,
        "disabled_overhead": disabled_overhead,
        "gate_max_enabled": MAX_ENABLED_OVERHEAD,
        "gate_max_disabled": MAX_DISABLED_OVERHEAD,
    }
    print("BENCH " + json.dumps(doc, sort_keys=True))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)

    assert enabled_overhead <= MAX_ENABLED_OVERHEAD, (
        f"enabled tracing overhead {enabled_overhead * 100:.1f}% "
        f"exceeds the {MAX_ENABLED_OVERHEAD * 100:.0f}% gate "
        f"({timings['traced'] * 1e3:.1f}ms vs "
        f"{timings['untraced'] * 1e3:.1f}ms)")
    assert disabled_overhead <= MAX_DISABLED_OVERHEAD, (
        f"disabled-path bill {disabled_overhead * 100:.2f}% exceeds "
        f"the {MAX_DISABLED_OVERHEAD * 100:.0f}% gate "
        f"({per_site * 1e9:.0f}ns x {sites} sites)")
    return doc


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer reps (same 1e6-row workload)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the BENCH JSON document to PATH")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="dump one traced rep as a Chrome trace file")
    args = ap.parse_args(argv)
    print("name,metric,value,unit,notes")
    bench_tracing_overhead(smoke=args.smoke, json_path=args.json,
                           trace_path=args.trace)


if __name__ == "__main__":
    main()
