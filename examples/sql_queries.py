"""Worked example: the SQL front door end to end (DESIGN.md §13).

    PYTHONPATH=src python examples/sql_queries.py

One method call — ``Client.sql(query, ref=...)`` — runs the whole
paper pipeline in miniature: the catalog resolves the ref to a pinned
commit, the snapshot *manifests* (no column data) synthesize a
contract per table, the query compiles to the same logical IR
hand-built declarative nodes use, the plan flows through ``optimize()``
with EXPLAIN provenance, the stats-driven ``auto`` backend executes
it, and the result caches content-addressed by the *logical tree* —
so any respelling of the query at the same commit is a zero-execution
metadata hit.

Things to watch for in the output:

- the EXPLAIN header quotes the original query text, then shows what
  the optimizer did to it (pushdown, pruning, probe fusion);
- the inferred output contract: dtypes computed by evaluating the
  compiled expressions with the real kernels, nullability widened on
  the right side of the LEFT JOIN, lineage on pass-through columns;
- the second run reporting ``executed=()`` — same commit, same tree,
  nothing to do — even though the spelling changed;
- the unknown-column error naming the ref and suggesting a fix: the
  message an agent retries from.
"""
import numpy as np

from repro.core.runner import Client
from repro.data.tables import Table
from repro.sql.errors import SqlCompileError


def build_client():
    client = Client()
    rng = np.random.default_rng(7)
    n = 20_000
    client.write_source_table("main", "fact", Table({
        "user_id": rng.integers(0, 900, n),
        "item_id": rng.integers(0, 200, n),
        "amount": np.round(rng.gamma(2.0, 30.0, n), 2),
    }), message="facts")
    client.write_source_table("main", "users", Table({
        "user_id": np.arange(800, dtype=np.int64),   # 100 ids unmatched
        "segment": (np.arange(800) % 16).astype(np.int64),
        "name": np.array([f"user-{i}" for i in range(800)],
                         dtype=object),
    }), message="users dimension")
    client.write_source_table("main", "items", Table({
        "item_id": np.arange(200, dtype=np.int64),
        "weight": rng.normal(size=200),
    }), message="items dimension")
    return client


def main():
    client = build_client()

    # -- 1. a star query with GROUP BY, compiled from text ----------------
    query = ("SELECT u.name, SUM(f.amount) AS total, "
             "COUNT(f.amount) AS orders "
             "FROM fact f "
             "JOIN users u ON f.user_id = u.user_id "
             "JOIN items i ON f.item_id = i.item_id "
             "WHERE u.segment = 3 "
             "GROUP BY u.name ORDER BY total DESC LIMIT 5")
    result = client.sql(query)
    print("=== EXPLAIN (plan.describe()) ===")
    print(result.describe())
    print()
    print("=== inferred output contract ===")
    for c in result.schema.columns().values():
        print(f"  {c.describe()}")
    print()
    print("=== top spenders in segment 3 ===")
    for name, total, cnt in zip(result.table.column("name"),
                                result.table.column("total"),
                                result.table.column("orders")):
        print(f"  {name:>10}  {total:9.2f}  ({cnt} orders)")
    print()

    # -- 2. respell the query: same logical tree, zero executions ---------
    respelled = " ".join(query.lower().split())
    rerun = client.sql(respelled)
    print("=== respelled rerun at the same commit ===")
    print(f"  executed={rerun.executed!r} cached={rerun.cached!r}")
    print(f"  fingerprints equal: "
          f"{rerun.fingerprint() == result.fingerprint()}")
    print()

    # -- 3. LEFT JOIN: inferred nullability widens -------------------------
    left = client.sql(
        "SELECT f.user_id, f.amount, u.name FROM fact f "
        "LEFT JOIN users u ON f.user_id = u.user_id")
    names = left.table._data["name"]
    n_null = 0 if names.valid is None else int((~names.valid).sum())
    print("=== LEFT JOIN: contract inference ===")
    print(f"  name column declared: "
          f"{left.schema.columns()['name'].describe()}")
    print(f"  unmatched fact rows (NULL name): {n_null}")
    print()

    # -- 4. the error an agent retries from --------------------------------
    print("=== unknown column: compile-time error naming the ref ===")
    try:
        client.sql("SELECT u.nmae FROM users u")
    except SqlCompileError as e:
        print(f"  {e}")


if __name__ == "__main__":
    main()
