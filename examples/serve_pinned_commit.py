"""Serving against a pinned commit while training publishes new
checkpoints (the snapshot-read guarantee at the serving boundary).

    PYTHONPATH=src python examples/serve_pinned_commit.py
"""
import jax
import numpy as np

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.catalog import Catalog
from repro.models import model as MDL
from repro.serving.serve_loop import Request, ServeLoop, load_params_at
from repro.training.optimizer import adamw_init


class _Client:
    def __init__(self, catalog):
        self.catalog = catalog
        self.store = catalog.store


def main():
    cfg = get_smoke_config("phi4_mini_3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)

    catalog = Catalog()
    ckpt = CheckpointManager(catalog)
    ckpt.save(step=100, params=params, opt_state=adamw_init(params),
              data_state={"epoch": 0, "shard_order_seed": 0},
              metrics={"loss": 2.0}, code="v1")
    catalog.tag("serving/v1", "main")
    print("replica pinned to tag serving/v1")

    # replica loads from the immutable tag
    client = _Client(catalog)
    like = jax.tree.map(np.asarray, params)
    served_params = load_params_at(client, "serving/v1", like)

    # training publishes newer checkpoints on main — replica unaffected
    noisier = jax.tree.map(lambda x: x + 1.0
                           if hasattr(x, "dtype") and x.dtype.kind == "f"
                           else x, like)
    ckpt.save(step=200, params=noisier, opt_state=adamw_init(params),
              data_state={"epoch": 0, "shard_order_seed": 0},
              metrics={"loss": 1.5}, code="v2")
    pinned_again = load_params_at(client, "serving/v1", like)
    same = all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(served_params),
                   jax.tree.leaves(pinned_again)))
    print(f"main advanced to step {ckpt.latest_step('main')}; "
          f"pinned replica params unchanged: {same}")
    assert same

    # continuous-batching decode on the pinned params
    loop = ServeLoop(cfg, jax.tree.map(jax.numpy.asarray, served_params),
                     batch_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new=8)
        for i in range(8)]
    for r in reqs:
        loop.submit(r)
    loop.run()
    print(f"served {sum(r.done for r in reqs)}/8 requests; "
          f"sample completion: {reqs[0].out}")

    # promotion is a catalog op, not a file copy:
    catalog.tag("serving/v2", "main")
    print("promotion: tagged serving/v2 ->", catalog.head("serving/v2").id[:10])


if __name__ == "__main__":
    main()
