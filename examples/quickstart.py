"""Quickstart: the paper's running example (Listings 1–6), end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds the raw_table -> parent -> child -> grand_child DAG with typed
contracts, runs it transactionally on a feature branch, reviews, merges.
"""
import datetime

import numpy as np

from repro.core import schema as S
from repro.core.contracts import CastDecl
from repro.core.dag import Pipeline
from repro.core.errors import ContractCompositionError
from repro.core.planner import plan
from repro.core.quality import expect_not_null, expect_row_count
from repro.core.runner import Client
from repro.data.tables import Table, arrow_cast, col, lit, str_lit


# -- Listing 3: contracts as types ------------------------------------------

class RawSchema(S.Schema):
    col1: str
    col2: datetime.datetime
    col3: int


class ParentSchema(S.Schema):          # "Node 1"
    col1: str
    col2: datetime.datetime
    _S: int


class ChildSchema(S.Schema):           # "Node 2"
    col2: datetime.datetime            # inherited type
    col4: float                        # fresh type
    col5: S.Nullable[str]              # fresh type, UNION(str, None)


class Grand(S.Schema):                 # "Node 3"
    col2: datetime.datetime            # inherited type
    col4: int                          # inherited type is narrowed


def main():
    # -- a lake with one source table ---------------------------------------
    client = Client()
    client.write_source_table("main", "raw_table", Table({
        "col1": np.array(["a", "a", "b", "b", "b"], dtype=object),
        "col2": np.array(["2026-07-01"] * 5, dtype="datetime64[ns]"),
        "col3": np.array([1, 2, 3, 4, 5], dtype=np.int64),
    }))

    # -- Listings 4–5: the typed DAG ----------------------------------------
    p = Pipeline("quickstart")
    p.source("raw_table", RawSchema)

    @p.node()   # parent_table: ParentSchema <- raw_table
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return df.group_by_sum(["col1", "col2"], "col3", out="_S")

    @p.node()   # "Node 1" -> "Node 2"
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        return df.select([
            col("col2"),
            lit(0.25).alias("col4"),
            lit(None).alias("col5"),
        ])

    @p.node(casts=[CastDecl("col4", S.INT)])   # "Node 2" -> "Node 3"
    def grand_child(df: ChildSchema = child_table) -> Grand:
        return df.select([
            col("col2"),
            arrow_cast(col("col4"), str_lit("Int64")).alias("col4"),
        ])

    # -- moment 2: the control plane validates composition -------------------
    validated = plan(p)
    print(validated.describe())

    # schema failures are caught here, not at runtime:
    bad = Pipeline("bad")
    bad.source("raw_table", RawSchema)

    @bad.node()   # narrows col3 int->int32 with NO declared cast
    def broken(df: RawSchema = "raw_table") -> S.Schema.of("B",
                                                           col3=S.INT32):
        return df

    try:
        plan(bad)
    except ContractCompositionError as e:
        print(f"\n[control plane rejected ill-typed DAG] {e}\n")

    # -- Listing 6: branch, run transactionally, merge ------------------------
    client.create_branch("feature", from_ref="main")
    result = client.run(validated, "feature", verifiers={
        "parent_table": [expect_row_count(1, 100), expect_not_null("_S")],
    })
    st = result.state
    print(f"run {st.run_id}: {st.status} "
          f"(data commit {st.ref[:10]}, code {st.code_hash})")

    client.merge("feature", into="main")
    out = client.read_table("main", "grand_child")
    print("grand_child on main:", out.to_pydict())


if __name__ == "__main__":
    main()
