"""Agentic collaboration + the Fig. 4 counterexample, live.

    PYTHONPATH=src python examples/agent_branch_workflow.py

1. an agent proposes a pipeline change on an isolated branch;
2. a human reviews the diff and merges (the PR flow for data);
3. a user's run aborts, leaving a dangling transactional branch;
4. a second agent tries to build on the aborted branch and merge —
   the visibility guardrail refuses (paper Fig. 4 made unrepresentable);
5. the sanctioned path: allow_reuse -> quarantine -> re-verify -> merge.
"""
import numpy as np

from repro.core.catalog import Visibility
from repro.core.errors import TransactionAborted, VisibilityError
from repro.core.runner import Client
from repro.core.transactions import TransactionalRun
from repro.data.tables import Table


def main():
    client = Client()
    cat = client.catalog
    client.write_source_table("main", "sales",
                              Table({"amount": np.array([100, 200, 300])}))

    # -- 1+2: agent proposes on a branch; human reviews and merges ----------
    cat.create_branch("agent/cleanup", "main")
    with TransactionalRun(cat, "agent/cleanup", code="dedup-v1",
                          registry=client.registry) as txn:
        txn.write_table("sales_clean", "snap-dedup-1")
    print("agent proposed:", cat.diff("main", "agent/cleanup"))
    cat.merge("agent/cleanup", into="main")        # human-approved PR
    print("after review+merge, main tables:",
          sorted(cat.tables("main")))

    # -- 3: a run fails mid-pipeline -----------------------------------------
    try:
        with TransactionalRun(cat, "main", registry=client.registry) as t2:
            t2.write_table("P", "P-new")
            raise RuntimeError("node 'child' OOMed")
    except RuntimeError:
        pass
    aborted = t2.branch
    print(f"\nrun {t2.run_id} aborted; branch {aborted!r} kept for triage")
    print("  triage read:", cat.read_table(aborted, "P"))
    print("  main is untouched:", sorted(cat.tables("main")))

    # -- 4: the Fig. 4 hazard is refused --------------------------------------
    try:
        cat.create_branch("agent/opportunist", aborted)
    except VisibilityError as e:
        print(f"\n[guardrail] {e}")

    # -- 5: the sanctioned reuse path (idempotent re-run optimization) --------
    cat.create_branch("retry/child-fix", aborted, allow_reuse=True)
    info = cat.branch_info("retry/child-fix")
    print(f"\nreuse allowed -> visibility={info.visibility.value}")
    cat.write_table("retry/child-fix", "C", "C-recomputed")
    try:
        cat.merge("retry/child-fix", into="main")
    except VisibilityError as e:
        print(f"[guardrail] merge before re-verification: {e}")
    # re-run verifiers on the quarantined branch, then mark verified
    cat.mark("retry/child-fix", Visibility.QUARANTINED, verified=True)
    cat.merge("retry/child-fix", into="main")
    print("after re-verification the merge is legal; main:",
          sorted(cat.tables("main")))


if __name__ == "__main__":
    main()
