"""Worked example: the logical-plan optimizer end to end (DESIGN.md §11).

    PYTHONPATH=src python examples/optimized_pipeline.py

A three-table star pipeline — facts joined to two dimensions, a
selective filter authored at the top, a narrow projection — planned,
optimized, EXPLAINed, and executed both ways to show the optimizer's
contract: same published bytes, less work.

What the passes do to this pipeline:

- *filter_pushdown* moves ``segment == 3`` from above both joins down
  onto the ``users`` side (it only reads users columns);
- *join_reorder* probes the estimated-smaller dimension first when
  the planner's TableStats say the authored order is backwards;
- *column_pruning* stops reading the payload columns nothing
  references (they never appear in the projection, the join keys, or
  the output contract);
- *probe_fusion* turns the pushed-down filter into a masked join
  probe, so the filtered users table is never materialized at all.
"""
import numpy as np

from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, col
from repro.exec.stats import collect_stats
from repro.optimizer import optimize


class Fact(S.Schema):
    user_id: int
    item_id: int
    amount: float
    payload: float        # referenced by nothing: elision fodder


class Users(S.Schema):
    user_id: int
    segment: int
    bio: str              # referenced by nothing: elision fodder


class Items(S.Schema):
    item_id: int
    weight: float


class Out(S.Schema):
    user_id: int
    amount: float
    weight: float


def build_sources():
    rng = np.random.default_rng(0)
    n = 50_000
    fact = Table({"user_id": rng.integers(0, 5_000, n),
                  "item_id": rng.integers(0, 800, n),
                  "amount": rng.normal(size=n),
                  "payload": rng.normal(size=n)})
    users = Table({"user_id": np.arange(5_000, dtype=np.int64),
                   "segment": (np.arange(5_000) % 32).astype(np.int64),
                   "bio": np.array([f"user {i}" for i in range(5_000)],
                                   dtype=object)})
    items = Table({"item_id": np.arange(800, dtype=np.int64),
                   "weight": rng.normal(size=800)})
    return {"fact": fact, "users": users, "items": items}


def build_pipeline() -> Pipeline:
    p = Pipeline("star_example")
    p.source("fact", Fact)
    p.source("users", Users)
    p.source("items", Items)
    # authored naively: join everything, THEN filter, then project —
    # exactly the shape a human (or an agent) writes first.
    p.sql(name="out", inputs={"f": "fact", "u": "users", "i": "items"},
          input_schemas={"f": Fact, "u": Users, "i": Items},
          output_schema=Out,
          joins=[("users", ["user_id"]), ("items", ["item_id"])],
          filter_expr=(col("segment") == 3),
          exprs=[col("user_id"), col("amount"), col("weight")])
    return p


def main():
    sources = build_sources()

    # plan-time statistics feed the cost model (join_reorder) and the
    # auto backend; they are observability metadata, never semantics.
    stats = {name: collect_stats(t._to_cols())
             for name, t in sources.items()}
    pl = plan(build_pipeline(), table_stats=stats)
    opt = optimize(pl)

    print("== EXPLAIN (optimized) ==")
    print(opt.describe())
    print()
    print("== rewritten tree ==")
    print(opt.steps[0].logical.describe())
    print()

    # run both ways; published bytes must be identical — that is the
    # rewrite-pass contract, enforced at scale by the differential
    # suite and the benchmark gate.
    fingerprints = {}
    for label, p in (("unoptimized", pl), ("optimized", opt)):
        client = Client()
        for name, t in sources.items():
            client.write_source_table("main", name, t)
        res = client.run(p, "main")
        out = client.read_table("main", "out")
        fingerprints[label] = out.fingerprint()
        print(f"{label:>12}: {len(out)} rows, executed={res.executed}, "
              f"fingerprint={out.fingerprint()}")

    assert fingerprints["unoptimized"] == fingerprints["optimized"]
    print("\nbit-for-bit: OK")


if __name__ == "__main__":
    main()
