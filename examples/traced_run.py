"""Auditing a rebase-heavy run from its flight-recorder manifest
(DESIGN.md §14).

    PYTHONPATH=src python examples/traced_run.py

The scenario an agent faces after the fact: "my pipeline published,
but main moved under it twice while it ran — what actually happened?"
With tracing on, the answer is no longer re-running with print
statements; the committed manifest IS the answer:

1. a traced transactional run suffers two injected head movements: a
   concurrent writer bumps `main` during verification, so publication
   conflicts, rebases, re-validates, and retries;
2. the published commit anchors a manifest —
   ``Catalog.run_manifest(commit)`` — holding the full span tree:
   publication attempts with outcomes, ref-conflict details
   (expected vs actual head), which nodes re-executed and which hit
   the content-addressed cache, per-node wall times, and every
   backend/auto decision with its reason;
3. the audit walks the tree like an agent would: reconstruct the
   retry story, bill the run's time to phases, and confirm from
   metrics that nothing degraded silently.
"""
import numpy as np

import repro.obs as obs
from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, col

Src = S.Schema.of("Src", x=int)
Mid = S.Schema.of("Mid", x=int, y=int)
Total = S.Schema.of("Total", total=int)


def build_pipeline() -> Pipeline:
    p = Pipeline("nightly_rollup")
    p.source("src", Src)

    for i in range(3):
        def make(mult):
            def mid(df: Src = "src") -> Mid:
                return df.select([col("x"),
                                  (col("x") * mult).alias("y")])
            return mid
        p.node(name=f"mid_{i}")(make(i + 1))

    @p.node()
    def sink(a: Mid = "mid_0", b: Mid = "mid_1", c: Mid = "mid_2") -> Total:
        total = int(a.column("y").sum() + b.column("y").sum()
                    + c.column("y").sum())
        return Table({"total": np.array([total], dtype=np.int64)})

    return p


def main():
    client = Client()
    client.write_source_table(
        "main", "src", Table({"x": np.arange(5, dtype=np.int64)}))
    pl = plan(build_pipeline())

    # -- 1: run traced, with main moving underneath us twice -----------------
    bumps = iter(((10, 20), (30, 40)))

    def hostile_verifier(_table):
        vals = next(bumps, None)        # first two verifications only
        if vals is not None:
            client.write_source_table(
                "main", "src",
                Table({"x": np.array(vals, dtype=np.int64)}))

    with obs.tracing():
        res = client.run(pl, "main",
                         verifiers={"sink": [hostile_verifier]})
    print(f"published {res.state.final_commit[:8]} after "
          f"{res.state.publish_attempts} publication attempts "
          f"(re-executed per rebase: {res.rebase_reexecutions})\n")

    # -- 2: the manifest is anchored to the commit ---------------------------
    man = client.catalog.run_manifest(res.state.final_commit)
    assert man is not None
    spans = man["spans"]
    by_id = {s["span_id"]: s for s in spans}
    print(f"manifest: run {man['run_id']} -> commit "
          f"{man['commit_id'][:8]}, {len(spans)} spans")

    # -- 3: the audit, from the tree alone -----------------------------------
    print("\npublication story:")
    for att in sorted((s for s in spans
                       if s["name"] == "publication_attempt"),
                      key=lambda s: s["attrs"]["attempt"]):
        a = att["attrs"]
        line = f"  attempt {a['attempt']}: {a['outcome']}"
        for ev in att["events"]:
            if ev["name"] == "ref_conflict":
                line += (f"  (expected head {ev['expected_head'][:8]}, "
                         f"found {ev['actual_head'][:8]})")
        print(line)

    print("\nwho re-executed vs who hit the cache, per attempt:")
    for node in (s for s in spans if s["name"] == "node"):
        parent = by_id.get(by_id.get(node["parent_id"], {})
                           .get("parent_id"))
        phase = "initial run"
        if parent is not None and parent["name"] == "reexecute":
            phase = "rebase re-execution"
        a = node["attrs"]
        wall_ms = (node["t1"] - node["t0"]) * 1e3
        print(f"  {a['node']:8} {a['cache']:4} "
              f"rows_out={a['rows_out']:>2} "
              f"{wall_ms:7.2f}ms  [{phase}]")

    print("\nverifier outcomes:")
    for v in (s for s in spans if s["name"] == "verifier"):
        a = v["attrs"]
        print(f"  {a['fn']:20} phase={a['phase']:10} {a['outcome']}")

    print("\nbilled time by phase:")
    for name in ("rebase", "revalidate", "reexecute"):
        total = sum(s["t1"] - s["t0"] for s in spans
                    if s["name"] == name)
        print(f"  {name:10} {total * 1e3:7.2f}ms "
              f"x{sum(1 for s in spans if s['name'] == name)}")

    m = man["metrics"]["counters"]
    print(f"\nmetrics: rebases={m.get('txn.rebases', 0)} "
          f"conflicts={m.get('txn.publication.conflicts', 0)} "
          f"cache misses={m.get('engine.cache.misses', 0)} "
          f"hits={m.get('engine.cache.hits', 0)} "
          f"degradations={m.get('exec.numpy_fallbacks', 0)}")

    # -- and the invariant that makes tracing safe to leave on ---------------
    rerun = client.run(pl, "main")
    print(f"\nuntraced rerun at the same head: executed "
          f"{len(rerun.executed)} nodes, {len(rerun.cached)} cache "
          f"hits — tracing is never key material, so traced and "
          f"untraced runs share cache entries bit-for-bit")


if __name__ == "__main__":
    main()
