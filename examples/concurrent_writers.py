"""Rebase-and-revalidate publication, live (DESIGN.md §7).

    PYTHONPATH=src python examples/concurrent_writers.py

1. six agents run concurrent transactional pipelines against `main`,
   each writing its own table: every publication is a CAS; losers of a
   race rebase onto the new head, re-run their verifiers against the
   rebased state, and retry — all six publish, one commit per run;
2. the stale-verification hazard is shown directly: without CAS a
   moved `main` would be silently three-way merged into a state no
   verifier ever saw (here the verifier re-runs and logs the new base);
3. two agents fight over the SAME table: exactly one wins, the other
   aborts cleanly with its branch preserved for triage.
"""
import threading

from repro.core.catalog import Catalog
from repro.core.errors import TransactionAborted
from repro.core.transactions import RunRegistry, TransactionalRun


def main():
    cat = Catalog()
    reg = RunRegistry()
    cat.write_table("main", "base", "b0")

    # -- 1: six concurrent runs, disjoint tables -----------------------------
    barrier = threading.Barrier(6)

    def agent(i):
        with TransactionalRun(cat, "main", registry=reg,
                              run_id=f"agent{i}",
                              max_publish_attempts=12) as txn:
            txn.write_table(f"metrics_{i}", f"m{i}")
            txn.verify(lambda read: read(f"metrics_{i}"))
            barrier.wait()          # all publish at once

    threads = [threading.Thread(target=agent, args=(i,))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    print("six concurrent runs published; main log (newest first):")
    for c in cat.log("main", limit=7):
        attempts = (reg.get_run(c.run_id).publish_attempts
                    if c.run_id else "-")
        print(f"  {c.id[:8]}  run={c.run_id or '<seed>':8} "
              f"CAS-attempts={attempts}")
    for st in reg.runs():
        assert st.final_commit == st.verified_head, "unverified publish!"
    print("every published commit == the head its verifiers validated\n")

    # -- 2: the verifier observes the rebase ---------------------------------
    seen = []
    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("report", "r1")
    txn.verify(lambda read: seen.append(read("base")))
    cat.write_table("main", "base", "b1")       # main moves under us
    txn.commit()
    print(f"verifier ran against base={seen[0]!r}, then re-ran against "
          f"the rebased base={seen[1]!r} before publishing "
          f"(attempts={txn.publish_attempts})\n")

    # -- 3: same-table race: one winner, one clean abort ---------------------
    b2 = threading.Barrier(2)
    outcome = {}

    def fighter(i):
        txn = TransactionalRun(cat, "main", run_id=f"fight{i}").begin()
        txn.write_table("hot", f"h{i}")
        txn.verify(lambda read: read("hot"))
        b2.wait()
        try:
            txn.commit()
            outcome[i] = "committed"
        except TransactionAborted:
            outcome[i] = f"aborted (branch {txn.branch} kept for triage)"

    ts = [threading.Thread(target=fighter, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for i, o in sorted(outcome.items()):
        print(f"fight{i}: {o}")
    print(f"main hot={cat.read_table('main', 'hot')!r} — exactly one "
          f"winner, no silent combine")


if __name__ == "__main__":
    main()
