"""Incremental, wave-parallel pipeline runs (DESIGN.md §8).

The agentic-lakehouse workflow: many iterations over the same DAG where
only a slice of the inputs moves between runs. The wave engine executes
independent nodes concurrently, and the content-addressed function
cache makes a re-run pay only for the *changed subgraph* — a fully
unchanged re-run executes zero nodes and publishes zero commits.

Run: ``PYTHONPATH=src python examples/incremental_reruns.py``
"""
import numpy as np

from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, col

Events = S.Schema.of("Events", user=str, amount=int)
Refs = S.Schema.of("Refs", user=str, bonus=int)
PerUser = S.Schema.of("PerUser", user=str, _S=int)
Enriched = S.Schema.of("Enriched", user=str, _S=int, bonus=int)


def build() -> Pipeline:
    p = Pipeline("incremental_demo")
    p.source("events", Events)
    p.source("referrals", Refs)

    @p.node()                       # wave 0 — depends on events only
    def per_user(df: Events = "events") -> PerUser:
        return df.group_by_sum(["user"], "amount", out="_S")

    @p.node()                       # wave 0 — depends on referrals only
    def bonuses(df: Refs = "referrals") -> Refs:
        return df.select([col("user"), col("bonus")])

    @p.node()                       # wave 1 — joins both subgraphs
    def enriched(agg: PerUser = "per_user",
                 ref: Refs = "bonuses") -> Enriched:
        return agg.join(ref, on=["user"])

    return p


def report(tag, res):
    print(f"  {tag}: executed={sorted(res.executed) or '[]'} "
          f"cached={sorted(res.cached) or '[]'} "
          f"rebase_reexecutions={list(res.rebase_reexecutions)}")


def main() -> None:
    client = Client()
    client.write_source_table("main", "events", Table({
        "user": np.array(["ann", "ann", "bob"], dtype=object),
        "amount": np.array([10, 5, 7], dtype=np.int64)}))
    client.write_source_table("main", "referrals", Table({
        "user": np.array(["ann", "bob"], dtype=object),
        "bonus": np.array([1, 2], dtype=np.int64)}))

    pl = plan(build())
    print("plan waves:")
    for w, steps in enumerate(pl.waves):
        print(f"  wave {w}: {[s.node.name for s in steps]}")

    print("\nrun 1 — cold: every node executes")
    report("run 1", client.run(pl, "main"))

    print("run 2 — nothing changed: zero executions, zero new commits")
    head = client.catalog.head("main").id
    report("run 2", client.run(pl, "main"))
    assert client.catalog.head("main").id == head

    print("run 3 — only `referrals` moved: events subgraph stays cached")
    client.write_source_table("main", "referrals", Table({
        "user": np.array(["ann", "bob"], dtype=object),
        "bonus": np.array([3, 4], dtype=np.int64)}))
    res = client.run(pl, "main")
    report("run 3", res)
    assert sorted(res.executed) == ["bonuses", "enriched"]

    out = client.read_table("main", "enriched")
    print(f"\nenriched@main: {out.to_pydict()}")


if __name__ == "__main__":
    main()
