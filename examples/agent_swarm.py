"""A 64-agent swarm, audited entirely from run manifests.

    PYTHONPATH=src python examples/agent_swarm.py

64 concurrent agents publish against one catalog while a seeded fault
plan crashes some of them at publication seams, a few abandon their
branches, a few write contract-violating state, and a janitor runs
``Catalog.gc`` against the live-run heartbeat set. Afterwards:

1. the linearizability checker proves the surviving history is clean
   (every published commit verified, atomic, exactly-once);
2. the audit is reconstructed *post hoc* from commit-anchored run
   manifests (DESIGN.md §14) — for every commit on ``main``, who
   published it, in how many CAS attempts, across how many spans —
   without consulting the in-memory records the swarm kept;
3. the GC ledger shows the debris (crashed, abandoned, aborted
   branches) was collected without touching published ancestry.
"""
import repro.obs as obs
from repro.chaos import FaultRule, SwarmConfig, check_swarm, run_swarm

CONFIG = SwarmConfig(
    n_agents=64, runs_per_agent=1, seed="example-64",
    hot_tables=3, p_contended=0.4, p_multi=0.15,
    p_violate=0.08, p_abandon=0.06, p_reuse=0.08,
    gc_every=8, use_store=True,
    fault_rules=(FaultRule("txn.commit.post_merge", "crash", 0.06),
                 FaultRule("txn.commit.pre_merge", "delay", 0.3,
                           delay_s=0.002),
                 FaultRule("store.put", "fail", 0.05)),
    fault_budget=10)


def main():
    with obs.tracing():
        res = run_swarm(CONFIG)

    print(f"swarm: {CONFIG.n_agents} agents, seed {CONFIG.seed!r}")
    print(f"outcomes: {res.outcomes()}")
    print(f"faults injected: {res.plan.faults_injected} "
          f"(budget {CONFIG.fault_budget}): {res.plan.injected}")

    violations = check_swarm(res)
    assert not violations, violations
    print("\nlinearizability: 0 violations — every published commit "
          "verified, atomic, exactly-once\n")

    # -- the audit: walk main and ask each commit who made it ---------------
    cat = res.catalog
    chain = [c for c in reversed(cat.log("main", limit=10_000))
             if c.run_id is not None]
    print(f"audit of {len(chain)} published commits, from manifests only:")
    traced = 0
    for c in chain:
        m = cat.run_manifest(c.id)
        if m is None:
            # lost-ack crashes (and failed audit writes) die between
            # the merge and the manifest anchor — the publication is
            # real, the audit reads back "untraced"
            print(f"  {c.id[:8]}  {c.run_id:<22} (no manifest: died "
                  f"after merge, before the audit anchor)")
            continue
        traced += 1
        root = next(s for s in m["spans"]
                    if s["span_id"] == m["root_span_id"])
        parent = cat.commit(c.parents[0]).tables if c.parents else {}
        delta = sorted(t for t, s in c.tables.items()
                       if parent.get(t) != s)
        print(f"  {c.id[:8]}  {m['run_id']:<22} "
              f"attempts={root['attrs'].get('publish_attempts', '?')} "
              f"spans={len(m['spans'])} wrote={delta}")
        assert m["commit_id"] == c.id and m["run_id"] == c.run_id
    print(f"({traced}/{len(chain)} commits carry manifests)")

    # -- the GC ledger ------------------------------------------------------
    swept = sum(len(r.collected) for r in res.gc_reports)
    print(f"\njanitor passes while agents ran: {len(res.gc_reports)} "
          f"({swept} branches collected mid-swarm)")
    if res.final_gc is not None:
        print(f"final sweep: collected "
              f"{[n for n, _ in res.final_gc.collected]}")
    print(f"branches left: {cat.branches()}")
    print(f"main tables: {len(cat.tables('main'))}")


if __name__ == "__main__":
    main()
