"""End-to-end driver (deliverable b): train a model for a few hundred
steps with transactional checkpoints, kill the worker mid-run, restart,
and verify the resumed run is bitwise-identical to an uninterrupted one.

    PYTHONPATH=src python examples/transactional_training.py [--steps 200]

This is the paper's protocol applied to the training pipeline: the
checkpoint {params, opt_state, data_state, metrics} is one transactional
run — a restart can never observe params from step N with a dataloader
cursor from step N-k.
"""
import argparse

import numpy as np

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.catalog import Catalog
from repro.data.pipeline import DataPipeline, TokenDataset
from repro.data.synthetic import markov_corpus
from repro.distributed.fault_tolerance import (FailureInjector,
                                               resilient_train)
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="xlstm_350m")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    B, S = 8, 64
    tokens = markov_corpus(B * S * 128, cfg.vocab_size, seed=0)

    def pipeline():
        return DataPipeline(TokenDataset(tokens, shard_tokens=B * S * 2),
                            batch=B, seq_len=S, seed=0)

    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    tc = TrainConfig(steps=args.steps, ckpt_every=25, log_every=50)

    # -- run A: uninterrupted ------------------------------------------------
    cat_a = Catalog()
    res_a = train(cfg, pipeline=pipeline(), opt_cfg=opt, tc=tc,
                  ckpt=CheckpointManager(cat_a))
    la = res_a["history"]
    print(f"[A] steps 0..{la[-1]['step']}  "
          f"loss {la[0]['loss']:.3f} -> {la[-1]['loss']:.3f}")

    # -- run B: killed twice, restarted from the committed branch head -------
    cat_b = Catalog()
    ckpt_b = CheckpointManager(cat_b)
    inj = FailureInjector(fail_at=(args.steps // 3, 2 * args.steps // 3))
    res_b = resilient_train(cfg, pipeline_factory=pipeline, opt_cfg=opt,
                            tc=tc, ckpt=ckpt_b, injector=inj)
    lb = res_b["history"]
    print(f"[B] killed at steps {sorted(inj._fired)}; "
          f"final loss {lb[-1]['loss']:.3f}")

    # -- the paper's claim: restart == replay --------------------------------
    drift = abs(la[-1]["loss"] - lb[-1]["loss"])
    print(f"[check] |loss_A - loss_B| = {drift:.2e} "
          f"{'OK (reproducible restart)' if drift < 1e-4 else 'MISMATCH!'}")
    assert drift < 1e-4

    # every PUBLISHED checkpoint commit (where main's head actually
    # moved) carries the complete artifact set — intermediate commits
    # exist only on (merged) txn branches, never as a head of main.
    published = [r for r in ckpt_b.registry.runs()
                 if r.status == "committed"]
    assert published
    for r in published:
        c = cat_b.commit(r.final_commit)
        assert {"params", "opt_state", "data_state",
                "metrics"} <= set(c.tables), "torn checkpoint!"
    print(f"[check] all {len(published)} published checkpoints complete "
          f"(head never observed torn)")


if __name__ == "__main__":
    main()
