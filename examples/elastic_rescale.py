"""Elastic rescaling: lose a pod mid-training, continue on fewer chips.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_rescale.py

Checkpoints are *logical* (unsharded pytrees in the versioned store), so
rescaling is purely a placement decision: restore the branch head, derive
new NamedShardings from the new mesh, `device_put`, continue. The global
batch contract is preserved (the pipeline cursor is part of the commit),
so the loss trajectory continues exactly — the paper's partial-vs-total-
failure upgrade applied to cluster capacity.
"""
import os

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.checkpoints.checkpointing import CheckpointManager  # noqa: E402
from repro.configs import get_smoke_config                    # noqa: E402
from repro.core.catalog import Catalog                        # noqa: E402
from repro.data.pipeline import DataPipeline, TokenDataset    # noqa: E402
from repro.data.synthetic import markov_corpus                # noqa: E402
from repro.distributed.elastic import reshard                 # noqa: E402
from repro.distributed.sharding import make_rules             # noqa: E402
from repro.training.optimizer import AdamWConfig              # noqa: E402
from repro.training.train_loop import TrainConfig, train      # noqa: E402


def mesh_of(n, shape, axes):
    devs = np.array(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def main():
    cfg = get_smoke_config("xlstm_350m")
    B, S = 8, 32
    tokens = markov_corpus(B * S * 64, cfg.vocab_size, seed=0)

    def pipeline():
        return DataPipeline(TokenDataset(tokens, shard_tokens=B * S * 2),
                            batch=B, seq_len=S, seed=0)

    catalog = Catalog()
    ckpt = CheckpointManager(catalog)
    opt = AdamWConfig(lr=3e-3)

    # phase 1: "two pods" — (2,2,2) mesh, 8 chips
    m1 = mesh_of(8, (2, 2, 2), ("pod", "data", "model"))
    print(f"[phase 1] {m1.devices.size} devices {dict(m1.shape)}")
    with m1:
        train(cfg, pipeline=pipeline(), opt_cfg=opt,
              tc=TrainConfig(steps=10, ckpt_every=5), ckpt=ckpt)
    print(f"[phase 1] committed step {ckpt.latest_step()}")

    # phase 2: a pod dies — restore the SAME branch head on (2,2)=4 chips
    m2 = mesh_of(4, (2, 2), ("data", "model"))
    rules = make_rules("train", m2)
    print(f"[phase 2] rescaled to {m2.devices.size} devices "
          f"{dict(m2.shape)} — same checkpoint, new placement")
    import repro.models.model as MDL
    from repro.training.optimizer import adamw_init
    like_p = MDL.init_params(jax.random.PRNGKey(0), cfg)
    like_o = adamw_init(like_p)
    params, opt_state, data_state, _ = ckpt.restore(like_p, like_o)
    params = reshard(params, m2, rules)
    opt_state = jax.tree.unflatten(
        jax.tree.structure(opt_state),
        jax.tree.leaves(reshard(opt_state, m2, rules)))
    with m2:
        res = train(cfg, pipeline=pipeline(), opt_cfg=opt,
                    tc=TrainConfig(steps=20, ckpt_every=5), ckpt=ckpt)
    hist = res["history"]
    assert hist[0]["step"] == 10, "resumed from the committed cursor"
    print(f"[phase 2] steps {hist[0]['step']}..{hist[-1]['step']}  "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print("[check] training continued across the rescale with the "
          "committed data cursor — slow but CORRECT")


if __name__ == "__main__":
    main()
