"""Quality verifiers, object store, tokenizer, fail-fast ordering."""
import numpy as np
import pytest

from repro.core import schema as S
from repro.core.errors import (ContractCompositionError, Moment, PlanError,
                               QualityError)
from repro.core.quality import (all_of, expect_in_range, expect_no_nan,
                                expect_not_null, expect_row_count,
                                expect_unique)
from repro.core.store import MemoryStore, content_hash
from repro.data.tables import Table
from repro.data.tokenizer import ByteTokenizer


def _t(**cols):
    return Table({k: np.asarray(v) for k, v in cols.items()})


# ---------------------------------------------------------------------------
# quality verifiers (paper §3.3 step 3)
# ---------------------------------------------------------------------------

def test_expect_not_null():
    expect_not_null("a")(_t(a=np.array([1, 2])))
    with pytest.raises(QualityError):
        expect_not_null("a")(Table({"a": np.array(["x", None],
                                                  dtype=object)}))


def test_expect_unique():
    expect_unique("a")(_t(a=np.array([1, 2, 3])))
    with pytest.raises(QualityError):
        expect_unique("a")(_t(a=np.array([1, 1])))


def test_expect_in_range():
    expect_in_range("a", 0, 10)(_t(a=np.array([0, 10])))
    with pytest.raises(QualityError):
        expect_in_range("a", 0, 10)(_t(a=np.array([11])))


def test_expect_row_count():
    expect_row_count(1, 2)(_t(a=np.array([1])))
    with pytest.raises(QualityError):
        expect_row_count(5)(_t(a=np.array([1])))


def test_expect_no_nan():
    expect_no_nan("a")(_t(a=np.array([1.0])))
    with pytest.raises(QualityError):
        expect_no_nan("a")(_t(a=np.array([np.nan])))


def test_all_of_short_circuits_with_all_errors():
    v = all_of(expect_row_count(1, 10), expect_unique("a"))
    v(_t(a=np.array([1, 2])))
    with pytest.raises(QualityError):
        v(_t(a=np.array([1, 1])))


# ---------------------------------------------------------------------------
# content-addressed store
# ---------------------------------------------------------------------------

def test_store_content_addressing_and_dedup():
    s = MemoryStore()
    k1 = s.put(b"hello")
    k2 = s.put(b"hello")
    assert k1 == k2 == content_hash(b"hello")
    assert s.get(k1) == b"hello"
    assert k1 in s


def test_store_arrays_roundtrip_dtypes():
    import ml_dtypes
    s = MemoryStore()
    for arr in (np.arange(5, dtype=np.int32),
                np.arange(5, dtype=np.float64),
                np.zeros(3, dtype=ml_dtypes.bfloat16),
                np.array(["a", "bc"], dtype="U2")):
        key = s.put_array(arr)
        back = s.get_array(key)
        assert back.dtype == arr.dtype
        np.testing.assert_array_equal(np.asarray(back, np.float32)
                                      if arr.dtype == ml_dtypes.bfloat16
                                      else back,
                                      np.asarray(arr, np.float32)
                                      if arr.dtype == ml_dtypes.bfloat16
                                      else arr)


def test_pytree_roundtrip():
    from repro.core.store import get_pytree, put_pytree
    s = MemoryStore()
    tree = {"a": np.arange(4.0), "b": [np.ones(2), np.zeros(3)]}
    key = put_pytree(s, tree)
    back = get_pytree(s, key, tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"][1], tree["b"][1])


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------

def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    ids = tok.encode("hello, lakehouse ✓")
    assert ids[0] == tok.bos_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == "hello, lakehouse ✓"


def test_tokenizer_spec_is_versionable():
    tok = ByteTokenizer()
    s = MemoryStore()
    key = s.put_json(tok.spec())
    assert s.get_json(key)["vocab_size"] == 259


# ---------------------------------------------------------------------------
# fail-fast ordering (paper §3: never fail later than you could earlier)
# ---------------------------------------------------------------------------

def test_fail_fast_ordering():
    """A DAG with BOTH a control-plane error (bad composition) and a
    would-be worker error (bad data) must fail at the CONTROL PLANE."""
    from repro.core.dag import Pipeline
    from repro.core.planner import plan

    Raw = S.Schema.of("Raw", a=S.FLOAT)
    Bad = S.Schema.of("Bad", a=S.INT32)   # narrowing, no cast

    p = Pipeline("ff")
    p.source("raw_table", Raw)

    @p.node()
    def out_t(df: Raw = "raw_table") -> Bad:
        raise AssertionError("worker must never run")   # would also fail

    with pytest.raises(ContractCompositionError):
        plan(p)   # rejected before any node executes


def test_moment_enum_ordering():
    assert Moment.AUTHORING < Moment.CONTROL_PLANE < Moment.WORKER
