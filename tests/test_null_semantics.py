"""Regression tests: SQL null/string semantics in the table layer.

Each test here reproduces a confirmed bug (crash or wrong answer) fixed
in the wave-engine PR; kept separate from test_tables.py so they run
even without hypothesis installed.
"""
import numpy as np
import pytest

from repro.core import schema as S
from repro.core.contracts import validate_table
from repro.core.errors import ContractRuntimeError
from repro.data.tables import Table, col, lit


# ---------------------------------------------------------------------------
# String columns: numpy <U*>/<S*> dtypes map to logical `str`
# ---------------------------------------------------------------------------

def test_unicode_list_column_has_logical_dtype_str():
    """Table({"a": ["x","y"]}) used to raise TypeError: unmapped dtype
    <U1 — an infrastructure crash where a contract verdict was due."""
    t = Table({"a": ["x", "y"]})
    assert t.logical_dtype("a") == "str"
    # canonical representation: object dtype, plain str payloads
    assert t.column("a").dtype == object
    assert all(type(v) is str for v in t.column("a"))


def test_bytes_column_normalizes_to_str():
    t = Table({"a": np.array([b"x", b"yz"])})
    assert t.logical_dtype("a") == "str"
    assert t.to_pydict() == {"a": ["x", "yz"]}


def test_lit_string_produces_canonical_str_column():
    """lit("hi") used to produce a fixed-width <U2 column that
    validate_table could not map."""
    t = Table({"a": [1, 2]}).select([lit("hi").alias("b")])
    assert t.column("b").dtype == object
    assert t.logical_dtype("b") == "str"


def test_string_contract_validates_instead_of_crashing():
    """Contract validation over ordinary string data returns a contract
    VERDICT (pass, or ContractRuntimeError), never a TypeError."""
    Str = S.Schema.of("Str", a=str)
    validate_table(Table({"a": ["x", "y"]}), Str)          # passes
    with pytest.raises(ContractRuntimeError, match="physical dtype"):
        validate_table(Table({"a": np.array([1, 2])}), Str)


def test_string_fingerprint_independent_of_construction_path():
    a = Table({"a": ["x", "y"]})
    b = Table({"a": np.array(["x", "y"], dtype=object)})
    c = Table({"a": np.array([b"x", b"y"])})
    assert a.fingerprint() == b.fingerprint() == c.fingerprint()


# ---------------------------------------------------------------------------
# Join: NULL keys match nothing (SQL equality semantics)
# ---------------------------------------------------------------------------

def test_join_null_keys_match_nothing():
    """NULL = NULL is not TRUE: the None rows must not pair up."""
    left = Table({"k": np.array([None, "a"], dtype=object),
                  "l": np.array([1, 2], dtype=np.int64)})
    right = Table({"k": np.array([None, "a"], dtype=object),
                   "r": np.array([10, 20], dtype=np.int64)})
    j = left.join(right, on=["k"])
    assert j.to_pydict() == {"k": ["a"], "l": [2], "r": [20]}


def test_join_null_key_one_side_only():
    left = Table({"k": np.array(["a", None, "b"], dtype=object),
                  "l": np.array([1, 2, 3], dtype=np.int64)})
    right = Table({"k": np.array(["a", "b"], dtype=object),
                   "r": np.array([10, 30], dtype=np.int64)})
    j = left.join(right, on=["k"])
    assert j.to_pydict() == {"k": ["a", "b"], "l": [1, 3], "r": [10, 30]}


def test_join_multi_key_any_null_drops_row():
    left = Table({"k1": np.array(["a", "a"], dtype=object),
                  "k2": np.array([None, "q"], dtype=object),
                  "l": np.array([1, 2], dtype=np.int64)})
    right = Table({"k1": np.array(["a", "a"], dtype=object),
                   "k2": np.array([None, "q"], dtype=object),
                   "r": np.array([10, 20], dtype=np.int64)})
    j = left.join(right, on=["k1", "k2"])
    assert j.to_pydict() == {"k1": ["a"], "k2": ["q"], "l": [2], "r": [20]}


def test_join_respects_validity_masks_after_roundtrip():
    """Nulls encoded via validity masks (e.g. restored from a snapshot)
    are join-NULLs too, not just literal None payloads."""
    from repro.core.store import MemoryStore
    store = MemoryStore()
    left = Table({"k": np.array([None, "a"], dtype=object),
                  "l": np.array([1, 2], dtype=np.int64)})
    left = Table.from_blobs(store, left.to_blobs(store))
    right = Table({"k": np.array(["a"], dtype=object),
                   "r": np.array([20], dtype=np.int64)})
    assert left.join(right, on=["k"]).num_rows == 1


# ---------------------------------------------------------------------------
# group_by_sum: SQL aggregate semantics over nullable columns
# ---------------------------------------------------------------------------

def test_group_by_sum_skips_null_values():
    """Used to crash with `NoneType + int` on nullable value columns."""
    t = Table({"k": np.array(["a", "a", "b"], dtype=object),
               "v": np.array([None, 1, 5], dtype=object)})
    g = t.group_by_sum(["k"], "v", out="s")
    assert g.to_pydict() == {"k": ["a", "b"], "s": [1, 5]}


def test_group_by_sum_all_null_group_sums_to_null():
    t = Table({"k": np.array(["a", "b"], dtype=object),
               "v": np.array([None, 3], dtype=object)})
    g = t.group_by_sum(["k"], "v", out="s")
    assert g.to_pydict() == {"k": ["a", "b"], "s": [None, 3]}
    assert g.has_nulls("s")


def test_group_by_sum_null_keys_form_one_group():
    """Documented choice: GROUP BY puts all NULL keys in ONE group
    (SQL standard), unlike join equality which matches none."""
    t = Table({"k": np.array([None, "a", None], dtype=object),
               "v": np.array([1, 2, 4], dtype=np.int64)})
    g = t.group_by_sum(["k"], "v", out="s")
    assert g.to_pydict() == {"k": [None, "a"], "s": [5, 2]}
    assert g.has_nulls("k")


def test_group_by_sum_masked_numeric_values():
    """Validity-masked numeric columns (not object payloads) skip too."""
    t = Table({"k": np.array([1, 1, 2], dtype=np.int64)})
    from repro.data.tables import _ColumnData
    t._data["v"] = _ColumnData(np.array([7, 8, 9], dtype=np.int64),
                               np.array([True, False, True]))
    g = t.group_by_sum(["k"], "v", out="s")
    assert g.to_pydict() == {"k": [1, 2], "s": [7, 9]}


def test_group_by_sum_no_nulls_unchanged():
    """The Listing-1 happy path is bit-identical to before the fix."""
    t = Table({"col1": np.array(["a", "a", "b"], dtype=object),
               "col3": np.array([1, 2, 3], dtype=np.int64)})
    g = t.group_by_sum(["col1"], "col3", out="_S")
    assert not g.has_nulls("_S") and not g.has_nulls("col1")
    assert g.to_pydict() == {"col1": ["a", "b"], "_S": [3, 3]}


def test_filter_eq_with_normalized_strings():
    t = Table({"name": ["ann", "bob"]})
    assert t.filter(col("name") == lit("ann")).num_rows == 1
