"""Masked segment-sum kernel validation (kernels/segment_sum).

Pallas kernel (interpret=True on this CPU container) and the XLA
``segment_sum`` oracle vs a numpy loop: integer sums must be exact
(associative even under wraparound); float sums compare with
tolerance. Hypothesis-free so it runs on minimal installs; shape
sweeps cover padding on both the row and segment axes.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.segment_sum.kernel import (  # noqa: E402
    masked_segment_sum_kernel)
from repro.kernels.segment_sum.ops import masked_segment_sum  # noqa: E402
from repro.kernels.segment_sum.ref import (  # noqa: E402
    masked_segment_sum_ref)


def _numpy_oracle(vals, ids, valid, num_segments):
    sums = np.zeros(num_segments, dtype=vals.dtype)
    counts = np.zeros(num_segments, dtype=np.int32)
    for v, i, ok in zip(vals, ids, valid):
        if ok:
            sums[i] += v
            counts[i] += 1
    return sums, counts


def _case(n, num_segments, dtype, seed, p_valid=0.7):
    r = np.random.default_rng(seed)
    ids = r.integers(0, num_segments, n).astype(np.int32)
    valid = r.random(n) < p_valid
    if np.issubdtype(dtype, np.integer):
        vals = r.integers(-50, 50, n).astype(dtype)
    else:
        vals = r.normal(size=n).astype(dtype)
    return vals, ids, valid


@pytest.mark.parametrize("n,num_segments", [
    (1000, 37),          # ragged both axes
    (1024, 512),         # exact block multiples
    (5, 3),              # smaller than any block
    (2000, 1),           # single segment
])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_int32_exact(n, num_segments, use_pallas):
    vals, ids, valid = _case(n, num_segments, np.int32, seed=n)
    want_s, want_c = _numpy_oracle(vals, ids, valid, num_segments)
    got_s, got_c = masked_segment_sum(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid),
        num_segments, use_pallas=use_pallas,
        block_n=256, block_s=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_s), want_s)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_float32_tolerance(use_pallas):
    vals, ids, valid = _case(3000, 50, np.float32, seed=1)
    want_s, want_c = _numpy_oracle(vals.astype(np.float64), ids, valid,
                                   50)
    got_s, got_c = masked_segment_sum(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 50,
        use_pallas=use_pallas, block_n=512, block_s=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got_s), want_s,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


def test_all_invalid_lanes_give_zero_sums_and_counts():
    vals, ids, _ = _case(500, 11, np.int32, seed=2)
    valid = np.zeros(500, dtype=bool)
    s, c = masked_segment_sum(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 11,
        use_pallas=True, block_n=128, block_s=8, interpret=True)
    assert np.asarray(s).sum() == 0 and np.asarray(c).sum() == 0


def test_empty_input():
    s, c = masked_segment_sum(
        jnp.asarray(np.array([], np.float32)),
        jnp.asarray(np.array([], np.int32)),
        jnp.asarray(np.array([], bool)), 5, use_pallas=True)
    assert np.asarray(s).shape == (5,)
    assert np.asarray(c).sum() == 0


def test_kernel_block_shape_invariance():
    """Tiling is a perf knob: output must not depend on block sizes."""
    vals, ids, valid = _case(777, 23, np.int32, seed=3)
    outs = []
    for block_n, block_s in ((64, 8), (256, 16), (1024, 512)):
        s, c = masked_segment_sum_kernel(
            jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 23,
            block_n=block_n, block_s=block_s, interpret=True)
        outs.append((np.asarray(s), np.asarray(c)))
    for s, c in outs[1:]:
        np.testing.assert_array_equal(s, outs[0][0])
        np.testing.assert_array_equal(c, outs[0][1])


def test_kernel_matches_xla_ref():
    vals, ids, valid = _case(2048, 96, np.int32, seed=4)
    a = masked_segment_sum_ref(jnp.asarray(vals), jnp.asarray(ids),
                               jnp.asarray(valid), 96)
    b = masked_segment_sum_kernel(jnp.asarray(vals), jnp.asarray(ids),
                                  jnp.asarray(valid), 96,
                                  block_n=512, block_s=32,
                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_jax_backend_pallas_mode_matches_reference():
    """The jax backend with the Pallas kernel enabled still satisfies
    the backend semantics contract (int32 -> bit-exact)."""
    from repro.data.tables import Table
    from repro.exec.jax_backend import JaxBackend

    r = np.random.default_rng(5)
    t = Table({"k": r.integers(0, 40, 3000).astype(np.int64),
               "v": r.integers(-1000, 1000, 3000).astype(np.int32)})
    be = JaxBackend(use_pallas=True, interpret=True)
    got = t.group_by_sum(["k"], "v", out="s", backend=be)
    want = t.group_by_sum(["k"], "v", out="s", backend="reference")
    assert got.fingerprint() == want.fingerprint()
