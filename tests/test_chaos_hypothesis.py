"""Hypothesis-driven chaos search (DESIGN.md §15).

Two hunts, both shrinking to minimal counterexamples on failure:

1. **Adversarial schedules**: hypothesis draws whole swarm
   configurations — agent counts, behavior mixes, fault rules, seeds —
   and asserts the linearizability checker finds nothing. A failure
   shrinks toward the smallest swarm + fault mix that breaks an
   invariant, and the printed seed replays it deterministically.
2. **Quarantine release under concurrent reuse**: a stateful machine
   interleaving writes, failed/successful re-verifications, and merge
   attempts on a quarantined branch, checking the Fig. 4 guardrail at
   every step: nothing merges while unverified, and released state was
   always exactly what a verifier saw.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property search needs hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.chaos import (FaultRule, SwarmConfig, check_swarm, run_swarm)
from repro.core.catalog import Catalog, Visibility
from repro.core.errors import RefConflict, ReproError, VisibilityError

POINTS = st.sampled_from(["txn.begin.post_branch", "txn.commit.pre_merge",
                          "txn.commit.post_merge", "txn.commit.pre_rebase",
                          "txn.commit.post_rebase", "store.put"])

fault_rules = st.lists(
    st.builds(FaultRule,
              match=POINTS,
              kind=st.sampled_from(["fail", "crash", "delay"]),
              rate=st.floats(0.0, 0.4),
              delay_s=st.just(0.001)),
    max_size=4).map(tuple)

configs = st.builds(
    SwarmConfig,
    n_agents=st.integers(2, 6),
    runs_per_agent=st.integers(1, 3),
    seed=st.integers(0, 2**32),
    hot_tables=st.integers(1, 2),
    p_contended=st.floats(0.0, 0.8),
    p_multi=st.floats(0.0, 0.4),
    p_violate=st.floats(0.0, 0.3),
    p_abandon=st.floats(0.0, 0.3),
    p_reuse=st.floats(0.0, 0.3),
    gc_every=st.integers(0, 4),
    use_store=st.booleans(),
    fault_rules=fault_rules,
    fault_budget=st.one_of(st.none(), st.integers(0, 10)))


@given(cfg=configs)
@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_searched_schedules_stay_linearizable(cfg):
    res = run_swarm(cfg)
    violations = check_swarm(res)
    assert not violations, (
        f"seed {cfg.seed!r}: {violations}\ninjected={res.plan.injected}")


# ---------------------------------------------------------------------------
# quarantine release state machine (concurrent-reuse vocabulary,
# explored sequentially — the true race is tests/test_catalog_gc.py)
# ---------------------------------------------------------------------------

class QuarantineRelease(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.cat = Catalog()
        self.cat.create_branch("txn/bad", "main",
                               visibility=Visibility.TXN, owner_run="bad")
        self.cat.write_table("txn/bad", "P", "P@bad", _system=True)
        self.cat.mark("txn/bad", Visibility.ABORTED, _system=True)
        self.cat.create_branch("q", "txn/bad", allow_reuse=True)
        self.writes = 0
        self.verified_heads: list[str] = []   # what releases validated

    def _info(self):
        return self.cat.branch_info("q")

    @precondition(lambda self: self._info().visibility
                  is Visibility.QUARANTINED)
    @rule()
    def write(self):
        self.writes += 1
        self.cat.write_table("q", "C", f"C@v{self.writes}")

    @precondition(lambda self: self._info().visibility
                  is Visibility.QUARANTINED)
    @rule(interleaved=st.booleans())
    def release(self, interleaved):
        """Re-verify; optionally a reuse write lands mid-verification
        (the concurrent-reuse race, serialized). The release must
        succeed iff nothing moved."""
        def verifier(read):
            if interleaved:
                self.writes += 1
                self.cat.write_table("q", "C", f"C@v{self.writes}")
        if interleaved:
            with pytest.raises(RefConflict):
                self.cat.release_quarantined("q", verifier)
        else:
            head = self.cat.release_quarantined("q", verifier)
            self.verified_heads.append(head.id)

    @precondition(lambda self: self._info().visibility
                  is Visibility.QUARANTINED)
    @rule()
    def failed_release(self):
        def verifier(read):
            raise ValueError("still broken")
        with pytest.raises(ValueError):
            self.cat.release_quarantined("q", verifier)

    @rule()
    def try_merge(self):
        info = self._info()
        gated = (info.visibility is Visibility.QUARANTINED
                 and not info.verified)
        try:
            self.cat.merge("q", into="main")
            assert not gated, "UNVERIFIED quarantined branch merged"
        except (VisibilityError, ReproError):
            assert gated or True   # refusals/conflicts always legal

    @invariant()
    def released_means_verified_exact_head(self):
        info = self._info()
        if info.visibility is Visibility.USER:
            # released: the CURRENT head must be one a verifier saw
            # (writes after release re-enter user domain, tracked by
            # updated head) — at minimum the release head is recorded
            assert self.verified_heads, "released without verification"

    @invariant()
    def main_has_no_unreleased_quarantine_state(self):
        tables = self.cat.tables("main")
        if not self.verified_heads:
            assert "P" not in tables and "C" not in tables, (
                "quarantined state reached main without any release")


QuarantineRelease.TestCase.settings = settings(
    max_examples=50, stateful_step_count=30, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])

TestQuarantineRelease = QuarantineRelease.TestCase
