"""Threaded stress harness: concurrent transactional runs against `main`.

The §3.3 protocol under real concurrency (DESIGN.md §7). Invariants
asserted over every interleaving the scheduler produces:

- every run either publishes atomically or aborts cleanly (branch
  preserved as ABORTED) — never a torn or silently-combined state;
- **linearizable history**: every published commit is a fast-forward of
  a transactional-branch head that the run's FULL verifier set
  validated, asserted by recording the head each verifier observed
  (``RunState.verified_head`` / ``TransactionalRun.verifier_heads``);
- :meth:`Catalog.write_tables` yields exactly ONE commit on main per
  successful run — ``log()`` reflects runs, not nodes.
"""
import threading

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.catalog import Catalog, Visibility
from repro.core.dag import Pipeline
from repro.core.errors import PublicationConflict, TransactionAborted
from repro.core.planner import plan
from repro.core.quality import expect_not_null, expect_row_count
from repro.core.runner import Client
from repro.core.transactions import TransactionalRun
from repro.data.tables import Table, col

K = 8  # concurrent runs

Src = S.Schema.of("Src", k=str, v=int)
Out = S.Schema.of("Out", k=str, v=int)


def _source_table() -> Table:
    return Table({"k": np.array(["a", "b", "c"], dtype=object),
                  "v": np.arange(3, dtype=np.int64)})


def _pipeline(i: int) -> Pipeline:
    p = Pipeline(f"worker{i}")
    p.source("src_table", Src)

    @p.node(name=f"out_{i}")
    def out_node(df: Src = "src_table") -> Out:
        return df.select([col("k"), col("v")])

    return p


def _spawn(n, fn):
    threads = [threading.Thread(target=fn, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()


# ---------------------------------------------------------------------------
# Disjoint outputs: all K runs MUST publish, rebasing past each other
# ---------------------------------------------------------------------------

def test_eight_concurrent_client_runs_all_publish():
    client = Client()
    client.write_source_table("main", "src_table", _source_table())
    base_log = len(client.catalog.log("main", limit=1000))
    plans = [plan(_pipeline(i)) for i in range(K)]
    barrier = threading.Barrier(K)
    results, errors = {}, {}

    def worker(i):
        barrier.wait()          # maximal contention: all begin together
        try:
            results[i] = client.run(
                plans[i], "main",
                verifiers={f"out_{i}": [expect_row_count(1, 10),
                                        expect_not_null("k")]},
                # each RefConflict implies another run published since we
                # last rebased, so K+2 attempts can never be exhausted
                max_publish_attempts=K + 2)
        except TransactionAborted as e:   # pragma: no cover - must not
            errors[i] = e

    _spawn(K, worker)
    assert not errors, f"disjoint runs aborted: {errors}"

    # all outputs are visible on main
    tables = client.catalog.tables("main")
    assert all(f"out_{i}" in tables for i in range(K))

    # linearizable: the commit each run published IS the branch head its
    # verifiers validated (fast-forward of fully-verified state)
    for res in results.values():
        st = res.state
        assert st.status == "committed"
        assert st.verified_head is not None
        assert st.final_commit == st.verified_head

    # exactly ONE commit on main per successful run
    log = client.catalog.log("main", limit=1000)
    assert len(log) == base_log + K
    assert ({c.run_id for c in log[:K]}
            == {res.state.run_id for res in results.values()})

    # no transactional branches leak
    assert client.catalog.branches() == ["main"]


# ---------------------------------------------------------------------------
# Same table: exactly one run wins; the rest abort cleanly
# ---------------------------------------------------------------------------

def test_concurrent_same_table_runs_serialize():
    cat = Catalog()
    cat.write_table("main", "T", "t0")
    barrier = threading.Barrier(K)
    outcomes = {}

    def worker(i):
        txn = TransactionalRun(cat, "main",
                               max_publish_attempts=K + 2).begin()
        txn.write_table("T", f"t-run{i}")
        txn.verify(lambda read: read("T"))
        barrier.wait()          # everyone wrote before anyone publishes
        try:
            merged = txn.commit()
            outcomes[i] = ("committed", merged.id, txn)
        except TransactionAborted:
            outcomes[i] = ("aborted", None, txn)

    _spawn(K, worker)
    committed = {i: v for i, v in outcomes.items() if v[0] == "committed"}
    aborted = {i: v for i, v in outcomes.items() if v[0] == "aborted"}
    # all K began from the same base and changed the same table: exactly
    # one can linearize; every other rebase must conflict and abort
    assert len(committed) == 1
    assert len(aborted) == K - 1

    (winner, (_, cid, wtxn)), = committed.items()
    assert cat.read_table("main", "T") == f"t-run{winner}"
    assert cat.head("main").id == cid
    # the winner's published head is exactly what its verifier validated
    assert set(wtxn.verifier_heads) == {cid}

    # losers' branches are preserved for triage, never mergeable
    for i, (_, _, txn) in aborted.items():
        info = cat.branch_info(txn.branch)
        assert info.visibility is Visibility.ABORTED
        assert cat.read_table(txn.branch, "T") == f"t-run{i}"


# ---------------------------------------------------------------------------
# Mixed workload, repeated rounds: determinism across interleavings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("round_", range(3))
def test_mixed_contention_rounds(round_):
    """Half the runs write private tables (must publish), half fight
    over one shared table (exactly one winner per round)."""
    cat = Catalog()
    cat.write_table("main", "shared", "s0")
    barrier = threading.Barrier(K)
    outcomes = {}

    def worker(i):
        txn = TransactionalRun(cat, "main",
                               max_publish_attempts=2 * K).begin()
        if i % 2 == 0:
            txn.write_table(f"private_{i}", f"p{i}")
        else:
            txn.write_table("shared", f"s-run{i}")
        txn.verify(lambda read: None)
        barrier.wait()
        try:
            outcomes[i] = ("committed", txn.commit().id, txn)
        except TransactionAborted:
            outcomes[i] = ("aborted", None, txn)

    _spawn(K, worker)
    disjoint = [i for i in range(0, K, 2)]
    fighting = [i for i in range(1, K, 2)]
    assert all(outcomes[i][0] == "committed" for i in disjoint)
    winners = [i for i in fighting if outcomes[i][0] == "committed"]
    assert len(winners) == 1
    assert cat.read_table("main", "shared") == f"s-run{winners[0]}"
    for i in disjoint:
        assert cat.read_table("main", f"private_{i}") == f"p{i}"
    # every published commit was verified against its actual parent:
    # published head == the head recorded at the last verifier pass
    for i, (status, cid, txn) in outcomes.items():
        if status == "committed":
            heads = set(txn.verifier_heads)
            assert heads == {cid}


# ---------------------------------------------------------------------------
# Retry-budget exhaustion surfaces as PublicationConflict
# ---------------------------------------------------------------------------

def test_publication_conflict_is_transaction_aborted():
    """PublicationConflict is catchable as TransactionAborted, so
    existing abort handling (and the stress workers above) covers it."""
    assert issubclass(PublicationConflict, TransactionAborted)
