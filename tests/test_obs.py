"""Flight recorder (DESIGN.md §14): tracing, manifests, invariants.

Covers the two test-gated invariants — cache-key non-interference
(tracing on/off/different-sink shares cache entries bit for bit) and
the near-zero-cost disabled path (shared no-op singletons; the wall-
clock gate lives in ``benchmarks/tracing_overhead.py``) — plus the
end-to-end audit story: a committed run that suffered an injected
rebase yields ``Catalog.run_manifest(commit_id)`` with the full span
tree, recorder thread-safety under the 8-thread concurrent-run
harness, manifest round-trip through ``FileStore``, structured
degradation events, and the EXPLAIN ANALYZE format.
"""
import json
import threading
import warnings

import numpy as np
import pytest

import repro.obs as obs
from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.engine import cache_key
from repro.core.errors import PlanError
from repro.core.planner import plan
from repro.core.runner import Client
from repro.core.store import FileStore, MemoryStore
from repro.data.tables import Table, col
from repro.kernels import fallback
from repro.obs.trace import _NULL_SPAN

Src = S.Schema.of("Src", x=int)
Mid = S.Schema.of("Mid", x=int, y=int)
Total = S.Schema.of("Total", total=int)


def _source(vals=(1, 2, 3)) -> Table:
    return Table({"x": np.array(vals, dtype=np.int64)})


def _add_mid(p, i, mult):
    @p.node(name=f"mid_{i}")
    def mid(df: Src = "src") -> Mid:
        return df.select([col("x"), (col("x") * mult).alias("y")])


def _diamond() -> Pipeline:
    p = Pipeline("diamond")
    p.source("src", Src)
    for i in range(3):
        _add_mid(p, i, i + 1)

    @p.node()
    def sink(a: Mid = "mid_0", b: Mid = "mid_1", c: Mid = "mid_2") -> Total:
        total = int(a.column("y").sum() + b.column("y").sum()
                    + c.column("y").sum())
        return Table({"total": np.array([total], dtype=np.int64)})

    return p


def _client(store=None) -> Client:
    from repro.core.catalog import Catalog
    c = Client(Catalog(store=store))
    c.write_source_table("main", "src", _source())
    return c


# ---------------------------------------------------------------------------
# recorder core
# ---------------------------------------------------------------------------

def test_span_tree_nesting_and_events():
    with obs.tracing() as rec:
        with rec.span("outer", a=1) as outer:
            rec.event("point", detail="x")
            with rec.span("inner") as inner:
                inner.set(b=2)
    assert outer.parent_id is None
    assert inner.parent_id == outer.span_id
    assert outer.events == [pytest.approx(outer.events[0])]
    assert outer.events[0]["name"] == "point"
    assert inner.attrs == {"b": 2}
    sub = rec.subtree(outer)
    assert [s.name for s in sub] == ["outer", "inner"]
    assert all(s.t1 is not None for s in sub)


def test_tracing_restores_previous_recorder():
    before = obs.get_recorder()
    with obs.tracing() as rec:
        assert obs.get_recorder() is rec
        assert rec.enabled
    assert obs.get_recorder() is before
    assert not obs.get_recorder().enabled


def test_null_recorder_is_free_singletons():
    rec = obs.NullRecorder()
    assert rec.span("anything", k=1) is _NULL_SPAN
    assert rec.start_span("x") is _NULL_SPAN
    # shared no-op span: enter/exit/set all return without allocating
    with rec.span("a") as sp:
        assert sp.set(whatever=1) is sp
    rec.event("ignored", k=2)
    rec.end_span(_NULL_SPAN)
    c = rec.metrics.counter("n")
    c.inc()
    assert c.value == 0            # null metrics drop updates
    h = rec.metrics.histogram("h")
    h.observe(3.0)
    assert h.count == 0


def test_metrics_registry_aggregates():
    m = obs.MetricsRegistry()
    m.counter("hits").inc()
    m.counter("hits").inc(2)
    m.histogram("lat").observe(1.0)
    m.histogram("lat").observe(3.0)
    snap = m.snapshot()
    assert snap["counters"]["hits"] == 3
    assert snap["histograms"]["lat"] == {
        "count": 2, "sum": 4.0, "min": 1.0, "max": 3.0, "mean": 2.0}


def test_orphan_events_recorded_without_open_span():
    with obs.tracing() as rec:
        rec.event("loose", why="no span open")
    assert rec.orphan_events()[0]["name"] == "loose"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_export_format(tmp_path):
    with obs.tracing() as rec:
        with rec.span("outer"):
            rec.event("mark", n=1)
            with rec.span("inner", rows=5):
                pass
    doc = obs.to_chrome_trace(rec.spans())
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    instants = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert {e["name"] for e in slices} == {"outer", "inner"}
    assert instants[0]["name"] == "mark" and instants[0]["args"] == {"n": 1}
    inner = next(e for e in slices if e["name"] == "inner")
    assert inner["args"] == {"rows": 5}
    assert inner["dur"] >= 0 and isinstance(inner["ts"], float)
    # ts strictly sorted, microseconds
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)
    # file round-trip is plain JSON (perfetto-loadable)
    path = tmp_path / "trace.json"
    obs.write_chrome_trace(path, rec.spans())
    assert json.loads(path.read_text())["traceEvents"]


def test_json_export_is_deterministic():
    with obs.tracing() as rec:
        with rec.span("a", z=1, a=2):
            pass
    out = obs.to_json(rec.spans())
    assert json.loads(out)["spans"][0]["attrs"] == {"z": 1, "a": 2}


# ---------------------------------------------------------------------------
# end-to-end audit: committed run with an injected rebase
# ---------------------------------------------------------------------------

def _run_with_concurrent_write(client, pl, write_fn):
    fired = []

    def bump_main(_table):
        if not fired:
            fired.append(True)
            write_fn()

    return client.run(pl, "main", verifiers={"sink": [bump_main]})


def test_rebase_heavy_run_manifest_full_audit():
    """The ISSUE acceptance criterion: a committed run that suffered an
    injected rebase yields a manifest holding publication attempts, the
    re-executed node set, per-node cache verdicts, and the rebase's
    conflict details."""
    client = _client()
    pl = plan(_diamond())
    with obs.tracing() as rec:
        res = _run_with_concurrent_write(
            client, pl,
            lambda: client.write_source_table("main", "src",
                                              _source((10,))))
    assert res.state.status == "committed"
    assert res.state.publish_attempts == 2
    assert res.rebase_reexecutions == (4,)     # full re-derivation

    man = client.catalog.run_manifest(res.state.final_commit)
    assert man is not None
    assert man["format"] == obs.MANIFEST_FORMAT
    assert man["commit_id"] == res.state.final_commit
    assert man["run_id"] == res.state.run_id

    by_name = {}
    for s in man["spans"]:
        by_name.setdefault(s["name"], []).append(s)

    # run root, sealed committed
    (run,) = by_name["run"]
    assert run["span_id"] == man["root_span_id"]
    assert run["attrs"]["status"] == "committed"
    assert run["attrs"]["commit"] == res.state.final_commit
    assert run["attrs"]["publish_attempts"] == 2

    # two publication attempts: conflict then published
    atts = sorted(by_name["publication_attempt"],
                  key=lambda s: s["attrs"]["attempt"])
    assert [a["attrs"]["outcome"] for a in atts] == ["conflict",
                                                    "published"]
    # the conflict attempt carries the ref_conflict event with heads
    ev = [e for e in atts[0]["events"] if e["name"] == "ref_conflict"]
    assert ev and ev[0]["expected_head"] != ev[0]["actual_head"]

    # rebase + revalidate + re-execution + verifier re-run all traced
    assert by_name["rebase"][0]["attrs"]["onto"] == \
        ev[0]["actual_head"]
    assert by_name["revalidate"][0]["attrs"]["reexecute"] is True
    assert by_name["reexecute"]
    phases = {v["attrs"]["phase"] for v in by_name["verifier"]}
    assert phases == {"initial", "revalidate"}
    assert all(v["attrs"]["outcome"] == "passed"
               for v in by_name["verifier"])

    # per-node cache verdicts: 4 misses on the first pass, 4 misses on
    # re-execution (the source moved), all four nodes named
    nodes = by_name["node"]
    assert {n["attrs"]["node"] for n in nodes} == {
        "mid_0", "mid_1", "mid_2", "sink"}
    assert all(n["attrs"]["cache"] in ("hit", "miss") for n in nodes)
    assert all("cache_key" in n["attrs"] for n in nodes)
    reexecuted = [n["attrs"]["node"] for n in nodes
                  if n["attrs"]["cache"] == "miss"]
    assert len(nodes) == 8 and len(reexecuted) == 8

    # metrics aggregated into the manifest
    assert man["metrics"]["counters"]["txn.rebases"] == 1
    assert man["metrics"]["counters"]["txn.publication.conflicts"] == 1
    assert man["metrics"]["counters"]["engine.cache.misses"] == 8


def test_untraced_run_leaves_no_manifest_and_aborted_run_none():
    client = _client()
    pl = plan(_diamond())
    res = client.run(pl, "main")
    assert client.catalog.run_manifest(res.state.final_commit) is None

    # aborted traced run: no commit, so nothing to anchor — but the
    # recorder still holds the sealed run span for live inspection
    from repro.core.errors import TransactionAborted
    client2 = _client()
    with obs.tracing() as rec:
        with pytest.raises(TransactionAborted):
            client2.run(plan(_diamond()), "main", fail_after="mid_1")
    (run,) = rec.spans("run")
    assert run.attrs["status"] == "aborted"
    assert run.t1 is not None


def test_run_manifest_accepts_branch_refs():
    client = _client()
    with obs.tracing():
        client.run(plan(_diamond()), "main")
    assert client.catalog.run_manifest("main") is not None


# ---------------------------------------------------------------------------
# invariant 1: cache-key non-interference (tracing is never key material)
# ---------------------------------------------------------------------------

def test_cache_key_identical_tracing_on_off_and_different_sinks():
    pl = plan(_diamond())
    step = pl.steps[0]
    snaps = {"df": "snap0"}
    baseline = cache_key(step, snaps)
    with obs.tracing():
        assert cache_key(step, snaps) == baseline
    with obs.tracing() as rec:
        # a recorder with totally different contents
        with rec.span("noise", blob="x" * 100):
            assert cache_key(step, snaps) == baseline
    assert cache_key(step, snaps) == baseline


def test_cached_rerun_sweep_traced_untraced_different_sink():
    """The ISSUE sweep: populate the cache under tracing, then rerun
    with tracing off AND with a different sink — every rerun must
    execute 0 nodes and publish identical fingerprints."""
    store = MemoryStore()
    client = _client(store)
    pl = plan(_diamond())
    with obs.tracing():
        first = client.run(pl, "main")
    assert len(first.executed) == 4
    fp = {t: client.read_table("main", t).fingerprint()
          for t in ("mid_0", "mid_1", "mid_2", "sink")}

    # rerun untraced: all four nodes cache-hit
    second = client.run(pl, "main")
    assert second.executed == () and len(second.cached) == 4

    # rerun under a DIFFERENT recorder: still all hits
    with obs.tracing():
        third = client.run(pl, "main")
    assert third.executed == () and len(third.cached) == 4

    # fingerprints bit-for-bit stable across the sweep
    for t, want in fp.items():
        assert client.read_table("main", t).fingerprint() == want

    # and the traced run's manifest recorded the hits
    man = client.catalog.run_manifest(third.state.final_commit)
    # fully-cached rerun writes nothing new -> same head as before; a
    # manifest exists iff the traced run actually published a commit
    if man is not None:
        nodes = [s for s in man["spans"] if s["name"] == "node"]
        assert all(n["attrs"]["cache"] == "hit" for n in nodes)


def test_traced_and_untraced_runs_share_cache_entries():
    """Populate untraced, hit traced — and vice versa — against one
    shared store: the key must not depend on the recorder either way."""
    store = MemoryStore()
    client = _client(store)
    pl = plan(_diamond())
    client.run(pl, "main")                 # populate untraced
    with obs.tracing() as rec:
        res = client.run(pl, "main")       # consume traced
    assert res.executed == ()
    nodes = rec.spans("node")
    assert nodes and all(s.attrs["cache"] == "hit" for s in nodes)


# ---------------------------------------------------------------------------
# invariant 2: disabled path is no-op objects (cost gate in benchmarks/)
# ---------------------------------------------------------------------------

def test_disabled_path_returns_shared_noop_span():
    rec = obs.get_recorder()
    assert isinstance(rec, obs.NullRecorder)
    assert rec.span("a", x=1) is rec.span("b") is _NULL_SPAN


# ---------------------------------------------------------------------------
# thread safety: the 8-thread concurrent-run harness, traced
# ---------------------------------------------------------------------------

def test_eight_concurrent_traced_runs_separate_manifests():
    K = 8
    CSrc = S.Schema.of("CSrc", k=str, v=int)
    COut = S.Schema.of("COut", k=str, v=int)
    client = Client()
    client.write_source_table(
        "main", "src_table",
        Table({"k": np.array(["a", "b", "c"], dtype=object),
               "v": np.arange(3, dtype=np.int64)}))

    def _pipeline(i):
        p = Pipeline(f"worker{i}")
        p.source("src_table", CSrc)

        @p.node(name=f"out_{i}")
        def out_node(df: CSrc = "src_table") -> COut:
            return df.select([col("k"), col("v")])

        return p

    plans = [plan(_pipeline(i)) for i in range(K)]
    barrier = threading.Barrier(K)
    results, errors = {}, {}

    def worker(i):
        barrier.wait()
        try:
            results[i] = client.run(plans[i], "main",
                                    max_publish_attempts=K + 2)
        except Exception as e:  # pragma: no cover - must not happen
            errors[i] = e

    with obs.tracing() as rec:
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(K)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors

    # one recorder took all K runs concurrently: every run span sealed,
    # and each commit's manifest holds exactly its own run's spans
    assert len(rec.spans("run")) == K
    seen_runs = set()
    for res in results.values():
        man = client.catalog.run_manifest(res.state.final_commit)
        assert man is not None
        assert man["run_id"] == res.state.run_id
        seen_runs.add(man["run_id"])
        roots = [s for s in man["spans"] if s["parent_id"] is None]
        assert [s["span_id"] for s in roots] == [man["root_span_id"]]
        # this run's node span, and no other run's
        node_names = {s["attrs"]["node"] for s in man["spans"]
                      if s["name"] == "node"}
        assert node_names == {res.tables and next(iter(res.tables))}
    assert len(seen_runs) == K

    # spans are internally consistent under concurrency: unique ids,
    # every parent id resolves, t1 >= t0
    spans = rec.spans()
    ids = [s.span_id for s in spans]
    assert len(ids) == len(set(ids))
    id_set = set(ids)
    for s in spans:
        assert s.parent_id is None or s.parent_id in id_set
        assert s.t1 is not None and s.t1 >= s.t0


# ---------------------------------------------------------------------------
# manifest round-trip through FileStore
# ---------------------------------------------------------------------------

def test_manifest_round_trip_file_store(tmp_path):
    store = FileStore(tmp_path / "lake")
    client = _client(store)
    pl = plan(_diamond())
    with obs.tracing():
        res = client.run(pl, "main")
    cid = res.state.final_commit

    # a FRESH store over the same directory reads the manifest back
    store2 = FileStore(tmp_path / "lake")
    man = obs.load_manifest(store2, cid)
    assert man is not None
    assert man["commit_id"] == cid
    assert {s["name"] for s in man["spans"]} >= {"run", "wave", "node",
                                                 "publication_attempt"}
    # manifest content is content-addressed: the anchored ref names the
    # same blob both stores see
    key = store2.get_ref(obs.MANIFEST_REF_PREFIX + cid)
    assert key is not None and store2.get_json(key) == man


# ---------------------------------------------------------------------------
# satellite: structured degradation events
# ---------------------------------------------------------------------------

def test_numpy_fallback_records_event_every_time_warns_once():
    fallback.reset_fallback_warnings()
    try:
        with obs.tracing() as rec:
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                fallback.warn_numpy_fallback("test.op", np.dtype(np.int64))
                fallback.warn_numpy_fallback("test.op", np.dtype(np.int64))
        # warning stays one-shot for interactive use...
        assert len(w) == 1
        assert issubclass(w[0].category, fallback.NumpyFallbackWarning)
        # ...but the manifest-bound event log records EVERY degradation
        evs = [e for e in rec.orphan_events()
               if e["name"] == "degradation"]
        assert len(evs) == 2
        assert evs[0]["kind"] == "numpy_fallback"
        assert evs[0]["op"] == "test.op"
        assert evs[0]["dtype"] == np.dtype(np.int64).str
        assert "x64" in evs[0]["reason"]
        assert rec.metrics.snapshot()["counters"][
            "exec.numpy_fallbacks"] == 2
    finally:
        fallback.reset_fallback_warnings()


def test_degradation_event_lands_inside_open_span():
    fallback.reset_fallback_warnings()
    try:
        with obs.tracing() as rec:
            with rec.span("node", node="n1") as sp:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore")
                    fallback.warn_numpy_fallback("op2",
                                                 np.dtype(np.float64))
        assert any(e["name"] == "degradation" for e in sp.events)
    finally:
        fallback.reset_fallback_warnings()


def test_sharded_downgrade_event_over_255_devices():
    jax = pytest.importorskip("jax")
    from repro.exec.sharded import ShardedBackend
    be = ShardedBackend(n_devices=300)      # uint8 bucket space is 255
    left = {"k": (np.array([1, 2], dtype=np.int64), None),
            "v": (np.array([10, 20], dtype=np.int64), None)}
    right = {"k": (np.array([2, 3], dtype=np.int64), None),
             "w": (np.array([7, 8], dtype=np.int64), None)}
    with obs.tracing() as rec:
        out = be.hash_join(left, right, ["k"])
    evs = [e for e in rec.orphan_events() if e["name"] == "degradation"]
    assert evs and evs[0]["kind"] == "sharded_downgrade"
    assert "255" in evs[0]["reason"]
    assert out["k"][0].tolist() == [2]      # correctness preserved


# ---------------------------------------------------------------------------
# satellite: auto decision events with reasons
# ---------------------------------------------------------------------------

def test_auto_decision_event_names_table_row():
    from repro.exec.auto import AutoBackend, TINY_ROWS
    be = AutoBackend()
    n = TINY_ROWS  # <= tiny on both sides combined? use tiny total
    left = {"k": (np.arange(4, dtype=np.int64), None)}
    right = {"k": (np.arange(4, dtype=np.int64), None)}
    with obs.tracing() as rec:
        be.hash_join(left, right, ["k"])
    evs = [e for e in rec.orphan_events() if e["name"] == "auto_decision"]
    assert evs and evs[0]["op"] == "hash_join"
    assert evs[0]["choice"] == "reference"
    assert "tiny threshold" in evs[0]["reason"]
    assert rec.metrics.snapshot()["counters"][
        "auto.hash_join.reference"] == 1


def test_explain_variants_agree_with_choose():
    from repro.exec import auto
    from repro.exec.stats import TableStats
    cases = [
        (TableStats(n_rows=10), TableStats(n_rows=10)),
        (TableStats(n_rows=500000), TableStats(n_rows=500000)),
    ]
    for l, r in cases:
        for ndev, sh in ((1, False), (8, True)):
            choice, reason = auto.explain_join(
                l, r, n_devices=ndev, sharded_available=sh)
            assert choice == auto.choose_join(
                l, r, n_devices=ndev, sharded_available=sh)
            assert isinstance(reason, str) and reason
    st = TableStats(n_rows=10)
    choice, reason = auto.explain_group_by_agg(
        st, (np.dtype(np.int32),))
    assert choice == auto.choose_group_by_agg(st, (np.dtype(np.int32),))
    assert reason


# ---------------------------------------------------------------------------
# satellite: sql -> parse -> compile -> infer spans
# ---------------------------------------------------------------------------

def test_sql_span_hierarchy():
    client = _client()
    client.run(plan(_diamond()), "main")
    with obs.tracing() as rec:
        res = client.sql("SELECT x, y FROM mid_1 WHERE x > 1")
    (sql,) = rec.spans("sql")
    assert sql.attrs["ref"] == "main"
    assert sql.attrs["rows_out"] == res.table.num_rows
    (parse,) = rec.spans("parse")
    (compile_,) = rec.spans("compile")
    (infer,) = rec.spans("infer")
    assert parse.parent_id == sql.span_id
    assert compile_.parent_id == sql.span_id
    assert infer.parent_id == compile_.span_id
    assert compile_.attrs["tables"] == ["mid_1"]
    # optimizer passes traced under the same sql span tree
    opt = rec.spans("optimizer_pass")
    assert {s.attrs["name"] for s in opt} >= {"filter_pushdown"}
    assert all(s.parent_id == sql.span_id for s in opt)


def test_optimizer_pass_spans_record_rewrites():
    client = _client()
    client.run(plan(_diamond()), "main")
    with obs.tracing() as rec:
        client.sql("SELECT x FROM mid_0 WHERE x > 1")
    push = next(s for s in rec.spans("optimizer_pass")
                if s.attrs["name"] == "filter_pushdown")
    assert push.attrs["rewrites"] == len(push.attrs["provenance"])


# ---------------------------------------------------------------------------
# satellite: EXPLAIN ANALYZE
# ---------------------------------------------------------------------------

def test_describe_analyze_requires_execution():
    pl = plan(_diamond())
    with pytest.raises(PlanError, match="analyze=True"):
        pl.describe(analyze=True)


def test_describe_analyze_format_pinned():
    import re
    client = _client()
    pl = plan(_diamond())
    client.run(pl, "main")
    d = pl.describe(analyze=True)
    # format-pinned like the EXPLAIN section: every step line ends with
    # the actual block; first run is all cache misses
    actuals = re.findall(
        r"\[actual: cache=(hit|miss|uncacheable|error) rows=(\d+|\?) "
        r"time=\d+\.\d{2}ms\]", d)
    assert len(actuals) == 4
    assert {v for v, _ in actuals} == {"miss"}
    # rerun: same plan object, now all hits with real row counts
    client.run(pl, "main")
    d2 = pl.describe(analyze=True)
    actuals2 = re.findall(r"cache=(\w+) rows=(\d+)", d2)
    assert {v for v, _ in actuals2} == {"hit"}
    assert {r for _, r in actuals2} == {"3", "1"}  # mids=3 rows, sink=1
    # plain describe unchanged (no actual block)
    assert "[actual:" not in pl.describe()


def test_query_result_describe_analyze():
    client = _client()
    client.run(plan(_diamond()), "main")
    res = client.sql("SELECT x FROM mid_0")
    d = res.describe(analyze=True)
    assert "[actual: cache=" in d
    assert "[actual:" not in res.describe()
