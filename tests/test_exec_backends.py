"""Differential suite for the pluggable execution backends (§9).

Every registered backend must reproduce the ``reference`` row-loop
oracle bit for bit — values, validity masks, row order, and the typed
fills in invalid lanes (all of it hashed by ``Table.fingerprint``) —
on join / group-by / filter / concat over random nullable tables,
including the PR 2 NULL-semantics regressions. One documented
carve-out (base.py): float SUM results compare with tolerance, because
summation order is not part of the semantics contract.

Deliberately hypothesis-free (seeded ``default_rng`` sweeps) so the
differential gate runs on minimal installs; the hypothesis sweep lives
in test_exec_backends_prop.py.
"""
import numpy as np
import pytest

from repro import exec as exec_backends
from repro.data.tables import Table, col, lit

BACKENDS = exec_backends.available_backends()
OTHERS = [b for b in BACKENDS if b != "reference"]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def random_table(n: int, seed: int) -> Table:
    """Nullable mixed-dtype table: int64/str/float64 keys (NULLs and
    NaNs included), object-int and int32 values."""
    r = np.random.default_rng(seed)
    k_int = r.integers(0, 6, n).astype(np.int64)
    k_str = np.array(
        [None if r.random() < 0.2 else f"k{int(x) % 4}" for x in k_int],
        dtype=object)
    f = r.normal(size=n)
    f[r.random(n) < 0.15] = np.nan
    v_obj = np.array(
        [None if r.random() < 0.25 else int(r.integers(-50, 50))
         for _ in range(n)], dtype=object)
    v32 = r.integers(-1000, 1000, n).astype(np.int32)
    return Table({"ki": k_int, "ks": k_str, "f": f,
                  "v": v_obj, "v32": v32})


def assert_tables_equal(a: Table, b: Table, float_cols=()):
    """Bit-for-bit equality (via repr, so NaN == NaN and None == None),
    except ``float_cols`` which compare to 1e-9 rtol on valid lanes."""
    assert a.column_names() == b.column_names()
    assert len(a) == len(b)
    for c in a.column_names():
        assert a.validity(c).tolist() == b.validity(c).tolist(), c
        if c in float_cols:
            m = a.validity(c)
            np.testing.assert_allclose(
                a.column(c)[m].astype(float),
                b.column(c)[m].astype(float), rtol=1e-9, atol=0)
        else:
            assert ([repr(x) for x in a.column(c)]
                    == [repr(y) for y in b.column(c)]), c


SEEDS = range(6)
KEYSETS = (["ki"], ["ks"], ["f"], ["ki", "ks"], ["ks", "f"])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_reference_and_vectorized_always_available():
    assert {"reference", "vectorized"} <= set(BACKENDS)


def test_sharded_and_auto_register_with_jax():
    """The whole differential suite below parametrizes over
    available_backends(); this guard makes a silent deregistration of
    the distributed backends fail loudly instead of shrinking the
    sweep."""
    assert "auto" in BACKENDS          # auto has no hard deps
    pytest.importorskip("jax")
    assert {"jax", "sharded"} <= set(BACKENDS)


def test_default_backend_is_vectorized():
    assert exec_backends.DEFAULT_BACKEND == "vectorized"
    # the active backend resolves (may have been switched by env)
    assert exec_backends.active_backend().name in BACKENDS


def test_env_selects_default(monkeypatch):
    monkeypatch.setenv("REPRO_EXEC_BACKEND", "reference")
    assert exec_backends._default_name() == "reference"


def test_unknown_backend_raises():
    with pytest.raises(KeyError, match="unknown execution backend"):
        exec_backends.get_backend("nope")
    t = Table({"a": np.array([1], dtype=np.int64)})
    with pytest.raises(KeyError):
        t.filter(col("a") >= lit(0), backend="nope")


def test_use_backend_scopes_and_restores():
    before = exec_backends.active_backend().name
    with exec_backends.use_backend("reference") as be:
        assert be.name == "reference"
        assert exec_backends.active_backend().name == "reference"
    assert exec_backends.active_backend().name == before


def test_per_call_override_beats_active():
    t = Table({"k": np.array([1, 1, 2], dtype=np.int64),
               "v": np.array([1, 2, 3], dtype=np.int64)})
    with exec_backends.use_backend("vectorized"):
        g = t.group_by_sum(["k"], "v", out="s", backend="reference")
    assert g.to_pydict() == {"k": [1, 2], "s": [3, 3]}


def test_unavailable_backend_reports_cleanly():
    exec_backends.register(
        "broken", lambda: (_ for _ in ()).throw(ImportError("no dep")))
    try:
        with pytest.raises(exec_backends.BackendUnavailable,
                           match="no dep"):
            exec_backends.get_backend("broken")
        assert "broken" not in exec_backends.available_backends()
    finally:
        exec_backends._factories.pop("broken", None)


# ---------------------------------------------------------------------------
# differential: join
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", OTHERS)
@pytest.mark.parametrize("how", ["inner", "left"])
@pytest.mark.parametrize("keys", KEYSETS, ids=lambda k: "+".join(k))
def test_join_matches_reference(backend, how, keys):
    for seed in SEEDS:
        left = random_table(40, seed)
        right = random_table(25, seed + 100).select(
            [col("ki"), col("ks"), col("f"), col("v32").alias("rv")])
        want = left.join(right, on=keys, how=how, backend="reference")
        got = left.join(right, on=keys, how=how, backend=backend)
        assert_tables_equal(want, got)
        assert want.fingerprint() == got.fingerprint(), (seed, keys)


@pytest.mark.parametrize("backend", OTHERS)
@pytest.mark.parametrize("how", ["inner", "left"])
def test_join_edge_shapes(backend, how):
    left = Table({"k": np.arange(5, dtype=np.int64),
                  "l": np.arange(5, dtype=np.int64)})
    empty = Table({"k": np.array([], dtype=np.int64),
                   "r": np.array([], dtype=np.int64)})
    nomatch = Table({"k": np.array([99], dtype=np.int64),
                     "r": np.array([1], dtype=np.int64)})
    sparse = Table({"k": np.array([2**40, 3], dtype=np.int64),
                    "r": np.array([7, 8], dtype=np.int64)})
    for right in (empty, nomatch, sparse):
        want = left.join(right, on=["k"], how=how, backend="reference")
        got = left.join(right, on=["k"], how=how, backend=backend)
        assert_tables_equal(want, got)
        # and the mirrored direction (empty/probe-side asymmetries)
        want = right.join(left, on=["k"], how=how, backend="reference")
        got = right.join(left, on=["k"], how=how, backend=backend)
        assert_tables_equal(want, got)


@pytest.mark.parametrize("backend", OTHERS)
def test_join_cross_kind_keys_compare_exactly(backend):
    """int64 vs float64 keys must match by exact Python equality — a
    float64 promotion would collapse 2**53 with 2**53 + 1."""
    left = Table({"k": np.array([2**53, 2**53 + 1], dtype=np.int64),
                  "l": np.array([1, 2], dtype=np.int64)})
    right = Table({"k": np.array([float(2**53)]),
                   "r": np.array([10], dtype=np.int64)})
    for how in ("inner", "left"):
        want = left.join(right, on=["k"], how=how, backend="reference")
        got = left.join(right, on=["k"], how=how, backend=backend)
        assert_tables_equal(want, got)
    assert left.join(right, on=["k"], backend=backend).num_rows == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_by_sum_zero_rows(backend):
    """A filter that matches nothing must aggregate to an empty table,
    not crash (empty-codes IndexError regression)."""
    t = Table({"k": np.array([1, 2], dtype=np.int64),
               "v": np.array([3, 4], dtype=np.int64)})
    empty = t.filter(col("v") > lit(100))
    g = empty.group_by_sum(["k"], "v", out="s", backend=backend)
    assert g.num_rows == 0
    assert g.column("s").dtype == np.int64
    assert g.column_names() == ["k", "s"]


@pytest.mark.parametrize("backend", BACKENDS)
def test_join_fanout_duplicate_right_keys(backend):
    """Matches expand in right-occurrence order per left row."""
    left = Table({"k": np.array([2, 1, 2], dtype=np.int64),
                  "l": np.array([0, 1, 2], dtype=np.int64)})
    right = Table({"k": np.array([2, 1, 2], dtype=np.int64),
                   "r": np.array([20, 10, 21], dtype=np.int64)})
    j = left.join(right, on=["k"], backend=backend)
    assert j.to_pydict() == {
        "k": [2, 2, 1, 2, 2], "l": [0, 0, 1, 2, 2],
        "r": [20, 21, 10, 20, 21]}


# ---------------------------------------------------------------------------
# differential: group_by_sum / filter / concat
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", OTHERS)
@pytest.mark.parametrize("keys", KEYSETS, ids=lambda k: "+".join(k))
def test_group_by_sum_matches_reference(backend, keys):
    for seed in SEEDS:
        t = random_table(50, seed)
        for value, float_sum in (("v", False), ("v32", False),
                                 ("f", True)):
            want = t.group_by_sum(keys, value, out="s",
                                  backend="reference")
            got = t.group_by_sum(keys, value, out="s", backend=backend)
            assert_tables_equal(want, got,
                                float_cols=("s",) if float_sum else ())
            if not float_sum:
                assert want.fingerprint() == got.fingerprint()


@pytest.mark.parametrize("backend", OTHERS)
def test_filter_and_concat_match_reference(backend):
    for seed in SEEDS:
        t = random_table(30, seed)
        pred = col("v32") > lit(0)
        assert_tables_equal(t.filter(pred, backend="reference"),
                            t.filter(pred, backend=backend))
        u = random_table(20, seed + 7)
        assert_tables_equal(t.concat(u, backend="reference"),
                            t.concat(u, backend=backend))


# ---------------------------------------------------------------------------
# PR 2 NULL-semantics regressions, re-run against every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_join_null_keys_match_nothing(backend):
    left = Table({"k": np.array([None, "a"], dtype=object),
                  "l": np.array([1, 2], dtype=np.int64)})
    right = Table({"k": np.array([None, "a"], dtype=object),
                   "r": np.array([10, 20], dtype=np.int64)})
    j = left.join(right, on=["k"], backend=backend)
    assert j.to_pydict() == {"k": ["a"], "l": [2], "r": [20]}


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_by_sum_null_semantics(backend):
    t = Table({"k": np.array([None, "a", None], dtype=object),
               "v": np.array([1, 2, 4], dtype=np.int64)})
    g = t.group_by_sum(["k"], "v", out="s", backend=backend)
    assert g.to_pydict() == {"k": [None, "a"], "s": [5, 2]}
    t2 = Table({"k": np.array(["a", "b"], dtype=object),
                "v": np.array([None, 3], dtype=object)})
    g2 = t2.group_by_sum(["k"], "v", out="s", backend=backend)
    assert g2.to_pydict() == {"k": ["a", "b"], "s": [None, 3]}
    assert g2.has_nulls("s")


@pytest.mark.parametrize("backend", BACKENDS)
def test_join_respects_validity_masks_after_roundtrip(backend):
    from repro.core.store import MemoryStore
    store = MemoryStore()
    left = Table({"k": np.array([None, "a"], dtype=object),
                  "l": np.array([1, 2], dtype=np.int64)})
    left = Table.from_blobs(store, left.to_blobs(store))
    right = Table({"k": np.array(["a"], dtype=object),
                   "r": np.array([20], dtype=np.int64)})
    assert left.join(right, on=["k"], backend=backend).num_rows == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_nan_keys_match_nothing_in_joins(backend):
    """NaN != NaN: float NaN keys behave like NULLs in join equality."""
    left = Table({"k": np.array([np.nan, 1.5]),
                  "l": np.array([1, 2], dtype=np.int64)})
    right = Table({"k": np.array([np.nan, 1.5]),
                   "r": np.array([10, 20], dtype=np.int64)})
    j = left.join(right, on=["k"], backend=backend)
    assert j.to_pydict() == {"k": [1.5], "l": [2], "r": [20]}
    jl = left.join(right, on=["k"], how="left", backend=backend)
    assert jl.num_rows == 2 and jl.to_pydict()["r"] == [None, 20]


@pytest.mark.parametrize("backend", BACKENDS)
def test_nan_keys_group_separately(backend):
    """Each NaN key is its own group (NaN != NaN), while NULLs
    collapse into one — the reference dict semantics."""
    t = Table({"k": np.array([np.nan, 1.0, np.nan]),
               "v": np.array([1, 2, 4], dtype=np.int64)})
    g = t.group_by_sum(["k"], "v", out="s", backend=backend)
    assert g.num_rows == 3
    assert g.to_pydict()["s"] == [1, 2, 4]


# ---------------------------------------------------------------------------
# left join semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_left_join_unmatched_rows_null_right(backend):
    left = Table({"k": np.array(["a", None, "b"], dtype=object),
                  "l": np.array([1, 2, 3], dtype=np.int64)})
    right = Table({"k": np.array(["a", "a"], dtype=object),
                   "r": np.array([10, 11], dtype=np.int64)})
    j = left.join(right, on=["k"], how="left", backend=backend)
    assert j.to_pydict() == {
        "k": ["a", "a", None, "b"], "l": [1, 1, 2, 3],
        "r": [10, 11, None, None]}
    assert j.has_nulls("r") and j.logical_dtype("r") == "int64"


@pytest.mark.parametrize("backend", BACKENDS)
def test_left_join_validity_mask_of_right_columns(backend):
    """Introduced NULLs are mask-NULLs with the canonical typed fill."""
    left = Table({"k": np.array([5, 7], dtype=np.int64)})
    right = Table({"k": np.array([5], dtype=np.int64),
                   "r": np.array([1.5])})
    j = left.join(right, on=["k"], how="left", backend=backend)
    assert j.validity("r").tolist() == [True, False]
    assert j.column("r")[1] == 0.0        # canonical numeric fill


def test_join_rejects_unknown_how():
    t = Table({"k": np.array([1], dtype=np.int64)})
    with pytest.raises(NotImplementedError, match="inner, left"):
        t.join(t, on=["k"], how="outer")


# ---------------------------------------------------------------------------
# group_by_sum output-name satellite
# ---------------------------------------------------------------------------

def test_group_by_sum_default_output_name():
    t = Table({"k": np.array([1, 1], dtype=np.int64),
               "v": np.array([2, 3], dtype=np.int64)})
    g = t.group_by_sum(["k"], "v")
    assert g.column_names() == ["k", "v_sum"]
    assert g.to_pydict() == {"k": [1], "v_sum": [5]}


def test_group_by_sum_default_name_decollides_against_keys():
    t = Table({"v_sum": np.array([1, 1], dtype=np.int64),
               "v": np.array([2, 3], dtype=np.int64)})
    g = t.group_by_sum(["v_sum"], "v")
    assert g.column_names() == ["v_sum", "v_sum_1"]


def test_group_by_sum_explicit_collision_raises():
    t = Table({"k": np.array([1], dtype=np.int64),
               "v": np.array([2], dtype=np.int64)})
    with pytest.raises(ValueError, match="collides with a group key"):
        t.group_by_sum(["k"], "v", out="k")


# ---------------------------------------------------------------------------
# Expr._binop object-dtype hardening satellite
# ---------------------------------------------------------------------------

def test_binop_arithmetic_over_nullable_object_column():
    """None payloads in masked lanes must not reach the ufunc: this
    used to raise TypeError from None - 1."""
    t = Table({"v": np.array([None, 2, 5], dtype=object)})
    f = t.filter((col("v") - 1) > lit(1))
    assert f.to_pydict() == {"v": [5]}


def test_binop_comparison_over_nullable_object_column():
    t = Table({"v": np.array([None, 2, 5], dtype=object)})
    assert t.filter(col("v") < lit(3)).to_pydict() == {"v": [2]}


def test_binop_two_nullable_object_columns():
    t = Table({"a": np.array([None, 2, 4], dtype=object),
               "b": np.array([1, None, 4], dtype=object)})
    f = t.filter((col("a") + col("b")) >= lit(8))
    assert f.to_pydict() == {"a": [4], "b": [4]}


def test_binop_null_lanes_carry_canonical_fill():
    """Arithmetic over nullable object columns leaves None (the
    canonical object fill) in masked lanes, so logically identical
    tables fingerprint identically regardless of construction path."""
    t = Table({"a": np.array([1, None], dtype=object),
               "b": np.array([2, 3], dtype=object)})
    built = t.select([(col("a") + col("b")).alias("s")])
    direct = Table({"s": np.array([3, None], dtype=object)})
    assert built.to_pydict() == direct.to_pydict() == {"s": [3, None]}
    assert built.fingerprint() == direct.fingerprint()


def test_binop_fully_valid_numeric_path_unchanged():
    t = Table({"a": np.array([1.0, 2.0])})
    out = t.select([(col("a") * 2).alias("d")])
    assert out.column("d").dtype == np.float64
    np.testing.assert_array_equal(out.column("d"), [2.0, 4.0])


# ---------------------------------------------------------------------------
# engine cache keys record the backend
# ---------------------------------------------------------------------------

def _toy_client_and_plan():
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.planner import plan
    from repro.core.runner import Client

    Src = S.Schema.of("Src", k=int, v=int)
    Agg = S.Schema.of("Agg", k=S.Nullable[int], s=S.Nullable[int])

    p = Pipeline("backend_fp")
    p.source("src", Src)

    @p.node()
    def agg(df: Src = "src") -> Agg:
        return df.group_by_sum(["k"], "v", out="s")

    client = Client()
    client.write_source_table("main", "src", Table({
        "k": np.array([1, 1, 2], dtype=np.int64),
        "v": np.array([10, 20, 30], dtype=np.int64)}))
    return client, plan(p)


def test_cache_key_moves_with_backend_switch():
    from repro.core.engine import cache_key

    client, pl = _toy_client_and_plan()
    step = pl.steps[0]
    snaps = {"df": "snap0"}
    with exec_backends.use_backend("vectorized"):
        k_vec = cache_key(step, snaps)
    with exec_backends.use_backend("reference"):
        k_ref = cache_key(step, snaps)
    assert k_vec is not None and k_ref is not None
    assert k_vec != k_ref


def test_backend_switch_never_serves_cross_backend_cache_hit():
    client, pl = _toy_client_and_plan()
    with exec_backends.use_backend("vectorized"):
        r1 = client.run(pl, "main")
        assert r1.executed == ("agg",)
        r2 = client.run(pl, "main")
        assert r2.executed == () and r2.cached == ("agg",)
    with exec_backends.use_backend("reference"):
        r3 = client.run(pl, "main")       # other backend: key moved
        assert r3.executed == ("agg",)
        r4 = client.run(pl, "main")       # same backend: hits again
        assert r4.executed == ()
    with exec_backends.use_backend("vectorized"):
        r5 = client.run(pl, "main")       # original entry still live
        assert r5.executed == ()
