"""Transactional pipelines (paper §3.3, Fig. 3): all outputs or none."""
import pytest

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import TransactionAborted, TransactionError
from repro.core.transactions import (RunRegistry, TransactionalRun,
                                     run_transaction)


@pytest.fixture
def cat():
    c = Catalog()
    c.write_table("main", "P", "P*")
    c.write_table("main", "C", "C*")
    c.write_table("main", "G", "G*")
    return c


def test_happy_path_atomic_publication(cat):
    """Fig. 3 bottom, run_1: all three tables land atomically."""
    reg = RunRegistry()
    before = cat.head("main").id
    with TransactionalRun(cat, "main", code="dag-v2",
                          registry=reg) as txn:
        txn.write_table("P", "P**")
        # mid-run: main is UNTOUCHED (readers see the old complete state)
        assert cat.tables("main")["P"] == "P*"
        txn.write_table("C", "C**")
        txn.write_table("G", "G**")
    assert cat.tables("main") == {"P": "P**", "C": "C**", "G": "G**"}
    state = reg.get_run(txn.run_id)
    assert state.status == "committed"
    assert state.ref == before                  # pinned start commit
    # txn branch cleaned up on success
    assert txn.branch not in cat.branches()


def test_failure_leaves_main_consistent(cat):
    """Fig. 3 bottom, run_2: failure after P** does NOT tear main."""
    reg = RunRegistry()
    with pytest.raises(RuntimeError, match="child blew up"):
        with TransactionalRun(cat, "main", registry=reg) as txn:
            txn.write_table("P", "P**")
            raise RuntimeError("child blew up")
    # main still serves the complete state of the last successful run
    assert cat.tables("main") == {"P": "P*", "C": "C*", "G": "G*"}
    # the aborted branch is preserved for debugging (paper's "bonus")
    assert txn.branch in cat.branches()
    info = cat.branch_info(txn.branch)
    assert info.visibility is Visibility.ABORTED
    assert cat.read_table(txn.branch, "P") == "P**"   # triage the failure
    assert reg.get_run(txn.run_id).status == "aborted"


def test_fig3_top_direct_writes_tear_main(cat):
    """Fig. 3 top: WITHOUT the txn protocol, a mid-run failure leaves
    main in the partially-stale state {P**, C*, G*}."""
    cat.write_table("main", "P", "P**")
    # ... crash before writing C — nothing to roll back
    assert cat.tables("main") == {"P": "P**", "C": "C*", "G": "G*"}
    # (this is the failure mode the protocol upgrades to total failure)


def test_verifier_failure_aborts(cat):
    """Step (3): data tests run on B' BEFORE the merge."""
    def verifier(read):
        if read("C") == "C-bad":
            raise ValueError("quality check failed: nulls in col4")

    with pytest.raises(TransactionAborted):
        with TransactionalRun(cat, "main") as txn:
            txn.write_table("P", "P**")
            txn.write_table("C", "C-bad")
            txn.verify(verifier)
    assert cat.tables("main")["C"] == "C*"
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED


def test_snapshot_reads_during_run(cat):
    """Reads inside the run resolve against the pinned start commit even
    if main moves concurrently (MVCC-style snapshot isolation)."""
    with TransactionalRun(cat, "main") as txn:
        cat.write_table("main", "P", "P-concurrent")   # concurrent writer
        assert txn.read_table("P") == "P*"             # snapshot read
        txn.write_table("G", "G**")
    # non-conflicting tables merge cleanly (three-way)
    assert cat.tables("main")["G"] == "G**"
    assert cat.tables("main")["P"] == "P-concurrent"


def test_concurrent_conflicting_commit_aborts(cat):
    """If main concurrently changed the SAME table, commit must not
    silently clobber it."""
    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("P", "P**")
    cat.write_table("main", "P", "P-concurrent")
    with pytest.raises(TransactionAborted, match="publication failed"):
        txn.commit()
    # the losing run is aborted, its branch kept for triage
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED
    assert cat.tables("main")["P"] == "P-concurrent"


def test_cannot_write_after_commit(cat):
    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("P", "P**")
    txn.commit()
    with pytest.raises(TransactionError):
        txn.write_table("C", "C**")


def test_cannot_begin_twice(cat):
    txn = TransactionalRun(cat, "main").begin()
    with pytest.raises(TransactionError):
        txn.begin()


def test_run_transaction_helper(cat):
    head = run_transaction(cat, "main", {"P": "Pnew", "C": "Cnew"},
                           code="helper")
    assert head.tables["P"] == "Pnew"
    assert cat.tables("main")["C"] == "Cnew"


def test_nested_runs_on_user_branches(cat):
    """The paper's collaboration story: agent proposes on a feature
    branch via a transactional run; human merges after review."""
    cat.create_branch("feature", "main")
    with TransactionalRun(cat, "feature") as txn:
        txn.write_table("P", "P-agent")
    assert cat.tables("feature")["P"] == "P-agent"
    assert cat.tables("main")["P"] == "P*"       # not yet reviewed
    cat.merge("feature", into="main")            # the PR merge
    assert cat.tables("main")["P"] == "P-agent"
