"""Transactional pipelines (paper §3.3, Fig. 3): all outputs or none."""
import pytest

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import (PublicationConflict, TransactionAborted,
                               TransactionError)
from repro.core.transactions import (RunRegistry, TransactionalRun,
                                     run_transaction)


@pytest.fixture
def cat():
    c = Catalog()
    c.write_table("main", "P", "P*")
    c.write_table("main", "C", "C*")
    c.write_table("main", "G", "G*")
    return c


def test_happy_path_atomic_publication(cat):
    """Fig. 3 bottom, run_1: all three tables land atomically."""
    reg = RunRegistry()
    before = cat.head("main").id
    with TransactionalRun(cat, "main", code="dag-v2",
                          registry=reg) as txn:
        txn.write_table("P", "P**")
        # mid-run: main is UNTOUCHED (readers see the old complete state)
        assert cat.tables("main")["P"] == "P*"
        txn.write_table("C", "C**")
        txn.write_table("G", "G**")
    assert cat.tables("main") == {"P": "P**", "C": "C**", "G": "G**"}
    state = reg.get_run(txn.run_id)
    assert state.status == "committed"
    assert state.ref == before                  # pinned start commit
    # txn branch cleaned up on success
    assert txn.branch not in cat.branches()


def test_failure_leaves_main_consistent(cat):
    """Fig. 3 bottom, run_2: failure after P** does NOT tear main."""
    reg = RunRegistry()
    with pytest.raises(RuntimeError, match="child blew up"):
        with TransactionalRun(cat, "main", registry=reg) as txn:
            txn.write_table("P", "P**")
            raise RuntimeError("child blew up")
    # main still serves the complete state of the last successful run
    assert cat.tables("main") == {"P": "P*", "C": "C*", "G": "G*"}
    # the aborted branch is preserved for debugging (paper's "bonus")
    assert txn.branch in cat.branches()
    info = cat.branch_info(txn.branch)
    assert info.visibility is Visibility.ABORTED
    assert cat.read_table(txn.branch, "P") == "P**"   # triage the failure
    assert reg.get_run(txn.run_id).status == "aborted"


def test_fig3_top_direct_writes_tear_main(cat):
    """Fig. 3 top: WITHOUT the txn protocol, a mid-run failure leaves
    main in the partially-stale state {P**, C*, G*}."""
    cat.write_table("main", "P", "P**")
    # ... crash before writing C — nothing to roll back
    assert cat.tables("main") == {"P": "P**", "C": "C*", "G": "G*"}
    # (this is the failure mode the protocol upgrades to total failure)


def test_verifier_failure_aborts(cat):
    """Step (3): data tests run on B' BEFORE the merge."""
    def verifier(read):
        if read("C") == "C-bad":
            raise ValueError("quality check failed: nulls in col4")

    with pytest.raises(TransactionAborted):
        with TransactionalRun(cat, "main") as txn:
            txn.write_table("P", "P**")
            txn.write_table("C", "C-bad")
            txn.verify(verifier)
    assert cat.tables("main")["C"] == "C*"
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED


def test_snapshot_reads_during_run(cat):
    """Reads inside the run resolve against the pinned start commit even
    if main moves concurrently (MVCC-style snapshot isolation)."""
    with TransactionalRun(cat, "main") as txn:
        cat.write_table("main", "P", "P-concurrent")   # concurrent writer
        assert txn.read_table("P") == "P*"             # snapshot read
        txn.write_table("G", "G**")
    # non-conflicting tables merge cleanly (three-way)
    assert cat.tables("main")["G"] == "G**"
    assert cat.tables("main")["P"] == "P-concurrent"


def test_concurrent_conflicting_commit_aborts(cat):
    """If main concurrently changed the SAME table, commit must not
    silently clobber it."""
    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("P", "P**")
    cat.write_table("main", "P", "P-concurrent")
    with pytest.raises(TransactionAborted, match="publication failed"):
        txn.commit()
    # the losing run is aborted, its branch kept for triage
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED
    assert cat.tables("main")["P"] == "P-concurrent"


def test_cannot_write_after_commit(cat):
    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("P", "P**")
    txn.commit()
    with pytest.raises(TransactionError):
        txn.write_table("C", "C**")


def test_cannot_begin_twice(cat):
    txn = TransactionalRun(cat, "main").begin()
    with pytest.raises(TransactionError):
        txn.begin()


def test_run_transaction_helper(cat):
    head = run_transaction(cat, "main", {"P": "Pnew", "C": "Cnew"},
                           code="helper")
    assert head.tables["P"] == "Pnew"
    assert cat.tables("main")["C"] == "Cnew"


def test_run_transaction_returns_own_merge_not_later_head(cat):
    """Regression: the helper used to return catalog.head(target) AFTER
    the with-block — under concurrency that can be someone else's
    commit. It must return the actual merged commit of THIS run."""
    recorded = {}

    def sneaky_verifier(read):
        # simulate a concurrent run publishing between our merge and the
        # (old) post-hoc head read: we publish, then main moves again.
        recorded["ran"] = True

    merged = run_transaction(cat, "main", {"P": "P1"},
                             verifiers=[sneaky_verifier])
    # another writer moves main AFTER our commit returned
    cat.write_table("main", "P", "P-later")
    assert recorded["ran"]
    assert merged.tables["P"] == "P1"          # our state, not P-later
    assert merged.run_id is not None
    assert cat.commit(merged.id).id == merged.id


# ---------------------------------------------------------------------------
# Rebase-and-revalidate publication (DESIGN.md §7)
# ---------------------------------------------------------------------------

def test_rebase_republishes_verified_state(cat):
    """If main moves after begin() on a DISJOINT table, commit() must
    rebase and re-run the verifiers against the rebased state — never
    silently three-way-merge a state no verifier saw."""
    seen_states = []

    def verifier(read):
        seen_states.append((read("P"), read("G")))

    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("G", "G**")
    txn.verify(verifier)
    assert seen_states == [("P*", "G**")]
    cat.write_table("main", "P", "P-concurrent")   # target moves
    merged = txn.commit()
    # the verifier RE-RAN and observed the rebased (published) state
    assert seen_states[-1] == ("P-concurrent", "G**")
    assert merged.tables == {"P": "P-concurrent", "C": "C*", "G": "G**",
                             }
    assert txn.publish_attempts == 2
    # published commit is exactly the branch head the verifiers validated
    assert txn.final_commit.id == merged.id


def test_verifier_failure_on_revalidation_aborts(cat):
    """A verifier that passes pre-conflict but fails against the rebased
    state must abort the run — publishing would be incorrect."""
    def verifier(read):
        if read("P") == "P-concurrent":
            raise ValueError("new base breaks the quality gate")

    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("G", "G**")
    txn.verify(verifier)                           # passes against P*
    cat.write_table("main", "P", "P-concurrent")
    with pytest.raises(TransactionAborted, match="revalidation"):
        txn.commit()
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED
    assert cat.tables("main")["G"] == "G*"         # nothing published


def test_writes_after_verify_are_revalidated(cat):
    """A write AFTER a verifier ran makes its observation stale; commit
    must re-run it so the published state is fully validated."""
    observed = []

    def verifier(read):
        observed.append(read("C"))

    txn = TransactionalRun(cat, "main").begin()
    txn.write_table("C", "C1")
    txn.verify(verifier)
    txn.write_table("C", "C2")                     # stale-ifies the pass
    txn.commit()
    assert observed == ["C1", "C2"]                # re-ran before merge
    assert cat.tables("main")["C"] == "C2"


def test_publication_conflict_after_retry_budget(cat):
    """A target that keeps moving exhausts the CAS budget and raises
    PublicationConflict; the branch is aborted and preserved."""
    def adversarial_verifier(read):
        # every (re)validation pass, the target moves again
        cat.write_table("main", "hot", f"v{len(moves)}")
        moves.append(1)

    moves = []
    txn = TransactionalRun(cat, "main", max_publish_attempts=3,
                           publish_backoff_s=0.0).begin()
    txn.write_table("G", "G**")
    txn.verify(adversarial_verifier)
    with pytest.raises(PublicationConflict, match="gave up after 3"):
        txn.commit()
    assert cat.branch_info(txn.branch).visibility is Visibility.ABORTED
    reg_free_state = txn.publish_attempts
    assert reg_free_state == 3


def test_registry_records_verified_head_and_attempts(cat):
    reg = RunRegistry()
    with TransactionalRun(cat, "main", registry=reg) as txn:
        txn.write_table("P", "P**")
        txn.verify(lambda read: read("P"))
    st = reg.get_run(txn.run_id)
    assert st.status == "committed"
    assert st.publish_attempts == 1
    assert st.verified_head == st.final_commit     # published == verified
    assert st.base_commit == st.ref                # no rebase happened


def test_registry_records_rebased_base_commit(cat):
    """After a rebase, `ref` keeps the pinned READ state while
    `base_commit` records the head actually published onto."""
    reg = RunRegistry()
    start = cat.head("main").id
    txn = TransactionalRun(cat, "main", registry=reg).begin()
    txn.write_table("G", "G**")
    moved = cat.write_table("main", "P", "P-concurrent")
    merged = txn.commit()
    st = reg.get_run(txn.run_id)
    assert st.ref == start
    assert st.base_commit == moved.id
    assert cat.commit(merged.id).parents[0] == st.base_commit


def test_keep_branch_on_success_releases_branch(cat):
    txn = TransactionalRun(cat, "main", keep_branch_on_success=True)
    with txn:
        txn.write_table("P", "P**")
    info = cat.branch_info(txn.branch)
    assert info.visibility is Visibility.USER      # published: released
    cat.delete_branch(txn.branch)                  # user may clean up


def test_nested_runs_on_user_branches(cat):
    """The paper's collaboration story: agent proposes on a feature
    branch via a transactional run; human merges after review."""
    cat.create_branch("feature", "main")
    with TransactionalRun(cat, "feature") as txn:
        txn.write_table("P", "P-agent")
    assert cat.tables("feature")["P"] == "P-agent"
    assert cat.tables("main")["P"] == "P*"       # not yet reviewed
    cat.merge("feature", into="main")            # the PR merge
    assert cat.tables("main")["P"] == "P-agent"
