"""The optimizer's proof obligation: optimized == unoptimized, bit for
bit, on every registered execution backend (DESIGN.md §11).

Every fixture pipeline runs twice per backend — once as planned, once
through ``optimize(plan, DEFAULT_PASSES)`` — and the *published* table
snapshots must fingerprint identically (``Table.fingerprint`` covers
values, validity masks, dtypes, row order and column order). This is
the rewrite-pass contract made executable; a pass that cannot
guarantee this must not fire.

The documented float-SUM carve-out (backends may regroup float
summation) does not apply here: no rewrite touches an aggregation —
pushdown/reorder/pruning/fusion rearrange scans, filters, projections
and joins, all of which gather rows — so equality is exact, never
tolerance-based.

Fixtures are chosen adversarially: NULL/NaN/object join keys (SQL
match-nothing semantics), left joins (where pushes must partially
refuse), shared filters (aux materialization + wave change), dead
columns, reorderable star chains, and an opaque Python node mixed in
(must pass through untouched).
"""
import numpy as np
import pytest

from repro import exec as exec_backends
from repro.core import schema as S
from repro.core.catalog import Catalog
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, _ColumnData, col, lit
from repro.exec.stats import collect_stats
from repro.optimizer import DEFAULT_PASSES, optimize

BACKENDS = exec_backends.available_backends()

Fact = S.Schema.of("Fact", user_id=int, item_id=int, amount=float,
                   junk=float)
Users = S.Schema.of("Users", user_id=int, segment=int, bio=str)
Items = S.Schema.of("Items", item_id=int, weight=float)
Out = S.Schema.of("Out", user_id=int, amount=float, weight=float)
Joined = S.Schema.of("Joined", user_id=int, amount=float, segment=int)

_rng = np.random.default_rng(7)
_N = 800


def _sources():
    uid = _rng.integers(0, 60, _N)
    fact = Table({"user_id": uid,
                  "item_id": _rng.integers(0, 25, _N),
                  "amount": _rng.normal(size=_N),
                  "junk": _rng.normal(size=_N)})
    # deliberately larger than items even after the assumed filter
    # selectivity, so the star fixture's greedy order is NOT identity
    users = Table({"user_id": np.arange(200, dtype=np.int64),
                   "segment": (np.arange(200) % 8).astype(np.int64),
                   "bio": np.array([f"u{i}" for i in range(200)],
                                   dtype=object)})
    items = Table({"item_id": np.arange(25, dtype=np.int64),
                   "weight": _rng.normal(size=25)})
    return {"fact": fact, "users": users, "items": items}


def _null_sources():
    """NULL validity + NaN payloads on keys: must match nothing,
    optimized or not."""
    uid = _rng.integers(0, 20, 200).astype(np.float64)
    uid[::7] = np.nan
    valid = np.ones(200, dtype=bool)
    valid[::11] = False
    FactN = S.Schema.of("Fact", user_id=float, item_id=int,
                        amount=float, junk=float)
    fact = Table({"user_id": _ColumnData(uid, valid),
                  "item_id": _rng.integers(0, 25, 200),
                  "amount": _rng.normal(size=200),
                  "junk": _rng.normal(size=200)})
    users = Table({"user_id": np.arange(20, dtype=np.float64),
                   "segment": (np.arange(20) % 8).astype(np.int64),
                   "bio": np.array([f"u{i}" for i in range(20)],
                                   dtype=object)})
    return FactN, {"fact": fact, "users": users}


def _p_single_join_pushable():
    p = Pipeline("single_join")
    p.source("fact", Fact)
    p.source("users", Users)
    p.sql(name="out", inputs={"f": "fact", "u": "users"},
          input_schemas={"f": Fact, "u": Users}, output_schema=Joined,
          join_with="users", join_on=["user_id"],
          filter_expr=(col("segment") > 2),
          exprs=[col("user_id"), col("amount"), col("segment")])
    return p, _sources(), None


def _p_star_reorder():
    src = _sources()
    p = Pipeline("star")
    p.source("fact", Fact)
    p.source("users", Users)
    p.source("items", Items)
    p.sql(name="out", inputs={"f": "fact", "u": "users", "i": "items"},
          input_schemas={"f": Fact, "u": Users, "i": Items},
          output_schema=Out,
          joins=[("users", ["user_id"]), ("items", ["item_id"])],
          filter_expr=(col("segment") == 3),
          exprs=[col("user_id"), col("amount"), col("weight")])
    stats = {t: collect_stats(tab._to_cols()) for t, tab in src.items()}
    return p, src, stats


def _p_null_keys():
    FactN, src = _null_sources()
    JoinedN = S.Schema.of("Joined", user_id=float, amount=float,
                          segment=int)
    p = Pipeline("null_keys")
    p.source("fact", FactN)
    p.source("users", S.Schema.of("Users", user_id=float, segment=int,
                                  bio=str))
    p.sql(name="out", inputs={"f": "fact", "u": "users"},
          input_schemas={"f": p.source_schemas["fact"],
                         "u": p.source_schemas["users"]},
          output_schema=JoinedN,
          join_with="users", join_on=["user_id"],
          filter_expr=(col("segment") >= 2),
          exprs=[col("user_id"), col("amount"), col("segment")])
    return p, src, None


def _p_object_keys():
    KF = S.Schema.of("KF", k=str, v=int)
    KD = S.Schema.of("KD", k=str, tag=int)
    KO = S.Schema.of("KO", k=str, v=int, tag=int)
    keys = np.array([f"k{i % 12}" for i in range(150)], dtype=object)
    src = {"f": Table({"k": keys,
                       "v": np.arange(150, dtype=np.int64)}),
           "d": Table({"k": np.array([f"k{i}" for i in range(12)],
                                     dtype=object),
                       "tag": (np.arange(12) % 3).astype(np.int64)})}
    p = Pipeline("object_keys")
    p.source("f", KF)
    p.source("d", KD)
    p.sql(name="out", inputs={"a": "f", "b": "d"},
          input_schemas={"a": KF, "b": KD}, output_schema=KO,
          join_with="d", join_on=["k"],
          filter_expr=(col("tag") == 1),
          exprs=[col("k"), col("v"), col("tag")])
    return p, src, None


def _p_left_join_right_filter():
    """Filter on the right side of a LEFT join: right-push must refuse,
    fusion into a masked right probe is still legal."""
    p = Pipeline("left_rfilter")
    p.source("fact", Fact)
    p.source("users", Users)
    JoinedL = S.Schema.of("Joined", user_id=int, amount=float)
    p.sql(name="out", inputs={"f": "fact", "u": "users"},
          input_schemas={"f": Fact, "u": Users}, output_schema=JoinedL,
          join_with="users", join_on=["user_id"], join_how="left",
          filter_expr=(col("amount") > 0),   # left-side pred: pushable
          exprs=[col("user_id"), col("amount")])
    src = _sources()
    # shrink users so some fact rows are unmatched (NULL-filled)
    src["users"] = src["users"].filter(col("user_id") < 30)
    return p, src, None


def _p_dead_columns():
    p = Pipeline("dead_cols")
    p.source("fact", Fact)
    Slim = S.Schema.of("Slim", user_id=int, amount=float)
    p.sql(name="out", inputs={"f": "fact"}, input_schemas={"f": Fact},
          output_schema=Slim,
          exprs=[col("user_id"), (col("amount") * lit(2.0)).alias("amount")])
    return p, _sources(), None


def _p_shared_filter():
    p = Pipeline("shared")
    p.source("fact", Fact)
    Slim = S.Schema.of("Slim", user_id=int, amount=float)
    for name in ("a", "b"):
        p.sql(name=name, inputs={"f": "fact"},
              input_schemas={"f": Fact}, output_schema=Slim,
              filter_expr=(col("amount") > 0),
              exprs=[col("user_id"), col("amount")])
    return p, _sources(), None


def _p_opaque_python_node():
    """An opaque Python node (no logical tree) rides along unrewritten
    next to a rewritable declarative sibling."""
    p = Pipeline("mixed")
    p.source("fact", Fact)
    p.source("users", Users)
    p.sql(name="out", inputs={"f": "fact", "u": "users"},
          input_schemas={"f": Fact, "u": Users}, output_schema=Joined,
          join_with="users", join_on=["user_id"],
          filter_expr=(col("segment") == 2),
          exprs=[col("user_id"), col("amount"), col("segment")])
    Top = S.Schema.of("Top", user_id=int, amount=float)

    @p.node()
    def top(j: Joined = "out") -> Top:
        order = np.argsort(np.asarray(j.column("amount")),
                           kind="stable")[::-1][:10]
        return Table({"user_id": np.asarray(j.column("user_id"))[order],
                      "amount": np.asarray(j.column("amount"))[order]})

    return p, _sources(), None


PIPELINES = [_p_single_join_pushable, _p_star_reorder, _p_null_keys,
             _p_object_keys, _p_left_join_right_filter,
             _p_dead_columns, _p_shared_filter, _p_opaque_python_node]


def _run(pl, sources, backend):
    c = Client(Catalog())
    for t, tab in sources.items():
        c.write_source_table("main", t, tab)
    with exec_backends.use_backend(backend):
        c.run(pl, "main", cache=False)
    return {t: c.read_table("main", t).fingerprint()
            for t in pl.output_tables}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("make", PIPELINES,
                         ids=lambda f: f.__name__.lstrip("_"))
def test_optimized_equals_unoptimized_bit_for_bit(make, backend):
    p, sources, stats = make()
    pl = plan(p, table_stats=stats)
    opt = optimize(pl, passes=DEFAULT_PASSES)
    assert opt.output_tables == pl.output_tables
    base = _run(pl, sources, backend)
    got = _run(opt, sources, backend)
    assert got == base


def test_star_fixture_actually_rewrites():
    """Guard against the suite silently testing nothing: the star
    fixture must trigger pushdown, reorder and pruning, and the shared
    fixture must materialize an aux step."""
    p, _, stats = _p_star_reorder()
    opt = optimize(plan(p, table_stats=stats))
    msgs = [m for s in opt.steps for m in s.provenance]
    assert any("filter_pushdown" in m for m in msgs)
    assert any("join_reorder" in m for m in msgs)
    assert any("column_pruning" in m for m in msgs)

    p, _, _ = _p_shared_filter()
    opt = optimize(plan(p))
    assert any(not s.published for s in opt.steps)

    p, _, _ = _p_single_join_pushable()
    opt = optimize(plan(p))
    assert any("probe_fusion" in m
               for s in opt.steps for m in s.provenance)
