"""Masked segment-reduce (MIN/MAX) kernel validation
(kernels/segment_sum — the segment-reduce family added with
``group_by_agg``).

Pallas kernel (interpret=True on this CPU container) and the XLA
``segment_min``/``segment_max`` reference vs a numpy loop. MIN/MAX are
order-independent reductions, so there is NO float carve-out here:
every dtype must match the oracle bit for bit, including the NaN
poisoning rule (a NaN in a *valid* float lane propagates to its
segment, matching ``np.minimum``/``np.maximum`` accumulation) and the
empty-segment identity (±inf / integer dtype extremes — the backend
rewrites those to NULL fills downstream). Hypothesis-free so it runs
on minimal installs.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.segment_sum.kernel import (  # noqa: E402
    masked_segment_reduce_kernel)
from repro.kernels.segment_sum.ops import masked_segment_reduce  # noqa: E402
from repro.kernels.segment_sum.ref import (  # noqa: E402
    masked_segment_reduce_ref, reduce_identity)


def _numpy_oracle(vals, ids, valid, num_segments, op):
    ident = reduce_identity(vals.dtype, op)
    red = np.full(num_segments, ident, dtype=vals.dtype)
    counts = np.zeros(num_segments, dtype=np.int32)
    fn = np.minimum if op == "min" else np.maximum
    for v, i, ok in zip(vals, ids, valid):
        if ok:
            red[i] = fn(red[i], v)      # NaN propagates, like reference
            counts[i] += 1
    return red, counts


def _case(n, num_segments, dtype, seed, p_valid=0.7, p_nan=0.0):
    r = np.random.default_rng(seed)
    ids = r.integers(0, num_segments, n).astype(np.int32)
    valid = r.random(n) < p_valid
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        vals = r.integers(max(info.min, -50), min(info.max, 50),
                          n).astype(dtype)
    else:
        vals = r.normal(size=n).astype(dtype)
        if p_nan:
            vals[r.random(n) < p_nan] = np.nan
    return vals, ids, valid


@pytest.mark.parametrize("n,num_segments", [
    (1000, 37),          # ragged both axes
    (1024, 512),         # exact block multiples
    (5, 3),              # smaller than any block
    (2000, 1),           # single segment
])
@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_int32_bit_exact(n, num_segments, op, use_pallas):
    vals, ids, valid = _case(n, num_segments, np.int32, seed=n)
    want_r, want_c = _numpy_oracle(vals, ids, valid, num_segments, op)
    got_r, got_c = masked_segment_reduce(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid),
        num_segments, op=op, use_pallas=use_pallas,
        block_n=256, block_s=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_r), want_r)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_float32_bit_exact_including_nan_poisoning(op, use_pallas):
    """MIN/MAX never reorder-drift: float comparisons are exact, and a
    NaN in a valid lane must poison exactly its own segment."""
    vals, ids, valid = _case(3000, 50, np.float32, seed=1, p_nan=0.05)
    want_r, want_c = _numpy_oracle(vals, ids, valid, 50, op)
    got_r, got_c = masked_segment_reduce(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 50,
        op=op, use_pallas=use_pallas, block_n=512, block_s=32,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(got_r), want_r)
    np.testing.assert_array_equal(np.asarray(got_c), want_c)


@pytest.mark.parametrize("op", ["min", "max"])
def test_nan_in_invalid_lane_does_not_poison(op):
    vals = np.array([np.nan, 1.0, np.nan, 2.0], dtype=np.float32)
    ids = np.array([0, 0, 1, 1], dtype=np.int32)
    valid = np.array([False, True, False, True])
    r, c = masked_segment_reduce(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 2,
        op=op, use_pallas=True, block_n=128, block_s=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(r),
                                  np.array([1.0, 2.0], np.float32))
    np.testing.assert_array_equal(np.asarray(c), [1, 1])


@pytest.mark.parametrize("op", ["min", "max"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_empty_segments_hold_identity(op, use_pallas):
    vals, ids, _ = _case(500, 11, np.int32, seed=2)
    valid = np.zeros(500, dtype=bool)
    r, c = masked_segment_reduce(
        jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid), 11,
        op=op, use_pallas=use_pallas, block_n=128, block_s=8,
        interpret=True)
    ident = reduce_identity(np.dtype(np.int32), op)
    assert np.asarray(r).tolist() == [ident] * 11
    assert np.asarray(c).sum() == 0


@pytest.mark.parametrize("op", ["min", "max"])
def test_kernel_block_shape_invariance(op):
    """Tiling is a perf knob: output must not depend on block sizes —
    and MIN/MAX make this exact even for floats."""
    vals, ids, valid = _case(777, 23, np.float32, seed=3, p_nan=0.1)
    outs = []
    for block_n, block_s in ((64, 8), (256, 16), (1024, 512)):
        r, c = masked_segment_reduce_kernel(
            jnp.asarray(vals), jnp.asarray(ids), jnp.asarray(valid),
            23, op, block_n=block_n, block_s=block_s, interpret=True)
        outs.append((np.asarray(r), np.asarray(c)))
    for r, c in outs[1:]:
        np.testing.assert_array_equal(r, outs[0][0])
        np.testing.assert_array_equal(c, outs[0][1])


@pytest.mark.parametrize("op", ["min", "max"])
def test_kernel_matches_xla_ref(op):
    vals, ids, valid = _case(2048, 96, np.int32, seed=4)
    a = masked_segment_reduce_ref(jnp.asarray(vals), jnp.asarray(ids),
                                  jnp.asarray(valid), 96, op)
    b = masked_segment_reduce_kernel(jnp.asarray(vals),
                                     jnp.asarray(ids),
                                     jnp.asarray(valid), 96, op,
                                     block_n=512, block_s=32,
                                     interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_unknown_op_raises():
    vals = jnp.asarray(np.zeros(4, np.int32))
    ids = jnp.asarray(np.zeros(4, np.int32))
    ok = jnp.asarray(np.ones(4, bool))
    with pytest.raises(ValueError, match="unknown segment reduce op"):
        masked_segment_reduce(vals, ids, ok, 2, op="median")


def test_jax_backend_pallas_minmax_matches_reference():
    """The jax backend with the Pallas kernel enabled satisfies the
    backend semantics contract on MIN/MAX (bit-exact, no carve-out)."""
    from repro.data.tables import Table
    from repro.exec.jax_backend import JaxBackend

    r = np.random.default_rng(5)
    f = r.normal(size=3000).astype(np.float32)
    f[r.random(3000) < 0.05] = np.nan
    t = Table({"k": r.integers(0, 40, 3000).astype(np.int64),
               "v": r.integers(-1000, 1000, 3000).astype(np.int32),
               "f": f})
    be = JaxBackend(use_pallas=True, interpret=True)
    got = t.group_by(["k"]).agg(("min", "v"), ("max", "v"),
                                ("min", "f"), ("max", "f"),
                                backend=be)
    want = t.group_by(["k"]).agg(("min", "v"), ("max", "v"),
                                 ("min", "f"), ("max", "f"),
                                 backend="reference")
    assert got.fingerprint() == want.fingerprint()
