"""Loop-aware HLO analyzer: validated against hand-built HLO and against
real jitted programs with KNOWN trip counts and FLOP counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import analyze_hlo, roofline_terms
from repro.roofline import hw


# ---------------------------------------------------------------------------
# synthetic HLO fragments
# ---------------------------------------------------------------------------

SYNTH = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %dot.1 = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_while_flops_multiplied():
    hc = analyze_hlo(SYNTH)
    # one 8x8x8 dot per trip, 10 trips: 2*8*8*8*10 = 10240
    assert hc.flops == pytest.approx(2 * 8 * 8 * 8 * 10)
    assert hc.dot_count == 1
    assert hc.while_trips == {"w": 10}


def test_synthetic_collectives_multiplied():
    hc = analyze_hlo(SYNTH)
    # all-reduce payload 8*8*4 bytes × 10 trips
    assert hc.collective_bytes == pytest.approx(8 * 8 * 4 * 10)
    assert hc.collective_ops == {"all-reduce": pytest.approx(2560.0)}


def test_known_trip_count_backend_config_preferred():
    hlo = SYNTH.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config='
        '{"known_trip_count":{"n":"7"}}')
    hc = analyze_hlo(hlo)
    assert hc.while_trips == {"w": 7}
    assert hc.flops == pytest.approx(2 * 8 * 8 * 8 * 7)


def test_comment_stripping_tuple_types():
    hlo = SYNTH.replace("(s32[], f32[8,8]) while",
                        "(s32[], /*index=1*/f32[8,8]) while")
    hc = analyze_hlo(hlo)
    assert hc.while_trips == {"w": 10}


# ---------------------------------------------------------------------------
# real compiled programs with known costs
# ---------------------------------------------------------------------------

def test_real_matmul_flops():
    M, K, N = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    hlo = f.lower(jnp.zeros((M, K)), jnp.zeros((K, N))).compile().as_text()
    hc = analyze_hlo(hlo)
    assert hc.flops == pytest.approx(2 * M * K * N, rel=0.01)


def test_real_scan_loop_multiplier():
    """A scan of T matmuls must report T× the FLOPs of one matmul."""
    T, D = 9, 32

    @jax.jit
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    hlo = f.lower(jnp.zeros((4, D)),
                  jnp.zeros((T, D, D))).compile().as_text()
    hc = analyze_hlo(hlo)
    assert T in hc.while_trips.values()
    assert hc.flops == pytest.approx(2 * 4 * D * D * T, rel=0.05)


def test_real_nested_scan_multiplies():
    T1, T2, D = 4, 5, 16

    @jax.jit
    def f(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.sin(x @ w), None
            x, _ = jax.lax.scan(inner, x, None, length=T2)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    hlo = f.lower(jnp.zeros((2, D)),
                  jnp.zeros((T1, D, D))).compile().as_text()
    hc = analyze_hlo(hlo)
    assert hc.flops == pytest.approx(2 * 2 * D * D * T1 * T2, rel=0.05)


def test_hbm_proxy_counts_weights_once():
    """Entry parameters (weights) are counted once per step."""
    D = 256

    @jax.jit
    def f(w, x):
        return x @ w

    hlo = f.lower(jnp.zeros((D, D)), jnp.zeros((1, D))).compile().as_text()
    hc = analyze_hlo(hlo)
    assert hc.hbm_bytes >= D * D * 4          # at least the weight read


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------

def test_roofline_term_arithmetic():
    r = roofline_terms(arch="a", shape="s", mesh="single", chips=256,
                       hlo_flops=256 * hw.PEAK_FLOPS_BF16,   # 1s compute
                       model_flops=128 * hw.PEAK_FLOPS_BF16,
                       hbm_bytes=256 * hw.HBM_BW * 0.5,      # 0.5s
                       collective_bytes=256 * hw.ICI_BW_PER_LINK * 0.25)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.collective_s == pytest.approx(0.25)
    assert r.bottleneck == "compute"
    assert r.useful_ratio == pytest.approx(0.5)


def test_roofline_bottleneck_selection():
    r = roofline_terms(arch="a", shape="s", mesh="m", chips=1,
                       hlo_flops=0.0, model_flops=0.0,
                       hbm_bytes=hw.HBM_BW * 2,
                       collective_bytes=hw.ICI_BW_PER_LINK)
    assert r.bottleneck == "memory"
