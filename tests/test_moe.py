"""MoE routing: gather-based dispatch vs a per-token brute-force oracle
(same GShard capacity-drop semantics), plus invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("granite_moe_3b")
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    B, S, d = 2, 16, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d),
                          jnp.float32).astype(jnp.bfloat16)
    return cfg, p, x


def _oracle(cfg, p, x):
    """Per-token loop with identical top-k / capacity / renorm rules."""
    m = cfg.moe
    B, S, d = x.shape
    E, k = m.num_experts, m.experts_per_token
    g = min(m.group_size, S)
    while S % g:
        g -= 1
    c = M._capacity(cfg)
    xg = np.asarray(x.astype(jnp.float32)).reshape(B, S // g, g, d)
    out = np.zeros((B, S // g, g, d), np.float32)
    for gi in range(S // g):
        xgi = xg[:, gi]
        logits = np.einsum("bgd,de->bge", xgi, np.asarray(p["router"]))
        probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
        gv_all, ei_all = jax.lax.top_k(jnp.asarray(probs), k)
        for b in range(B):
            cnt: dict[int, int] = {}
            keep = np.zeros((g, k), bool)
            for t in range(g):
                for kk in range(k):
                    e = int(ei_all[b, t, kk])
                    pos = cnt.get(e, 0)
                    cnt[e] = pos + 1
                    keep[t, kk] = pos < c
            gvb = np.asarray(gv_all[b]) * keep
            gvb = gvb / np.maximum(gvb.sum(-1, keepdims=True), 1e-9)
            for t in range(g):
                acc = np.zeros(d, np.float32)
                xe = jnp.asarray(xgi[b, t]).astype(jnp.bfloat16)
                for kk in range(k):
                    if not keep[t, kk]:
                        continue
                    e = int(ei_all[b, t, kk])
                    h = jax.nn.silu(xe @ p["experts"]["w_gate"][e]) * \
                        (xe @ p["experts"]["w_up"][e])
                    fo = (h @ p["experts"]["w_down"][e])
                    acc += gvb[t, kk] * np.asarray(fo, np.float32)
                out[b, gi, t] = acc
    return out.reshape(B, S, d)


def test_gather_dispatch_matches_oracle(setup):
    cfg, p, x = setup
    got, _aux = M.moe_forward(p, x, cfg)
    want = _oracle(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=5e-2, atol=5e-2)


def test_moe_grads_finite(setup):
    cfg, p, x = setup

    def loss(pp):
        y, aux = M.moe_forward(pp, x, cfg)
        return jnp.sum(jnp.square(y.astype(jnp.float32))) + aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_aux_loss_near_one_when_balanced(setup):
    """Shazeer load-balance loss normalizes to ~1 under balanced routing
    (E · Σ_e f_e·P_e / k with f_e ≈ k/E, P_e ≈ 1/E)."""
    cfg, p, x = setup
    _, aux = M.moe_forward(p, x, cfg)
    assert 0.8 < float(aux) < 1.5


def test_capacity_drops_are_bounded(setup):
    """With capacity_factor≥1 and uniform routing, most tokens survive:
    output norm is nonzero for nearly all positions."""
    cfg, p, x = setup
    got, _ = M.moe_forward(p, x, cfg)
    norms = np.linalg.norm(np.asarray(got, np.float32), axis=-1)
    assert (norms > 0).mean() > 0.9
