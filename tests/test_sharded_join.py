"""Sharded-backend specifics (DESIGN.md §10) beyond the differential
suite (which already runs ``sharded`` and ``auto`` through every
registered-backend case in test_exec_backends.py):

- mesh-shape cases: the same join must fingerprint identically on 1,
  2 and 8 forced host devices (subprocess-isolated like
  test_multidevice.py — the main pytest process keeps 1 CPU device);
- the Pallas hash-probe path (REPRO_HASHJOIN_PALLAS) as a backend
  configuration, not just a kernel unit;
- the stats -> backend auto-selection decision table as a pure
  function;
- cache tokens: backend switches AND mesh-shape changes must move
  engine cache keys (the float-SUM summation-order carve-out makes a
  mesh change observable, so a stale cross-mesh hit is a correctness
  bug);
- the shared numpy-fallback plumbing: 64-bit keys/values that cannot
  lower warn once, naming jax_enable_x64.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro import exec as exec_backends  # noqa: E402
from repro.data.tables import Table, col  # noqa: E402
from repro.exec.auto import choose_group_by, choose_join  # noqa: E402
from repro.exec.sharded import ShardedBackend  # noqa: E402
from repro.exec.stats import TableStats, collect_stats  # noqa: E402
from repro.kernels import fallback  # noqa: E402

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# mesh shapes (subprocess: forced host platform device count)
# ---------------------------------------------------------------------------

_MESH_BODY = """
    import numpy as np
    from repro.data.tables import Table, col

    r = np.random.default_rng(7)
    n, m = 4000, 3000
    left = Table({
        "k": r.integers(0, 500, n).astype(np.int64),
        "s": np.array([None if r.random() < 0.1 else f"u{i%7}"
                       for i in range(n)], dtype=object),
        "x": r.normal(size=n)})
    right = Table({
        "k": r.integers(0, 500, m).astype(np.int64),
        "s": np.array([None if r.random() < 0.1 else f"u{i%5}"
                       for i in range(m)], dtype=object),
        "w": r.integers(-100, 100, m).astype(np.int64)})
    for keys in (["k"], ["s"], ["k", "s"]):
        for how in ("inner", "left"):
            want = left.join(right, on=keys, how=how,
                             backend="reference").fingerprint()
            got = left.join(right, on=keys, how=how,
                            backend="sharded").fingerprint()
            assert got == want, (keys, how)
    print("MESH_JOIN ok", jax.device_count())
"""


@pytest.mark.parametrize("n_devices", [2, 8])
def test_sharded_join_matches_reference_on_mesh(n_devices):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        import jax
        assert jax.device_count() == {n_devices}, jax.devices()
    """) + textwrap.dedent(_MESH_BODY)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert f"MESH_JOIN ok {n_devices}" in r.stdout


def test_sharded_join_single_device_inprocess():
    """1-device mesh runs the full exchange+probe path in-process."""
    r = np.random.default_rng(3)
    left = Table({"k": r.integers(0, 50, 300).astype(np.int64),
                  "x": r.normal(size=300)})
    right = Table({"k": r.integers(0, 50, 200).astype(np.int64),
                   "w": r.integers(0, 9, 200).astype(np.int64)})
    for how in ("inner", "left"):
        assert (left.join(right, on=["k"], how=how,
                          backend="sharded").fingerprint()
                == left.join(right, on=["k"], how=how,
                             backend="reference").fingerprint())


def test_sharded_pallas_probe_matches_reference():
    """REPRO_HASHJOIN_PALLAS=1 configuration: the probe inner loop runs
    through the Pallas kernel (direct-address table path)."""
    be = ShardedBackend(use_pallas_probe=True)
    r = np.random.default_rng(11)
    left = Table({"k": r.integers(0, 99, 400).astype(np.int64),
                  "x": r.integers(-5, 5, 400).astype(np.int64)})
    right = Table({"k": r.integers(0, 99, 150).astype(np.int64),
                   "w": r.normal(size=150)})
    for how in ("inner", "left"):
        assert (left.join(right, on=["k"], how=how,
                          backend=be).fingerprint()
                == left.join(right, on=["k"], how=how,
                             backend="reference").fingerprint())


def test_sharded_wide_span_and_negative_keys():
    """Hash-partition mode (span past the slot budget) and rebase mode
    (negative keys) both hold the bit-for-bit contract."""
    wide_l = Table({"k": np.array([0, 2**28, 2**30, 5, -7],
                                  dtype=np.int64),
                    "l": np.arange(5, dtype=np.int64)})
    wide_r = Table({"k": np.array([2**30, 0, 2**28, 2**28, -7],
                                  dtype=np.int64),
                    "r": np.arange(5, dtype=np.int64)})
    for how in ("inner", "left"):
        assert (wide_l.join(wide_r, on=["k"], how=how,
                            backend="sharded").fingerprint()
                == wide_l.join(wide_r, on=["k"], how=how,
                               backend="reference").fingerprint())


def test_sharded_narrow_and_mixed_width_int_keys():
    """Narrow signed keys must widen to int64 before the rebase —
    native-width subtraction wraps int8 spans — and same-kind
    mixed-width keys (int16 vs int64) must not overflow casting the
    joint min into the narrow dtype (post-review regressions)."""
    l8 = Table({"k": np.array([-100, 0, 100, 50], dtype=np.int8),
                "l": np.arange(4, dtype=np.int64)})
    r8 = Table({"k": np.array([100, -100, 50], dtype=np.int8),
                "r": np.arange(3, dtype=np.int64)})
    for how in ("inner", "left"):
        assert (l8.join(r8, on=["k"], how=how,
                        backend="sharded").fingerprint()
                == l8.join(r8, on=["k"], how=how,
                           backend="reference").fingerprint())
    l16 = Table({"k": np.array([0, 5, 10], dtype=np.int16),
                 "l": np.arange(3, dtype=np.int64)})
    r64 = Table({"k": np.array([5, -100_000], dtype=np.int64),
                 "r": np.arange(2, dtype=np.int64)})
    for how in ("inner", "left"):
        assert (l16.join(r64, on=["k"], how=how,
                         backend="sharded").fingerprint()
                == l16.join(r64, on=["k"], how=how,
                            backend="reference").fingerprint())


def test_sharded_uint64_keys_past_int64_range():
    """uint64 keys whose MIN exceeds 2**63 must rebase in the native
    dtype — an int64 intermediate raised OverflowError (post-review
    regression). Small span -> slot-code path; huge span -> codes."""
    base = 2**64 - 100
    left = Table({"k": np.array([base, base + 7, base + 3],
                                dtype=np.uint64),
                  "l": np.arange(3, dtype=np.int64)})
    right = Table({"k": np.array([base + 3, base, base + 3],
                                 dtype=np.uint64),
                   "r": np.arange(3, dtype=np.int64)})
    for how in ("inner", "left"):
        assert (left.join(right, on=["k"], how=how,
                          backend="sharded").fingerprint()
                == left.join(right, on=["k"], how=how,
                             backend="reference").fingerprint())
    # span wider than int64 as well (codes path)
    wide = Table({"k": np.array([1, 2**64 - 2], dtype=np.uint64),
                  "l": np.arange(2, dtype=np.int64)})
    wide_r = Table({"k": np.array([2**64 - 2, 5], dtype=np.uint64),
                    "r": np.arange(2, dtype=np.int64)})
    assert (wide.join(wide_r, on=["k"], backend="sharded").fingerprint()
            == wide.join(wide_r, on=["k"],
                         backend="reference").fingerprint())


def test_offset_dense_keys_keep_table_mode():
    """Keys dense in a range far from zero must rebase into table mode
    (the Pallas-able direct-address path), not lose it to the
    no-rebase shortcut (post-review regression)."""
    from repro.exec.sharded import MAX_TABLE_SPAN

    r = np.random.default_rng(2)
    base = 2**30
    lcols = {"k": (base + r.integers(0, 1000, 200).astype(np.int64),
                   None)}
    rcols = {"k": (base + r.integers(0, 1000, 100).astype(np.int64),
                   None)}
    be = ShardedBackend()
    lk, rk, span = be._device_keys(lcols, rcols, ["k"])
    assert 0 < span <= MAX_TABLE_SPAN, "rebase must keep table mode"
    # and the pallas-probe configuration joins it correctly
    left = Table({"k": lcols["k"][0], "l": np.arange(200,
                                                     dtype=np.int64)})
    right = Table({"k": rcols["k"][0], "r": np.arange(100,
                                                      dtype=np.int64)})
    pb = ShardedBackend(use_pallas_probe=True)
    assert (left.join(right, on=["k"], backend=pb).fingerprint()
            == left.join(right, on=["k"],
                         backend="reference").fingerprint())


def test_sharded_right_occurrence_order_with_duplicates():
    left = Table({"k": np.array([2, 1, 2], dtype=np.int64),
                  "l": np.array([0, 1, 2], dtype=np.int64)})
    right = Table({"k": np.array([2, 1, 2], dtype=np.int64),
                   "r": np.array([20, 10, 21], dtype=np.int64)})
    j = left.join(right, on=["k"], backend="sharded")
    assert j.to_pydict() == {
        "k": [2, 2, 1, 2, 2], "l": [0, 0, 1, 2, 2],
        "r": [20, 21, 10, 20, 21]}


# ---------------------------------------------------------------------------
# auto-selection decision table
# ---------------------------------------------------------------------------

def _stats(n, kinds=("i",), card=None, span=None, lo=0):
    return TableStats(n_rows=n, key_kinds=tuple(kinds),
                      est_key_cardinality=card, int_key_span=span,
                      int_key_lo=None if span is None else lo,
                      int_key_hi=None if span is None else lo + span - 1)


def test_choose_join_decision_table():
    # tiny -> reference (per-call constants dominate)
    assert choose_join(_stats(10, span=10), _stats(5, span=5),
                       n_devices=8, sharded_available=True) \
        == "reference"
    # dense single int key -> vectorized bincount path
    assert choose_join(_stats(50_000, span=60_000),
                       _stats(50_000, span=60_000),
                       n_devices=8, sharded_available=True) \
        == "vectorized"
    # large sparse keys on a real mesh -> sharded
    assert choose_join(_stats(500_000, span=16_000_000),
                       _stats(500_000, span=16_000_000),
                       n_devices=8, sharded_available=True) \
        == "sharded"
    # same stats, single device -> stay vectorized
    assert choose_join(_stats(500_000, span=16_000_000),
                       _stats(500_000, span=16_000_000),
                       n_devices=1, sharded_available=True) \
        == "vectorized"
    # same stats, sharded unavailable -> vectorized
    assert choose_join(_stats(500_000, span=16_000_000),
                       _stats(500_000, span=16_000_000),
                       n_devices=8, sharded_available=False) \
        == "vectorized"
    # large but object keys (no span) -> sharded still handles via
    # factorized codes
    assert choose_join(_stats(500_000, kinds=("O",)),
                       _stats(500_000, kinds=("O",)),
                       n_devices=8, sharded_available=True) \
        == "sharded"
    # mid-size -> vectorized
    assert choose_join(_stats(5_000, span=10**9), _stats(5_000,
                                                         span=10**9),
                       n_devices=8, sharded_available=True) \
        == "vectorized"
    # disjoint key ranges: each side's span is tiny but the JOINT span
    # is huge — must not be routed as dense (post-review regression)
    assert choose_join(_stats(500_000, span=100_000, lo=0),
                       _stats(500_000, span=100_000, lo=10**9),
                       n_devices=8, sharded_available=True) \
        == "sharded"


def test_choose_group_by_decision_table():
    assert choose_group_by(_stats(10), np.dtype(np.int32),
                           jax_available=True) == "reference"
    assert choose_group_by(_stats(500_000), np.dtype(np.int32),
                           jax_available=True) == "jax"
    assert choose_group_by(_stats(500_000), np.dtype(np.int32),
                           jax_available=False) == "vectorized"
    # 64-bit values cannot lower without x64 -> vectorized
    if not jax.config.jax_enable_x64:
        assert choose_group_by(_stats(500_000), np.dtype(np.int64),
                               jax_available=True) == "vectorized"
    assert choose_group_by(_stats(500_000), np.dtype(object),
                           jax_available=True) == "vectorized"
    assert choose_group_by(_stats(5_000), np.dtype(np.int32),
                           jax_available=True) == "vectorized"


def test_collect_stats_shapes_the_decision():
    r = np.random.default_rng(0)
    cols = {"k": (r.integers(0, 100, 5000).astype(np.int64), None),
            "v": (r.normal(size=5000), None)}
    st = collect_stats(cols, ["k"])
    assert st.n_rows == 5000
    assert st.single_int_key
    assert st.int_key_span is not None and st.int_key_span <= 100
    assert 50 <= st.est_key_cardinality <= 100
    # NULL keys do not crash the sampler
    ks = np.array([None, "a", "b", None] * 100, dtype=object)
    st2 = collect_stats({"k": (ks, None)}, ["k"])
    assert st2.key_kinds == ("O",) and st2.est_key_cardinality == 2


def test_auto_backend_differential_and_delegation():
    r = np.random.default_rng(5)
    t = Table({"k": r.integers(0, 30, 500).astype(np.int64),
               "v": r.integers(-99, 99, 500).astype(np.int32)})
    u = Table({"k": r.integers(0, 30, 300).astype(np.int64),
               "w": r.normal(size=300)})
    assert (t.join(u, on=["k"], backend="auto").fingerprint()
            == t.join(u, on=["k"], backend="reference").fingerprint())
    assert (t.group_by_sum(["k"], "v", out="s",
                           backend="auto").fingerprint()
            == t.group_by_sum(["k"], "v", out="s",
                              backend="reference").fingerprint())


# ---------------------------------------------------------------------------
# cache tokens: backend AND mesh identity fold into engine cache keys
# ---------------------------------------------------------------------------

def test_cache_tokens_distinguish_mesh_shapes():
    one = ShardedBackend(n_devices=1)
    eight = ShardedBackend(n_devices=8)
    assert one.cache_token() != eight.cache_token()
    assert one.name == eight.name == "sharded"
    # the inherited segment-sum Pallas flag regroups float SUMs, so it
    # must move the token too (post-review regression)
    assert (ShardedBackend(n_devices=8, use_pallas=True).cache_token()
            != eight.cache_token())
    # host backends keep the bare-name token
    assert exec_backends.get_backend("vectorized").cache_token() \
        == "vectorized"
    assert exec_backends.get_backend("reference").cache_token() \
        == "reference"
    # auto's token pins policy version + thresholds + device count
    tok = exec_backends.get_backend("auto").cache_token()
    assert tok.startswith("auto[v") and "devices=" in tok


def test_engine_cache_key_moves_with_mesh_shape(monkeypatch):
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.engine import cache_key
    from repro.core.planner import plan

    Src = S.Schema.of("Src", k=int, v=int)
    Agg = S.Schema.of("Agg", k=S.Nullable[int], s=S.Nullable[int])
    p = Pipeline("mesh_fp")
    p.source("src", Src)

    @p.node()
    def agg(df: Src = "src") -> Agg:
        return df.group_by_sum(["k"], "v", out="s")

    step = plan(p).steps[0]
    snaps = {"df": "snap0"}
    keys = set()
    for ndev in (1, 2, 8):
        be = ShardedBackend(n_devices=ndev)
        monkeypatch.setattr(exec_backends, "_active", "sharded")
        monkeypatch.setitem(exec_backends._instances, "sharded", be)
        keys.add(cache_key(step, snaps))
    assert len(keys) == 3, "mesh shape must move every cache key"


# ---------------------------------------------------------------------------
# numpy-fallback plumbing (shared with the jax backend)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.config.jax_enable_x64,
                    reason="fallback only fires with x64 off")
def test_x64_fallback_warns_once_naming_the_fix():
    fallback.reset_fallback_warnings()
    huge = np.array([2**40, 3, 2**40 + 1, 2**62], dtype=np.int64)
    left = Table({"k": huge, "l": np.arange(4, dtype=np.int64)})
    right = Table({"k": huge[::-1].copy(),
                   "r": np.arange(4, dtype=np.int64)})
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = left.join(right, on=["k"], backend="sharded")
        left.join(right, on=["k"], backend="sharded")  # second call
    ours = [x for x in w
            if issubclass(x.category, fallback.NumpyFallbackWarning)]
    assert len(ours) == 1, "must warn exactly once per (op, dtype)"
    assert "jax_enable_x64" in str(ours[0].message)
    # and the fallback result is still correct
    assert got.fingerprint() == left.join(
        right, on=["k"], backend="reference").fingerprint()


@pytest.mark.skipif(jax.config.jax_enable_x64,
                    reason="fallback only fires with x64 off")
def test_jax_backend_group_by_warns_on_64bit_values():
    fallback.reset_fallback_warnings()
    t = Table({"k": np.arange(100, dtype=np.int64) % 5,
               "v": np.arange(100, dtype=np.int64)})
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        g = t.group_by_sum(["k"], "v", out="s", backend="jax")
    ours = [x for x in w
            if issubclass(x.category, fallback.NumpyFallbackWarning)]
    assert len(ours) == 1
    assert "jax_enable_x64" in str(ours[0].message)
    assert g.fingerprint() == t.group_by_sum(
        ["k"], "v", out="s", backend="reference").fingerprint()


# ---------------------------------------------------------------------------
# planner stats metadata
# ---------------------------------------------------------------------------

def test_plan_records_input_stats():
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.planner import plan

    Src = S.Schema.of("Src2", k=int, v=int)
    Out = S.Schema.of("Out2", k=int, v=int)
    p = Pipeline("stats_meta")
    p.source("src", Src)

    @p.node()
    def out(df: Src = "src") -> Out:
        return df.select([col("k"), col("v")])

    st = TableStats(n_rows=123, key_kinds=("i",),
                    est_key_cardinality=7, int_key_span=10)
    pl = plan(p, table_stats={"src": st})
    assert pl.steps[0].input_stats == {"src": st}
    assert "rows=123" in pl.describe()
    # stats are optional metadata: plans without them stay identical
    pl2 = plan(p)
    assert pl2.steps[0].input_stats is None
    assert pl2.code_hash == pl.code_hash
