"""Optimizer unit tests: pass mechanics, provenance/EXPLAIN format,
wave recomputation, cache-key discipline, elision-vs-contract
soundness, and the single-stats-collection regression (DESIGN.md §11).

Bit-for-bit *output* equivalence of rewritten plans lives in
``test_optimizer_differential.py``; this file pins the surrounding
machinery: what gets rewritten (and what must NOT), what the rewrite
records, and how the engine keys it.
"""
import dataclasses

import numpy as np
import pytest

import repro.exec.auto as auto_mod
from repro import exec as exec_backends
from repro.core import schema as S
from repro.core.catalog import Catalog
from repro.core.dag import Pipeline
from repro.core.engine import cache_key
from repro.core.logical import Join, Project, Reorder, Scan
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Expr, Table, col
from repro.exec.stats import TableStats
from repro.optimizer import DEFAULT_PASSES, PASSES, optimize

Fact = S.Schema.of("Fact", user_id=int, item_id=int, amount=float,
                   junk=float)
Users = S.Schema.of("Users", user_id=int, segment=int, bio=str)
Items = S.Schema.of("Items", item_id=int, weight=float)
Out = S.Schema.of("Out", user_id=int, amount=float, weight=float)


def _star(filter_expr=None, how="inner"):
    p = Pipeline("star")
    p.source("fact", Fact)
    p.source("users", Users)
    p.source("items", Items)
    p.sql(name="out", inputs={"f": "fact", "u": "users", "i": "items"},
          input_schemas={"f": Fact, "u": Users, "i": Items},
          output_schema=Out,
          joins=[("users", ["user_id"]), ("items", ["item_id"])],
          join_how=how,
          filter_expr=filter_expr,
          exprs=[col("user_id"), col("amount"), col("weight")])
    return p


def _stats(fact=5000, users=500, items=100):
    return {"fact": TableStats(n_rows=fact),
            "users": TableStats(n_rows=users),
            "items": TableStats(n_rows=items)}


# ---------------------------------------------------------------------------
# pass registry / plumbing
# ---------------------------------------------------------------------------

def test_default_passes_are_registered_in_order():
    assert DEFAULT_PASSES == ("filter_pushdown", "join_reorder",
                              "column_pruning", "probe_fusion",
                              "partial_agg")
    assert all(name in PASSES for name in DEFAULT_PASSES)


def test_unknown_pass_raises():
    pl = plan(_star())
    with pytest.raises(ValueError, match="unknown optimizer pass"):
        optimize(pl, passes=["filter_pushdown", "nope"])


def test_optimize_stamps_pass_list_on_plan_and_steps():
    pl = plan(_star())
    assert pl.optimizer_passes == ()
    opt = optimize(pl, passes=["probe_fusion"])
    assert opt.optimizer_passes == ("probe_fusion",)
    assert all(s.opt_passes == ("probe_fusion",) for s in opt.steps)
    # the original plan is untouched (passes are pure Plan -> Plan)
    assert pl.optimizer_passes == ()
    assert all(s.opt_passes == () for s in pl.steps)


# ---------------------------------------------------------------------------
# individual rewrites: what fires, what must not
# ---------------------------------------------------------------------------

def test_filter_pushdown_sinks_side_local_predicate():
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    opt = optimize(pl, passes=["filter_pushdown"])
    d = opt.steps[0].logical.describe()
    # pushed below both joins, onto the users side
    assert "filter((segment==3), scan(users))" in d


def test_filter_pushdown_keeps_predicate_above_left_join_right_side():
    """Right-push under a LEFT join would turn NULL-filled unmatched
    rows into dropped rows — must not fire onto the right side. (The
    *left*-push below the outer left join is legal and may still
    happen: a left-side predicate commutes with a left join.)"""
    pl = plan(_star(filter_expr=(col("segment") == 3), how="left"))
    opt = optimize(pl, passes=["filter_pushdown"])
    d = opt.steps[0].logical.describe()
    assert "filter((segment==3), scan(users))" not in d
    # the predicate still guards the fact-users join output
    assert "filter((segment==3), join(scan(fact), scan(users)" in d


def test_opaque_expression_is_never_rewritten():
    """A hand-rolled Expr has references() None: every pass must leave
    the tree alone rather than guess."""
    opaque = Expr(lambda t: (np.asarray(t.column("segment")) == 3, None),
                  "opaque")
    assert opaque.references() is None
    pl = plan(_star(filter_expr=opaque), table_stats=_stats())
    opt = optimize(pl)
    assert "filter(opaque, " in opt.steps[0].logical.describe()
    # pushdown and fusion skipped; only reorder may legally fire (it
    # does not need the predicate) — the filter itself stays put.
    assert not any("pushdown: pushed" in m or "probe_fusion" in m
                   for s in opt.steps for m in s.provenance)


def test_join_reorder_requires_stats_and_restores_order():
    pl = plan(_star())                     # no stats
    assert not any("join_reorder" in m for s in optimize(pl).steps
                   for m in s.provenance)
    pl = plan(_star(), table_stats=_stats(users=500, items=100))
    opt = optimize(pl, passes=["join_reorder"])
    tree = opt.steps[0].logical
    assert isinstance(tree, Project)
    assert isinstance(tree.child, Reorder)
    assert tree.child.order == (1, 0)      # items (100) before users (500)
    [msg] = opt.steps[0].provenance
    assert "join_reorder: order=[1, 0]" in msg
    # already-optimal chains are left alone (order would be identity)
    pl2 = plan(_star(), table_stats=_stats(users=100, items=500))
    assert not any("join_reorder" in m for s in
                   optimize(pl2, passes=["join_reorder"]).steps
                   for m in s.provenance)


def test_probe_fusion_moves_filter_into_join_pred():
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    opt = optimize(pl, passes=["filter_pushdown", "probe_fusion"])
    tree = opt.steps[0].logical
    join = tree.child            # project -> join(join(fact,users),items)
    assert isinstance(join, Join)
    inner = join.left
    assert isinstance(inner, Join)
    assert inner.right_pred is not None
    assert inner.right_pred.describe() == "(segment==3)"
    assert isinstance(inner.right, Scan)   # the Filter op is gone


def test_column_pruning_elides_dead_columns_only():
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    opt = optimize(pl, passes=["column_pruning"])
    d = opt.steps[0].logical.describe()
    # junk (fact) and bio (users) are referenced by nothing
    assert "junk" not in d and "bio" not in d
    assert "scan(fact, cols=" in d
    [msg] = opt.steps[0].provenance
    assert "'junk'" in msg and "'bio'" in msg


def test_column_pruning_keeps_contract_referenced_column():
    """Appendix-A soundness: a column no expression reads but the
    output contract resolves upstream must survive elision."""
    Src = S.Schema.of("Src", x=int, amount=int, junk=int)
    O = S.Schema.of("O", amount=int)
    p = Pipeline("lineage")
    p.source("src", Src)
    # the projected 'amount' is computed from x; the CONTRACT's
    # 'amount' column still resolves by name to src.amount.
    p.sql(name="o", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=O, exprs=[col("x").alias("amount")])
    opt = optimize(plan(p), passes=["column_pruning"])
    scan = opt.steps[0].logical.child
    assert isinstance(scan, Scan)
    assert "junk" not in scan.columns
    assert "amount" in scan.columns       # kept for the verifier
    assert "x" in scan.columns            # kept for the expression


# ---------------------------------------------------------------------------
# shared-filter materialization: aux steps + wave recomputation
# ---------------------------------------------------------------------------

Src = S.Schema.of("Src", x=int, y=int)
Half = S.Schema.of("Half", x=int, y=int)


def _shared_filter_pipeline():
    p = Pipeline("shared")
    p.source("src", Src)
    for name in ("a", "b"):
        p.sql(name=name, inputs={"s": "src"}, input_schemas={"s": Src},
              output_schema=Half, filter_expr=(col("x") > 2),
              exprs=[col("x"), col("y")])
    return p


def test_shared_filter_materializes_once_and_recomputes_waves():
    pl = plan(_shared_filter_pipeline())
    assert [s.wave for s in pl.steps] == [0, 0]
    opt = optimize(pl, passes=["filter_pushdown"])
    names = [s.node.name for s in opt.steps]
    assert names == ["__opt_shared_0", "a", "b"]
    aux, a, b = opt.steps
    assert not aux.published and a.published and b.published
    # the rewrite added a dependency level: waves were recomputed
    assert [s.wave for s in opt.steps] == [0, 1, 1]
    assert [sorted(s.node.name for s in w) for w in opt.waves] == [
        ["__opt_shared_0"], ["a", "b"]]
    # aux outputs never reach the publish set
    assert opt.output_tables == ("a", "b")
    assert opt.source_tables() == ("src",)
    # consumers now read the aux table, not src
    assert set(a.node.inputs.values()) == {"__opt_shared_0"}
    assert "(aux) " in opt.describe()


def test_shared_filter_plan_executes_and_publishes_only_consumers():
    pl = optimize(plan(_shared_filter_pipeline()),
                  passes=["filter_pushdown"])
    c = Client(Catalog())
    c.write_source_table("main", "src", Table(
        {"x": np.arange(6, dtype=np.int64),
         "y": np.arange(6, dtype=np.int64) * 10}))
    c.run(pl, "main")
    assert c.read_table("main", "a").column("x").tolist() == [3, 4, 5]
    with pytest.raises(Exception):
        c.read_table("main", "__opt_shared_0")


def test_aux_output_pruning_respects_downstream_references():
    """Second half of the elision condition: the aux step's own output
    schema shrinks only to what downstream scans + its own predicate
    read."""
    p = Pipeline("shared2")
    p.source("src", Src)
    OnlyX = S.Schema.of("OnlyX", x=int)
    for name in ("a", "b"):
        p.sql(name=name, inputs={"s": "src"}, input_schemas={"s": Src},
              output_schema=OnlyX, filter_expr=(col("x") > 2),
              exprs=[col("x")])
    opt = optimize(plan(p), passes=["filter_pushdown", "column_pruning"])
    aux = opt.steps[0]
    assert not aux.published
    assert aux.node.output_schema.names() == ["x"]   # y elided
    assert any("no downstream step or contract verifier references"
               in m for m in aux.provenance)
    c = Client(Catalog())
    c.write_source_table("main", "src", Table(
        {"x": np.arange(6, dtype=np.int64),
         "y": np.arange(6, dtype=np.int64) * 10}))
    c.run(opt, "main")
    assert c.read_table("main", "b").column("x").tolist() == [3, 4, 5]


# ---------------------------------------------------------------------------
# describe(): EXPLAIN section + stat-map truncation (exact format)
# ---------------------------------------------------------------------------

def test_describe_truncates_wide_stat_maps():
    pl = plan(_star(), table_stats=_stats())
    wide = {t: TableStats(n_rows=i + 1) for i, t in
            enumerate(["a", "b", "c", "d", "e"])}
    step = dataclasses.replace(pl.steps[0], input_stats=wide)
    pl = dataclasses.replace(pl, steps=(step,))
    d = pl.describe()
    assert "[stats: a rows=1; b rows=2; c rows=3; +2 more (of 5)]" in d
    assert "rows=4" not in d and "rows=5" not in d


def test_describe_explain_section_exact_format():
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    opt = optimize(pl, passes=["filter_pushdown", "probe_fusion"])
    lines = opt.describe().splitlines()
    assert lines[0] == f"plan star (code={pl.code_hash})"
    assert lines[-3] == ("  optimizer: passes=[filter_pushdown, "
                         "probe_fusion]; rewrites=2")
    assert lines[-2] == ("    - out: filter_pushdown: pushed filter "
                         "below join")
    assert lines[-1] == ("    - out: probe_fusion: fused 1 filter(s) "
                         "into join probe masks")
    # unoptimized plans carry no EXPLAIN section at all
    assert "optimizer:" not in pl.describe()


# ---------------------------------------------------------------------------
# cache-key discipline
# ---------------------------------------------------------------------------

def test_cache_key_folds_pass_list_and_provenance():
    snaps = {"f": "s1", "u": "s2", "i": "s3"}
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    k_plain = cache_key(pl.steps[0], snaps)
    opt_a = optimize(pl, passes=["filter_pushdown"])
    opt_b = optimize(pl, passes=["filter_pushdown", "probe_fusion"])
    k_a = cache_key(opt_a.steps[0], snaps)
    k_b = cache_key(opt_b.steps[0], snaps)
    assert len({k_plain, k_a, k_b}) == 3
    # deterministic: re-optimizing reproduces the same key
    assert cache_key(optimize(pl, passes=["filter_pushdown"]).steps[0],
                     snaps) == k_a


def test_unoptimized_cache_key_is_stable_against_feature():
    """An unoptimized plan must key exactly as before the optimizer
    existed: no opt/rewrite material sneaks into the hash."""
    pl = plan(_star())
    step = pl.steps[0]
    assert step.opt_passes == () and step.provenance == ()
    snaps = {"f": "s1", "u": "s2", "i": "s3"}
    assert cache_key(step, snaps) == cache_key(
        dataclasses.replace(step, wave=7), snaps)  # wave is not key material


def test_rewritten_tree_is_the_cache_material():
    pl = plan(_star(filter_expr=(col("segment") == 3)))
    opt = optimize(pl, passes=["filter_pushdown"])
    mat = opt.steps[0].cache_material()
    assert mat is not None and "<logical:" in mat
    assert "filter((segment==3), scan(users))" in mat


def test_non_structural_tree_is_uncacheable():
    opaque = Expr(lambda t: (np.asarray(t.column("segment")) == 3, None),
                  "opaque")
    pl = plan(_star(filter_expr=opaque))
    assert pl.steps[0].cache_material() is None
    assert cache_key(pl.steps[0], {"f": "s"}) is None


# ---------------------------------------------------------------------------
# satellite: stats are collected at most once per input
# ---------------------------------------------------------------------------

def test_auto_backend_reuses_planner_stats(monkeypatch):
    """The planner collected TableStats once; the auto backend's join
    dispatch must not re-sample the same inputs (the double-collection
    bug this PR fixes). Joins whose stats the planner could not know
    still collect exactly once per side."""
    calls = []
    real = auto_mod.collect_stats

    def counting(cols, keys=(), **kw):
        calls.append(tuple(keys))
        return real(cols, keys, **kw)

    monkeypatch.setattr(auto_mod, "collect_stats", counting)
    rng = np.random.default_rng(0)
    fact = Table({"user_id": rng.integers(0, 50, 400),
                  "item_id": rng.integers(0, 20, 400),
                  "amount": rng.normal(size=400),
                  "junk": rng.normal(size=400)})
    users = Table({"user_id": np.arange(50, dtype=np.int64),
                   "segment": (np.arange(50) % 4).astype(np.int64),
                   "bio": np.array(["u"] * 50, dtype=object)})
    items = Table({"item_id": np.arange(20, dtype=np.int64),
                   "weight": rng.normal(size=20)})

    def run(pln):
        c = Client(Catalog())
        c.write_source_table("main", "fact", fact)
        c.write_source_table("main", "users", users)
        c.write_source_table("main", "items", items)
        with exec_backends.use_backend("auto"):
            c.run(pln, "main", cache=False)

    stats = {t: auto_mod.collect_stats(tab._to_cols())
             for t, tab in [("fact", fact), ("users", users),
                            ("items", items)]}
    pl = plan(_star(), table_stats=stats)
    calls.clear()
    run(pl)
    # first join: both sides are planner-known scans -> 0 collections;
    # second join: left side is the join intermediate (planner cannot
    # know it) -> 1 collection; items scan is planner-known -> 0.
    assert len(calls) == 1

    calls.clear()
    run(plan(_star()))          # no planner stats: one per side per join
    assert len(calls) == 4
