"""Hash-probe kernel validation (kernels/hash_join).

Pallas kernel (interpret=True on this CPU container) and the XLA
gather oracle vs the numpy fallback: the probe is pure int32 in /
int32 out, so everything is bit-exact — no tolerance anywhere. Shape
sweeps cover padding on both the probe and table axes; the numpy
fallback is part of the contract (``kernels.fallback`` routes the
execution backends through it when JAX/x64 cannot serve a dtype).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.hash_join.kernel import hash_probe_kernel  # noqa: E402
from repro.kernels.hash_join.ops import (  # noqa: E402
    build_probe_table_np, hash_probe, hash_probe_np)
from repro.kernels.hash_join.ref import (  # noqa: E402
    build_probe_table, hash_probe_ref)


def _case(n_build, n_probe, table_size, seed, dup=True):
    r = np.random.default_rng(seed)
    hi = table_size if dup else min(table_size * 4, 2**30)
    slots = np.sort(r.integers(0, table_size, n_build)).astype(np.int32)
    probes = r.integers(-2, hi + 2, n_probe).astype(np.int32)
    return slots, probes


def _oracle(slots_sorted, probes, table_size):
    starts = np.zeros(len(probes), np.int32)
    counts = np.zeros(len(probes), np.int32)
    for i, p in enumerate(probes):
        if 0 <= p < table_size:
            run = np.flatnonzero(slots_sorted == p)
            if len(run):
                starts[i] = run[0]
                counts[i] = len(run)
    return starts, counts


@pytest.mark.parametrize("n_build,n_probe,table_size", [
    (200, 501, 37),      # ragged everything
    (256, 512, 64),      # exact block multiples
    (3, 5, 2),           # smaller than any block
    (0, 7, 4),           # empty build side
    (100, 0, 16),        # empty probe side
])
def test_build_and_probe_match_brute_force(n_build, n_probe,
                                           table_size):
    slots, probes = _case(n_build, n_probe, table_size, seed=n_probe)
    ts_np, tc_np = build_probe_table_np(slots, table_size)
    ts, tc = build_probe_table(jnp.asarray(slots), table_size)
    np.testing.assert_array_equal(np.asarray(ts), ts_np)
    np.testing.assert_array_equal(np.asarray(tc), tc_np)

    want_s, want_c = _oracle(slots, probes, table_size)
    for got_s, got_c in [
        hash_probe_np(ts_np, tc_np, probes),
        hash_probe_ref(jnp.asarray(ts_np), jnp.asarray(tc_np),
                       jnp.asarray(probes)),
        hash_probe_kernel(jnp.asarray(ts_np), jnp.asarray(tc_np),
                          jnp.asarray(probes), block_n=64, block_t=16,
                          interpret=True),
    ]:
        got_c = np.asarray(got_c)
        np.testing.assert_array_equal(got_c, want_c)
        # starts are only meaningful where a match exists
        hit = want_c > 0
        np.testing.assert_array_equal(np.asarray(got_s)[hit],
                                      want_s[hit])


def test_invalid_build_slots_are_dropped():
    """Out-of-range build slots (padding / other shards' key ranges)
    must not contribute to any (start, count)."""
    slots = np.array([0, 0, 2, 9, 9, -1], dtype=np.int32)
    slots = np.sort(slots)
    ts, tc = build_probe_table_np(slots, 5)
    assert tc.tolist() == [2, 0, 1, 0, 0]
    s, c = hash_probe_np(ts, tc, np.array([0, 2, 9, -1], np.int32))
    assert c.tolist() == [2, 1, 0, 0]


def test_kernel_block_shape_invariance():
    """Tiling is a perf knob: output must not depend on block sizes."""
    slots, probes = _case(777, 1234, 123, seed=3)
    ts, tc = build_probe_table_np(slots, 123)
    outs = []
    for block_n, block_t in ((32, 8), (256, 64), (1024, 512)):
        s, c = hash_probe_kernel(
            jnp.asarray(ts), jnp.asarray(tc), jnp.asarray(probes),
            block_n=block_n, block_t=block_t, interpret=True)
        outs.append((np.asarray(s), np.asarray(c)))
    for s, c in outs[1:]:
        np.testing.assert_array_equal(s, outs[0][0])
        np.testing.assert_array_equal(c, outs[0][1])


def test_ops_wrapper_dispatches_pallas_and_ref():
    slots, probes = _case(300, 700, 50, seed=4)
    ts, tc = build_probe_table_np(slots, 50)
    a = hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                   jnp.asarray(probes), use_pallas=False)
    b = hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                   jnp.asarray(probes), use_pallas=True,
                   block_n=128, block_t=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_kernel_stays_int32_under_x64_scope():
    """The sharded backend calls the probe inside an enable_x64 scope;
    the kernel's accumulators are dtype-pinned so the Pallas stores
    stay int32."""
    slots, probes = _case(100, 200, 20, seed=5)
    ts, tc = build_probe_table_np(slots, 20)
    with jax.experimental.enable_x64():
        s, c = hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                          jnp.asarray(probes), use_pallas=True,
                          block_n=64, block_t=8, interpret=True)
    want_s, want_c = hash_probe_np(ts, tc, probes)
    np.testing.assert_array_equal(np.asarray(c), want_c)
    hit = want_c > 0
    np.testing.assert_array_equal(np.asarray(s)[hit], want_s[hit])
