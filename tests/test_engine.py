"""Wave-parallel, content-addressed execution engine (DESIGN.md §8).

Covers: level scheduling in the planner; deterministic partial-output
flush when a wave fails with siblings in flight; the cache-correctness
property (same plan + same sources ⇒ identical published snapshots and
ZERO node executions on the second run); incremental re-execution after
a publication rebase (only the changed subgraph runs); cache
persistence across clients sharing one object store; and the
Appendix-A elision-soundness regression for SQL join null semantics.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.catalog import Catalog, Visibility
from repro.core.dag import Pipeline
from repro.core.engine import NodeCache, PlanExecutor, cache_key
from repro.core.errors import (CatalogError, ContractRuntimeError,
                               TransactionAborted)
from repro.core.planner import plan
from repro.core.runner import Client
from repro.core.store import FileStore, MemoryStore
from repro.data.tables import Table, col
from repro.optimizer import optimize

Src = S.Schema.of("Src", x=int)
Mid = S.Schema.of("Mid", x=int, y=int)
Total = S.Schema.of("Total", total=int)


def _source(vals=(1, 2, 3)) -> Table:
    return Table({"x": np.array(vals, dtype=np.int64)})


def _add_mid(p: Pipeline, i: int, sleep_s: float, mult: int) -> None:
    # factory so each closure gets its OWN cells (the engine folds
    # captured values into the cache key — mults must not be shared)
    @p.node(name=f"mid_{i}")
    def mid(df: Src = "src") -> Mid:
        time.sleep(sleep_s)
        return df.select([col("x"), (col("x") * mult).alias("y")])


def _diamond(*, sleeps=(0.0, 0.0, 0.0), mults=(1, 2, 3)) -> Pipeline:
    """src -> (mid_0 | mid_1 | mid_2) -> sink: one 3-wide wave + a sink."""
    p = Pipeline("diamond")
    p.source("src", Src)
    for i in range(3):
        _add_mid(p, i, sleeps[i], mults[i])

    @p.node()
    def sink(a: Mid = "mid_0", b: Mid = "mid_1", c: Mid = "mid_2") -> Total:
        total = int(a.column("y").sum() + b.column("y").sum()
                    + c.column("y").sum())
        return Table({"total": np.array([total], dtype=np.int64)})

    return p


def _client(store=None) -> Client:
    c = Client(Catalog(store=store))
    c.write_source_table("main", "src", _source())
    return c


# ---------------------------------------------------------------------------
# Wave scheduling (planner)
# ---------------------------------------------------------------------------

def test_plan_assigns_waves_by_dependency_level():
    pl = plan(_diamond())
    waves = {s.node.name: s.wave for s in pl.steps}
    assert waves == {"mid_0": 0, "mid_1": 0, "mid_2": 0, "sink": 1}
    assert [sorted(s.node.name for s in w) for w in pl.waves] == [
        ["mid_0", "mid_1", "mid_2"], ["sink"]]
    assert pl.source_tables() == ("src",)


def test_wave_parallel_run_matches_sequential_result():
    c1, c2 = _client(), _client()
    pl = plan(_diamond())
    r_par = c1.run(pl, "main", max_workers=3)
    r_seq = c2.run(pl, "main", max_workers=1, cache=False)
    assert r_par.state.status == r_seq.state.status == "committed"
    t1 = c1.read_table("main", "sink")
    t2 = c2.read_table("main", "sink")
    assert t1.fingerprint() == t2.fingerprint()
    assert t1.column("total")[0] == (1 + 2 + 3) * (1 + 2 + 3)


# ---------------------------------------------------------------------------
# Concurrent-wave failure injection: deterministic partial-output flush
# ---------------------------------------------------------------------------

def test_fail_with_siblings_mid_flight_flushes_exactly_validated():
    """fail_after on a node whose wave siblings are PROVABLY mid-flight:
    the engine drains the wave and the ABORTED branch holds exactly the
    validated outputs (all three siblings, never the sink)."""
    siblings_started = threading.Barrier(3, timeout=10)
    p = Pipeline("inflight")
    p.source("src", Src)
    for i in range(3):
        @p.node(name=f"mid_{i}")
        def mid(df: Src = "src") -> Mid:
            siblings_started.wait()   # nobody finishes until all started
            return df.select([col("x"), (col("x") * 2).alias("y")])

    @p.node()
    def sink(a: Mid = "mid_0", b: Mid = "mid_1", c: Mid = "mid_2") -> Total:
        return Table({"total": np.array([0], dtype=np.int64)})

    client = _client()
    before = client.catalog.tables("main")
    with pytest.raises(TransactionAborted) as ei:
        client.run(plan(p), "main", fail_after="mid_1", max_workers=3)
    # main untouched; ABORTED branch preserved with exactly the wave's
    # validated outputs — including the fail_after node's own output,
    # excluding the never-started sink.
    assert client.catalog.tables("main") == before
    branch = ei.value.branch
    assert client.catalog.branch_info(branch).visibility is Visibility.ABORTED
    held = set(client.catalog.tables(branch)) - set(before)
    assert held == {"mid_0", "mid_1", "mid_2"}


def test_failing_sibling_output_not_flushed():
    """A sibling that fails *validation* is excluded from the flush; its
    validated wave-mates are preserved. The flush set is a function of
    the plan, not of thread timing."""
    p = Pipeline("liar_sibling")
    p.source("src", Src)

    @p.node(name="mid_0")
    def ok_node(df: Src = "src") -> Mid:
        return df.select([col("x"), (col("x") * 2).alias("y")])

    @p.node(name="mid_1")
    def liar(df: Src = "src") -> Mid:
        return df.select([col("x")])          # missing y: fails moment 3

    @p.node(name="mid_2")
    def slow_ok(df: Src = "src") -> Mid:
        time.sleep(0.05)
        return df.select([col("x"), (col("x") * 3).alias("y")])

    client = _client()
    for _ in range(3):   # repeat: identical flush set across timings
        before = client.catalog.tables("main")
        with pytest.raises(TransactionAborted) as ei:
            client.run(plan(p), "main", max_workers=3, cache=False)
        assert isinstance(ei.value.cause, ContractRuntimeError)
        held = set(client.catalog.tables(ei.value.branch)) - set(before)
        assert held == {"mid_0", "mid_2"}


# ---------------------------------------------------------------------------
# Cache correctness: same plan + same sources ⇒ same snapshots, 0 reruns
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_property_cached_rerun_is_identical_and_free(seed):
    """Property (seeded sweep): same plan + same sources ⇒ identical
    published snapshots with ZERO node executions on the second run."""
    rng = np.random.default_rng(seed)
    vals = tuple(int(v) for v in rng.integers(-100, 100,
                                              size=rng.integers(1, 12)))
    client = Client()
    client.write_source_table("main", "src", _source(vals))
    pl = plan(_diamond())
    r1 = client.run(pl, "main")
    assert set(r1.executed) == {"mid_0", "mid_1", "mid_2", "sink"}
    log_after_first = len(client.catalog.log("main", limit=1000))

    r2 = client.run(pl, "main")
    assert r2.state.status == "committed"
    assert r2.executed == ()                      # zero node executions
    assert set(r2.cached) == set(r1.executed)
    assert dict(r2.tables) == dict(r1.tables)     # identical snapshots
    # fully-cached re-run publishes no new commit (no churn)
    assert len(client.catalog.log("main", limit=1000)) == log_after_first


def test_cache_distinguishes_changed_source_and_changed_code():
    client = _client()
    r1 = client.run(plan(_diamond()), "main")
    assert len(r1.executed) == 4

    # change ONE thing at a time: the source data...
    client.write_source_table("main", "src", _source((7, 8)))
    r2 = client.run(plan(_diamond()), "main")
    assert len(r2.executed) == 4                  # all inputs moved
    assert client.read_table("main", "sink").column("total")[0] == \
        (7 + 8) * (1 + 2 + 3)

    # ...then nothing: full hit again
    r3 = client.run(plan(_diamond()), "main")
    assert r3.executed == ()

    # ...then the code (different multipliers = different closures)
    r4 = client.run(plan(_diamond(mults=(1, 2, 4))), "main")
    assert "mid_2" in r4.executed and "sink" in r4.executed
    assert "mid_0" in r4.cached and "mid_1" in r4.cached


def test_cache_hit_still_validates_contract():
    """A cache hit must re-run validate_table for the CURRENT plan: a
    poisoned/stale snapshot cannot slip past the worker moment."""
    client = _client()
    pl = plan(_diamond())
    client.run(pl, "main")
    # poison the cache: point a hit at a snapshot violating Mid
    step = next(s for s in pl.steps if s.node.name == "mid_0")
    key = cache_key(step, {"df": client.catalog.read_table("main", "src")})
    bad = Table({"x": np.array([1], dtype=np.int64)})   # missing y
    client.node_cache.put(key, bad.to_blobs(client.store))
    with pytest.raises(TransactionAborted) as ei:
        client.run(pl, "main")
    assert isinstance(ei.value.cause, ContractRuntimeError)


def test_cache_persists_across_clients_sharing_a_store(tmp_path):
    store = FileStore(str(tmp_path))
    c1 = _client(store=store)
    r1 = c1.run(plan(_diamond()), "main")
    assert len(r1.executed) == 4

    c2 = _client(store=FileStore(str(tmp_path)))   # fresh catalog+cache
    r2 = c2.run(plan(_diamond()), "main")
    assert r2.executed == ()                       # warmed from disk
    assert c2.read_table("main", "sink").fingerprint() == \
        c1.read_table("main", "sink").fingerprint()


def test_node_cache_survives_pruned_blobs():
    store = MemoryStore()
    cache = NodeCache(store)
    cache.put("k1", "missing-snapshot")
    assert cache.lookup("k1") is None              # ref without blob: miss


def test_pruned_column_blob_recomputes_instead_of_aborting():
    """A cache entry whose manifest survived but whose column blobs were
    pruned must demote to a miss (recompute), never abort the run."""
    client = _client()
    pl = plan(_diamond())
    r1 = client.run(pl, "main")
    # prune an array blob UNIQUE to mid_1's cached output (its y = x*2;
    # content-addressing shares mid_0's y = x*1 with the source itself)
    manifest = client.store.get_json(r1.tables["mid_1"])
    del client.store._blobs[manifest["columns"]["y"]["values"]]
    r2 = client.run(pl, "main")
    assert r2.state.status == "committed"
    assert "mid_1" in r2.executed                  # recomputed, not hit
    assert "mid_0" in r2.cached and "mid_2" in r2.cached


def test_unfingerprintable_closure_capture_disables_caching():
    """A node capturing an object with only a default id-based repr can
    be mutated without changing its fingerprint — such nodes must never
    cache (stale-hit hazard), they re-execute every run."""
    class Cfg:                                     # default object repr
        scale = 2

    cfg = Cfg()
    p = Pipeline("mutable_capture")
    p.source("src", Src)

    @p.node(name="scaled")
    def scaled(df: Src = "src") -> Mid:
        return df.select([col("x"), (col("x") * cfg.scale).alias("y")])

    pl = plan(p)
    assert cache_key(pl.steps[0], {"df": "snap"}) is None
    client = _client()
    client.run(pl, "main")
    cfg.scale = 5                                  # mutate between runs
    res = client.run(pl, "main")
    assert res.executed == ("scaled",)             # not a stale hit
    assert client.read_table("main", "scaled").column("y").tolist() == \
        [5, 10, 15]


def test_stable_closure_reprs_still_cache():
    pl = plan(_diamond())                          # captures ints/floats
    for step in pl.steps:
        assert cache_key(step, {"df": "snap"}) is not None


def test_numpy_array_capture_disables_caching():
    """numpy reprs TRUNCATE (large arrays print '...'), so a captured
    array can mutate without changing any printable identity — such
    nodes must never cache."""
    weights = np.arange(2000, dtype=np.int64)
    p = Pipeline("array_capture")
    p.source("src", Src)

    @p.node(name="weighted")
    def weighted(df: Src = "src") -> Mid:
        w = int(weights.sum())
        return df.select([col("x"), (col("x") * 0 + w).alias("y")])

    pl = plan(p)
    assert cache_key(pl.steps[0], {"df": "snap"}) is None
    client = _client()
    client.run(pl, "main")
    weights[1000] = -999_999                       # repr unchanged!
    res = client.run(pl, "main")
    assert res.executed == ("weighted",)           # re-executed
    assert client.read_table("main", "weighted").column("y")[0] == \
        int(weights.sum())


# module-global data value read by the node below; mutated in-test
_GLOBAL_SCALE = 10


def test_mutated_module_global_changes_cache_key():
    """A node reading a module-global data value must fold that VALUE
    into its cache key — mutating the global used to yield a stale hit
    (only the global's NAME was fingerprinted, via co_names)."""
    global _GLOBAL_SCALE
    p = Pipeline("global_read")
    p.source("src", Src)

    @p.node(name="scaled")
    def scaled(df: Src = "src") -> Mid:
        return df.select([col("x"),
                          (col("x") * _GLOBAL_SCALE).alias("y")])

    pl = plan(p)
    client = _client()
    _GLOBAL_SCALE = 10
    client.run(pl, "main")
    _GLOBAL_SCALE = 20                             # mutate the global
    res = client.run(plan(p), "main")
    assert "scaled" in res.executed                # key moved: no hit
    assert client.read_table("main", "scaled").column("y").tolist() == \
        [20, 40, 60]
    _GLOBAL_SCALE = 10
    res2 = client.run(plan(p), "main")             # back: warm again
    assert res2.executed == ()


def _helper_rate():
    return 0.25


def test_helper_function_const_change_moves_cache_key():
    """A referenced helper's CONSTANTS are part of the fingerprint: a
    `return 0.25` -> `return 0.5` edit is co_consts-only (identical
    bytecode) and used to leave the key unchanged — a stale hit."""
    p = Pipeline("helper_read")
    p.source("src", Src)

    @p.node(name="rated")
    def rated(df: Src = "src") -> Mid:
        r = _helper_rate()
        return df.select([col("x"), (col("x") * 0 + int(r * 4)).alias("y")])

    pl = plan(p)
    k1 = cache_key(pl.steps[0], {"df": "snap"})
    global _helper_rate
    orig = _helper_rate

    def _helper_rate():                            # noqa: F811
        return 0.5
    try:
        k2 = cache_key(plan(p).steps[0], {"df": "snap"})
    finally:
        _helper_rate = orig
    assert k1 is not None and k2 is not None and k1 != k2


def test_global_read_inside_nested_lambda_is_fingerprinted():
    """Globals read only inside a nested lambda (its own co_names) must
    move the key too."""
    global _GLOBAL_SCALE
    p = Pipeline("lambda_read")
    p.source("src", Src)

    @p.node(name="thresh")
    def thresh(df: Src = "src") -> Mid:
        f = (lambda v: v * _GLOBAL_SCALE)          # noqa: E731
        return df.select([col("x"), (col("x") * 0 + f(1)).alias("y")])

    _GLOBAL_SCALE = 10
    k1 = cache_key(plan(p).steps[0], {"df": "snap"})
    _GLOBAL_SCALE = 20
    k2 = cache_key(plan(p).steps[0], {"df": "snap"})
    _GLOBAL_SCALE = 10
    assert k1 is not None and k2 is not None and k1 != k2


def test_hand_rolled_expr_makes_declarative_node_uncacheable():
    """Expr(fn, name) carries no faithful structural description: two
    different fns under one output name must not collide — such nodes
    are uncacheable (library-built expressions still cache)."""
    from repro.data.tables import Expr

    def custom(mult):
        p = Pipeline(f"custom")
        p.source("src", Src)
        p.sql(name="out_t", inputs={"s": "src"}, input_schemas={"s": Src},
              output_schema=Mid,
              exprs=[col("x"),
                     Expr(lambda t: (t.column("x") * mult, None), "y")])
        return plan(p)

    assert cache_key(custom(2).steps[0], {"s": "snap"}) is None
    # end to end: the opaque-expr node re-executes every run
    client = _client()
    client.run(custom(2), "main")
    res = client.run(custom(3), "main")            # same name, new fn
    assert res.executed == ("out_t",)
    assert client.read_table("main", "out_t").column("y").tolist() == \
        [3, 6, 9]


# ---------------------------------------------------------------------------
# Publication rebase re-executes only the changed subgraph
# ---------------------------------------------------------------------------

def _run_with_concurrent_write(client, pl, write_fn):
    """Run `pl` with a verifier that (once) moves main mid-publication,
    forcing the CAS to conflict and the run to rebase-and-revalidate."""
    fired = []

    def bump_main(_table):
        if not fired:
            fired.append(True)
            write_fn()

    return client.run(pl, "main", verifiers={"sink": [bump_main]})


def test_rebase_past_unrelated_write_reexecutes_nothing():
    client = _client()
    pl = plan(_diamond())
    res = _run_with_concurrent_write(
        client, pl,
        lambda: client.catalog.write_table("main", "unrelated", "snap"))
    assert res.state.status == "committed"
    assert res.state.publish_attempts == 2         # one CAS conflict
    assert res.rebase_reexecutions == (0,)         # O(changed subgraph)=0


def test_rebase_past_moved_source_recomputes_and_publishes_fresh():
    """A concurrent update to a SOURCE this run read forces the rebase
    to re-derive the DAG — the published outputs must reflect the NEW
    source, not the snapshots computed at begin()."""
    client = _client()              # src = (1, 2, 3)
    pl = plan(_diamond())
    res = _run_with_concurrent_write(
        client, pl,
        lambda: client.write_source_table("main", "src", _source((10,))))
    assert res.state.status == "committed"
    # every node depends (transitively) on src: full re-derivation...
    assert res.rebase_reexecutions == (4,)
    # ...and the published sink was computed from the rebased source.
    assert client.read_table("main", "sink").column("total")[0] == \
        10 * (1 + 2 + 3)
    assert res.state.final_commit == res.state.verified_head


def test_rebase_partial_subgraph_reexecution():
    """Two independent sources; only one moves mid-publication: the
    untouched source's subgraph hits the cache, the moved one re-runs."""
    p = Pipeline("two_roots")
    p.source("src", Src)
    p.source("other", Src)

    @p.node(name="from_src")
    def a(df: Src = "src") -> Mid:
        return df.select([col("x"), (col("x") * 2).alias("y")])

    @p.node(name="from_other")
    def b(df: Src = "other") -> Mid:
        return df.select([col("x"), (col("x") * 5).alias("y")])

    client = _client()
    client.write_source_table("main", "other", _source((4,)))
    fired = []

    def bump(_t):
        if not fired:
            fired.append(True)
            client.write_source_table("main", "other", _source((9,)))

    res = client.run(plan(p), "main", verifiers={"from_src": [bump]})
    assert res.state.status == "committed"
    assert res.rebase_reexecutions == (1,)         # only from_other
    assert client.read_table("main", "from_other").column("y")[0] == 45
    assert client.read_table("main", "from_src").column("y").tolist() == \
        [2, 4, 6]


# ---------------------------------------------------------------------------
# Appendix-A elision stays sound under SQL join null semantics
# ---------------------------------------------------------------------------

def test_elided_checks_sound_for_declarative_join_with_null_keys():
    """Regression for the NULL-join-key fix: a declarative join is
    null-preserving only because NULL keys match nothing. With null-keyed
    rows present in both inputs, the planner's elided NOT-NULL checks
    must hold physically — re-validated here WITHOUT elision."""
    from repro.core.contracts import validate_table

    L = S.Schema.of("L", k=S.Nullable[str], a=int)
    R = S.Schema.of("R", k=S.Nullable[str], b=int)
    J = S.Schema.of("J", k=S.Nullable[str], a=int, b=int)

    p = Pipeline("nulljoin")
    p.source("left_t", L)
    p.source("right_t", R)
    p.sql(name="joined", inputs={"l": "left_t", "r": "right_t"},
          input_schemas={"l": L, "r": R}, output_schema=J,
          exprs=[col("k"), col("a"), col("b")],
          join_with="right_t", join_on=("k",))

    pl = plan(p)
    step = pl.steps[0]
    # a and b are not-null upstream + declarative join: statically elided
    assert step.elided_null_checks == frozenset({"a", "b"})

    client = Client()
    client.write_source_table("main", "left_t", Table({
        "k": np.array([None, "x", "y"], dtype=object),
        "a": np.array([1, 2, 3], dtype=np.int64)}))
    client.write_source_table("main", "right_t", Table({
        "k": np.array([None, "x"], dtype=object),
        "b": np.array([10, 20], dtype=np.int64)}))
    res = client.run(pl, "main")
    assert res.state.status == "committed"
    out = client.read_table("main", "joined")
    # NULL keys matched nothing: only the "x" row survives
    assert out.to_pydict() == {"k": ["x"], "a": [2], "b": [20]}
    # soundness: the elided checks hold physically (validate w/o elision)
    validate_table(out, J, name="joined")
    assert not out.has_nulls("a") and not out.has_nulls("b")


# ---------------------------------------------------------------------------
# Optimizer-rewritten plans through the engine: waves + cache discipline
# ---------------------------------------------------------------------------

def _pushable_pipeline() -> Pipeline:
    D = S.Schema.of("D", x=int, tag=int)
    J = S.Schema.of("J", x=int, y=int, tag=int)
    p = Pipeline("pushable")
    p.source("src", Src)
    p.source("dim", D)
    p.sql(name="out", inputs={"s": "src", "d": "dim"},
          input_schemas={"s": Src, "d": D}, output_schema=J,
          join_with="dim", join_on=["x"],
          filter_expr=(col("tag") > 0),
          exprs=[col("x"), (col("x") * 2).alias("y"), col("tag")])
    return p


def _pushable_client() -> Client:
    c = _client()
    c.write_source_table("main", "dim", Table({
        "x": np.array([1, 2, 3, 4], dtype=np.int64),
        "tag": np.array([0, 1, 1, 0], dtype=np.int64)}))
    return c


def test_rewritten_plan_recomputes_waves_and_executes():
    """A shared-filter materialization adds a dependency level: the
    engine must schedule the aux step a wave BEFORE its consumers (not
    trust the stale plan()-time levels) and publish only consumers."""
    p = Pipeline("sharedwaves")
    p.source("src", Src)
    # consumers share the filter but differ in projection — identical
    # consumers would (correctly) also share one cache entry, which is
    # not what this test is about.
    p.sql(name="a", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=Src, filter_expr=(col("x") > 1),
          exprs=[col("x")])
    p.sql(name="b", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=Src, filter_expr=(col("x") > 1),
          exprs=[(col("x") * 2).alias("x")])
    pl = plan(p)
    assert [s.wave for s in pl.steps] == [0, 0]
    opt = optimize(pl, passes=["filter_pushdown"])
    assert [(s.node.name, s.wave) for s in opt.steps] == [
        ("__opt_shared_0", 0), ("a", 1), ("b", 1)]
    client = _client()
    res = client.run(opt, "main")
    assert res.state.status == "committed"
    # aux executed (it is a real node evaluation)…
    assert set(res.executed) == {"__opt_shared_0", "a", "b"}
    # …but never published
    assert set(res.tables) == {"a", "b"}
    assert client.read_table("main", "a").column("x").tolist() == [2, 3]


def test_cache_misses_when_optimizer_pass_list_changes():
    """Stale-hit regression: the engine cache key folds the optimizer
    pass list + provenance, so flipping passes must re-execute — even
    when a pass is a no-op on this plan — while re-running the SAME
    optimized plan stays a pure cache hit."""
    client = _pushable_client()
    pl = plan(_pushable_pipeline())
    opt1 = optimize(pl, passes=["filter_pushdown"])
    r1 = client.run(opt1, "main")
    assert r1.executed == ("out",)

    # same optimized plan again: zero executions
    r2 = client.run(optimize(plan(_pushable_pipeline()),
                             passes=["filter_pushdown"]), "main")
    assert r2.executed == () and r2.cached == ("out",)

    # different pass list that rewrites the tree further: miss
    r3 = client.run(optimize(plan(_pushable_pipeline()),
                             passes=["filter_pushdown", "probe_fusion"]),
                    "main")
    assert r3.executed == ("out",)

    # pass list whose passes happen to rewrite NOTHING here: the tree
    # matches the unoptimized plan, but the key still must move
    r4 = client.run(optimize(plan(_pushable_pipeline()),
                             passes=["join_reorder"]), "main")
    assert r4.executed == ("out",)

    # and the plain unoptimized plan keys differently from all of them
    r5 = client.run(plan(_pushable_pipeline()), "main")
    assert r5.executed == ("out",)

    # every variant is warm now: reruns of each are free
    for mk in (lambda: optimize(plan(_pushable_pipeline()),
                                passes=["filter_pushdown"]),
               lambda: plan(_pushable_pipeline())):
        r = client.run(mk(), "main")
        assert r.executed == ()
