"""Catalog.gc + quarantine release (DESIGN.md §15).

The liveness rules (live owners, grace windows, pins), the invariants
(quarantine and published ancestry survive every schedule), the
runmanifest sweep, and the concurrent-reuse race on
release_quarantined — the Fig. 4 guardrail under branch reuse.
"""
import threading

import pytest

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import (BranchNotFound, RefConflict,
                               VisibilityError)
from repro.core.store import FileStore, MemoryStore
from repro.core.transactions import RunRegistry, TransactionalRun
from repro.obs import MANIFEST_REF_PREFIX, load_manifest, store_manifest


def _txn_branch(cat, rid, tables=None):
    """Create a TXN branch with one commit, owned by run ``rid``."""
    b = f"txn/{rid}"
    cat.create_branch(b, "main", visibility=Visibility.TXN, owner_run=rid)
    for t, s in (tables or {"t": f"s@{rid}"}).items():
        cat.write_table(b, t, s, run_id=rid, _system=True)
    return b


# ---------------------------------------------------------------------------
# liveness rules
# ---------------------------------------------------------------------------

def test_gc_collects_abandoned_keeps_live():
    cat = Catalog()
    live = _txn_branch(cat, "r-live")
    dead = _txn_branch(cat, "r-dead")
    report = cat.gc(live_runs=["r-live"])
    assert dead in [n for n, _ in report.collected]
    assert live in [n for n, _ in report.kept]
    assert live in cat.branches() and dead not in cat.branches()
    reasons = dict(report.kept)
    assert "live txn" in reasons[live]


def test_gc_grace_period_protects_young_txn():
    cat = Catalog()
    b = _txn_branch(cat, "r1")
    now = cat.branch_info(b).updated_at
    report = cat.gc(live_runs=[], grace_s=60.0, now=now + 1.0)
    assert b in [n for n, _ in report.kept]
    report = cat.gc(live_runs=[], grace_s=60.0, now=now + 61.0)
    assert b in [n for n, _ in report.collected]


def test_gc_aborted_grace_then_collect_unless_pinned():
    cat = Catalog()
    b1, b2 = _txn_branch(cat, "a1"), _txn_branch(cat, "a2")
    for b in (b1, b2):
        cat.mark(b, Visibility.ABORTED, _system=True)
    now = max(cat.branch_info(b).updated_at for b in (b1, b2))
    # within the triage window both survive
    rep = cat.gc(grace_s=300.0, now=now + 10)
    assert {b1, b2} <= {n for n, _ in rep.kept}
    # past the window, the pinned one (triage in progress) survives
    pin = cat.pin(b1)
    rep = cat.gc(grace_s=300.0, now=now + 301)
    assert b1 in [n for n, _ in rep.kept]
    assert b2 in [n for n, _ in rep.collected]
    # unpinning releases it to the next pass
    cat.unpin(pin)
    rep = cat.gc(grace_s=300.0, now=now + 302)
    assert b1 in [n for n, _ in rep.collected]


def test_gc_pin_is_refcounted():
    cat = Catalog()
    b = _txn_branch(cat, "a1")
    cat.mark(b, Visibility.ABORTED, _system=True)
    pid = cat.pin(b)
    assert cat.pin(b) == pid
    cat.unpin(pid)
    assert b in [n for n, _ in cat.gc().kept]     # one ref left
    cat.unpin(pid)
    assert b in [n for n, _ in cat.gc().collected]


def test_gc_never_touches_user_quarantined_or_tags():
    cat = Catalog()
    cat.write_table("main", "t", "s0")
    cat.create_branch("feature", "main")
    cat.tag("v1", "main")
    aborted = _txn_branch(cat, "a1")
    cat.mark(aborted, Visibility.ABORTED, _system=True)
    cat.create_branch("retry", aborted, allow_reuse=True)  # QUARANTINED
    report = cat.gc()
    names = {n for n, _ in report.collected}
    assert names == {aborted}
    assert "retry" in cat.branches() and "feature" in cat.branches()
    assert cat.head("v1") is not None
    kept = dict(report.kept)
    assert "quarantined" in kept["retry"]


def test_gc_dry_run_reports_without_deleting():
    cat = Catalog()
    b = _txn_branch(cat, "r1")
    report = cat.gc(dry_run=True)
    assert b in [n for n, _ in report.collected]
    assert b in cat.branches()
    assert report.swept_manifests == () and report.swept_tmp == 0


def test_gc_preserves_pinned_commit_ancestry():
    """Commits are never deleted: a pinned commit's whole ancestry is
    readable after any GC schedule, even when the branch that produced
    it was collected."""
    cat = Catalog()
    b = _txn_branch(cat, "r1", {"x": "s1"})
    cat.write_table(b, "y", "s2", run_id="r1", _system=True)
    pinned = cat.pin(cat.head(b).id)
    cat.mark(b, Visibility.ABORTED, _system=True)
    # pinned HEAD keeps the branch; unpin, collect, then re-pin the
    # commit id directly — the metadata must still be fully walkable
    cat.unpin(pinned)
    cat.gc()
    assert b not in cat.branches()
    c = cat.commit(pinned)
    assert c.tables == {"x": "s1", "y": "s2"}
    parent = cat.commit(c.parents[0])
    assert parent.tables == {"x": "s1"}


# ---------------------------------------------------------------------------
# runmanifest sweep
# ---------------------------------------------------------------------------

def test_gc_sweeps_unreachable_manifests_only():
    store = MemoryStore()
    cat = Catalog(store)
    reachable = cat.write_table("main", "t", "s1").id
    store_manifest(store, reachable, {"run_id": "keep"})
    store_manifest(store, "deadbeef" * 3, {"run_id": "orphan"})
    report = cat.gc()
    assert report.swept_manifests == ("deadbeef" * 3,)
    assert load_manifest(store, reachable) == {"run_id": "keep"}
    assert load_manifest(store, "deadbeef" * 3) is None
    assert list(store.refs(MANIFEST_REF_PREFIX)) == [
        f"{MANIFEST_REF_PREFIX}{reachable}"]


def test_gc_keeps_manifest_reachable_only_via_pin():
    store = MemoryStore()
    cat = Catalog(store)
    cid = cat.write_table("main", "t", "s1").id
    cat.write_table("main", "t", "s2")     # head moves past cid
    store_manifest(store, cid, {"run_id": "pinned-reader"})
    pin = cat.pin(cid)
    assert cat.gc().swept_manifests == ()  # pin anchors reachability
    cat.unpin(pin)
    # cid is still an ancestor of main: reachable, still kept
    assert cat.gc().swept_manifests == ()


def test_gc_sweeps_store_tmp_through_filestore(tmp_path):
    from repro.chaos import (FaultPlan, FaultRule, InjectedCrash,
                             fault_injection)
    store = FileStore(str(tmp_path))
    cat = Catalog(store)
    plan = FaultPlan(0, (FaultRule("filestore.put.pre_replace",
                                   "crash", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            store.put(b"leak")
    report = cat.gc()
    assert report.swept_tmp == 1
    assert cat.gc(sweep_store_tmp=False).swept_tmp == 0


# ---------------------------------------------------------------------------
# end-to-end: crashed runs leave debris GC recovers
# ---------------------------------------------------------------------------

def test_gc_recovers_crashed_publication_debris():
    from repro.chaos import (FaultPlan, FaultRule, InjectedCrash,
                             fault_injection)
    cat = Catalog()
    reg = RunRegistry()
    txn = TransactionalRun(cat, "main", run_id="crasher", registry=reg)
    txn.begin()
    txn.write_tables({"t": "s@crasher"})
    plan = FaultPlan(0, (FaultRule("txn.commit.post_merge",
                                   "crash", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            txn.commit()
    # lost-ack state: published, branch dangling, registry says running
    assert cat.tables("main")["t"] == "s@crasher"
    assert txn.branch in cat.branches()
    assert reg.get_run("crasher").status == "running"
    report = cat.gc(live_runs=[])          # liveness says: dead
    assert txn.branch in [n for n, _ in report.collected]
    assert cat.tables("main")["t"] == "s@crasher"   # publication intact


# ---------------------------------------------------------------------------
# quarantine release
# ---------------------------------------------------------------------------

def _aborted_with_reuse(cat):
    b = _txn_branch(cat, "bad", {"P": "P@bad"})
    cat.mark(b, Visibility.ABORTED, _system=True)
    q = "retry"
    cat.create_branch(q, b, allow_reuse=True)
    return b, q


def test_release_quarantined_happy_path():
    cat = Catalog()
    _, q = _aborted_with_reuse(cat)
    cat.write_table(q, "C", "C@retry")
    with pytest.raises(VisibilityError):
        cat.merge(q, into="main")          # unverified: gated
    seen = []
    head = cat.release_quarantined(q, lambda read: seen.append(read("C")))
    assert seen == ["C@retry"] and head.tables["C"] == "C@retry"
    info = cat.branch_info(q)
    assert info.visibility is Visibility.USER and info.verified
    merged = cat.merge(q, into="main")
    assert merged.tables["C"] == "C@retry"
    assert merged.tables["P"] == "P@bad"   # re-legitimized BY the release


def test_release_requires_quarantined_state():
    cat = Catalog()
    cat.create_branch("feature", "main")
    with pytest.raises(VisibilityError, match="not.*quarantined"):
        cat.release_quarantined("feature", lambda read: None)
    with pytest.raises(BranchNotFound):
        cat.release_quarantined("ghost", lambda read: None)


def test_release_verifier_failure_keeps_quarantine():
    cat = Catalog()
    _, q = _aborted_with_reuse(cat)

    def bad(read):
        raise ValueError("still broken")
    with pytest.raises(ValueError, match="still broken"):
        cat.release_quarantined(q, bad)
    info = cat.branch_info(q)
    assert info.visibility is Visibility.QUARANTINED and not info.verified
    with pytest.raises(VisibilityError):
        cat.merge(q, into="main")


def test_release_concurrent_reuse_race_is_refused():
    """The Fig. 4 counterexample under reuse: a writer appends to the
    quarantined branch WHILE the verifier is running. The release must
    CAS-fail — never releasing state the verifier did not see."""
    cat = Catalog()
    _, q = _aborted_with_reuse(cat)
    cat.write_table(q, "C", "C@v1")
    in_verifier = threading.Event()
    let_finish = threading.Event()

    def slow_verifier(read):
        assert read("C") == "C@v1"
        in_verifier.set()
        assert let_finish.wait(5.0)

    def racer():
        assert in_verifier.wait(5.0)
        cat.write_table(q, "C", "C@v2")    # sneak past re-verification?
        let_finish.set()

    t = threading.Thread(target=racer)
    t.start()
    with pytest.raises(RefConflict, match="moved during re-verification"):
        cat.release_quarantined(q, slow_verifier)
    t.join()
    info = cat.branch_info(q)
    assert info.visibility is Visibility.QUARANTINED and not info.verified
    with pytest.raises(VisibilityError):
        cat.merge(q, into="main")          # v2 never became mergeable
    # re-verifying the NEW state is the sanctioned path forward
    cat.release_quarantined(q, lambda read: read("C") == "C@v2")
    assert cat.merge(q, into="main").tables["C"] == "C@v2"


def test_release_reads_are_pinned_to_captured_head():
    """The verifier's reader resolves against the head captured at
    entry — an immutable commit — even if the branch moves mid-flight;
    the release then refuses (the reader saw the OLD state)."""
    cat = Catalog()
    _, q = _aborted_with_reuse(cat)
    cat.write_table(q, "C", "C@v1")
    observed = {}

    def verifier(read):
        cat.write_table(q, "C", "C@v2")    # branch moves under us
        observed["C"] = read("C")          # reader must NOT see v2
    with pytest.raises(RefConflict):
        cat.release_quarantined(q, verifier)
    assert observed["C"] == "C@v1"
