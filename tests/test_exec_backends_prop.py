"""Hypothesis property sweep over the execution backends (§9).

Generates random nullable tables (NULL keys AND NULL values, string
and integer dtypes — the exact-equality subset of the semantics
contract) and asserts every registered backend agrees with the
``reference`` oracle bit for bit, via ``Table.fingerprint`` (which
hashes values, validity masks, and the fills in invalid lanes).

Mirrors test_tables.py: skips cleanly without hypothesis; the seeded
deterministic sweep in test_exec_backends.py runs everywhere.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property sweeps need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import exec as exec_backends
from repro.data.tables import Table

BACKENDS = exec_backends.available_backends()

keys_st = st.lists(
    st.one_of(st.none(), st.sampled_from(["a", "b", "c", "d"])),
    min_size=0, max_size=25)
vals_st = st.lists(st.one_of(st.none(), st.integers(-100, 100)),
                   min_size=0, max_size=25)


def _table(keys, vals):
    n = min(len(keys), len(vals))
    return Table({
        "k": np.array(keys[:n], dtype=object),
        "v": np.array(vals[:n], dtype=object),
        "i": np.arange(n, dtype=np.int64),
    })


@settings(max_examples=40, deadline=None)
@given(lk=keys_st, lv=vals_st, rk=keys_st, rv=vals_st,
       how=st.sampled_from(["inner", "left"]))
def test_property_join_backends_agree(lk, lv, rk, rv, how):
    from repro.data.tables import col
    left = _table(lk, lv)
    right = _table(rk, rv).select([col("k"), col("v").alias("w"),
                                   col("i").alias("j")])
    want = left.join(right, on=["k"], how=how, backend="reference")
    for b in BACKENDS:
        got = left.join(right, on=["k"], how=how, backend=b)
        assert got.fingerprint() == want.fingerprint(), (b, how)


@settings(max_examples=40, deadline=None)
@given(k=keys_st, v=vals_st,
       keyset=st.sampled_from([["k"], ["i"], ["k", "i"]]))
def test_property_group_by_backends_agree(k, v, keyset):
    t = _table(k, v)
    # i is int64 mod 3: small int groups exercise the fast path
    t = Table({"k": t.column("k"), "v": t.column("v"),
               "i": t.column("i") % 3})
    want = t.group_by_sum(keyset, "v", out="s", backend="reference")
    for b in BACKENDS:
        got = t.group_by_sum(keyset, "v", out="s", backend=b)
        assert got.fingerprint() == want.fingerprint(), (b, keyset)
    # invariant: non-NULL values sum is preserved across groups
    total = sum(x for x in t.to_pydict()["v"] if x is not None)
    got_total = sum(x for x in want.to_pydict()["s"] if x is not None)
    assert total == got_total


@settings(max_examples=30, deadline=None)
@given(k=keys_st, v=vals_st, thresh=st.integers(-100, 100))
def test_property_filter_backends_agree(k, v, thresh):
    from repro.data.tables import col, lit
    t = _table(k, v)
    want = t.filter(col("v") >= lit(thresh), backend="reference")
    for b in BACKENDS:
        got = t.filter(col("v") >= lit(thresh), backend=b)
        assert got.fingerprint() == want.fingerprint(), b
