"""Per-architecture smoke tests (deliverable f): reduced same-family
configs, one forward + one decode step + one train step on CPU, asserting
shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.configs.base import SHAPES
from repro.models import model as MDL


def _extras(cfg, B, dtype=jnp.bfloat16):
    ex = {}
    if cfg.encoder_layers:
        ex["audio_embeds"] = jnp.zeros(
            (B, cfg.num_source_positions, cfg.d_model), dtype)
    elif cfg.family == "vlm":
        ex["vision_embeds"] = jnp.zeros(
            (B, cfg.num_source_positions, cfg.d_model), dtype)
    return ex


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params = MDL.init_params(rng, cfg)
    B, S = 2, 24
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    logits, aux = MDL.forward(params, cfg, toks, **_extras(cfg, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes_finite(arch, rng):
    cfg = get_smoke_config(arch)
    params = MDL.init_params(rng, cfg)
    B = 2
    enc = None
    ex = _extras(cfg, B)
    if cfg.encoder_layers:
        enc = MDL.encode(params, cfg, ex["audio_embeds"])
    caches = MDL.init_cache(cfg, B, 32, enc_out=enc)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, caches = MDL.decode_step(params, cfg, tok, caches)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1
                         ).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng):
    from repro.training.optimizer import AdamWConfig, adamw_init
    from repro.training.train_loop import TrainConfig, make_train_step

    cfg = get_smoke_config(arch)
    params = MDL.init_params(rng, cfg)
    opt = adamw_init(params)
    B, S = 2, 16
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (B, S)).astype(np.int32)
    ex = _extras(cfg, B)
    tc = TrainConfig(remat=None, block_q=8, block_kv=8)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), tc,
                           extra_spec=dict.fromkeys(ex) if ex else None)
    new_params, new_opt, metrics = jax.jit(step)(
        params, opt, jnp.asarray(toks), jnp.asarray(toks), *ex.values())
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                     params, new_params))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """Full (dry-run) configs carry the exact published dimensions."""
    expected = {
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "phi3_vision_4b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "llama4_scout_17b": (48, 5120, 40, 8, 8192, 202048),
        "granite_moe_3b": (32, 1536, 24, 8, 512, 49155),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "phi4_mini_3b": (32, 3072, 24, 8, 8192, 200064),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
    }
    cfg = get_config(arch)
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected[arch]


def test_moe_configs():
    l4 = get_config("llama4_scout_17b")
    assert l4.moe.num_experts == 16 and l4.moe.experts_per_token == 1
    gr = get_config("granite_moe_3b")
    assert gr.moe.num_experts == 40 and gr.moe.experts_per_token == 8


def test_family_properties():
    assert get_config("recurrentgemma_9b").sub_quadratic
    assert get_config("xlstm_350m").sub_quadratic
    for a in ("minitron_8b", "whisper_medium", "phi3_vision_4b",
              "llama4_scout_17b", "granite_moe_3b", "phi3_medium_14b",
              "command_r_plus_104b", "phi4_mini_3b"):
        assert not get_config(a).sub_quadratic, a


def test_param_counts_sane():
    """Analytic N within the published ballpark (loose: ±40%)."""
    approx = {
        "phi4_mini_3b": 3.8e9, "minitron_8b": 8e9,
        "phi3_medium_14b": 14e9, "command_r_plus_104b": 104e9,
        "recurrentgemma_9b": 9e9,
        # xlstm-350m: the ASSIGNED dims (24L, d=1024, d_ff=0) give ~150M
        # analytically — the published 350M includes mLSTM expansion
        # factors the assignment does not specify.
        "xlstm_350m": 0.15e9,
        "llama4_scout_17b": 17e9 * 6,    # 16 experts: total, not active
        "whisper_medium": 0.77e9, "phi3_vision_4b": 4.2e9,
        "granite_moe_3b": 3.3e9,
    }
    for a, n in approx.items():
        got = get_config(a).num_params()
        assert 0.5 * n < got < 1.9 * n, (a, got, n)


def test_active_params_moe():
    l4 = get_config("llama4_scout_17b")
    assert l4.num_active_params() < 0.3 * l4.num_params()


def test_shapes_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288
