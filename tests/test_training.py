"""Training loop + transactional checkpoints + fault tolerance.

These integration tests run the REAL loop on the xlstm smoke config
(smallest arch) and verify the paper's properties at the training layer:
atomic checkpoint publication, restart-from-commit bitwise reproduction,
and the serving boundary's snapshot reads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs import get_smoke_config
from repro.core.catalog import Catalog, Visibility
from repro.core.errors import QualityError
from repro.data.pipeline import DataPipeline, TokenDataset
from repro.data.synthetic import markov_corpus
from repro.distributed.fault_tolerance import (FailureInjector,
                                               resilient_train)
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainConfig, train


CFG = get_smoke_config("xlstm_350m")
B, S = 4, 32


def _pipeline(seed=0):
    tokens = markov_corpus(B * S * 64, CFG.vocab_size, seed=seed)
    return DataPipeline(TokenDataset(tokens, shard_tokens=B * S * 2),
                        batch=B, seq_len=S, seed=seed)


@pytest.fixture(scope="module")
def short_run():
    catalog = Catalog()
    ckpt = CheckpointManager(catalog, branch="main")
    tc = TrainConfig(steps=8, ckpt_every=4, seed=0)
    result = train(CFG, pipeline=_pipeline(), opt_cfg=AdamWConfig(lr=1e-3),
                   tc=tc, ckpt=ckpt)
    return catalog, ckpt, result


def test_loss_decreases(short_run):
    _, _, result = short_run
    hist = result["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_checkpoints_published_transactionally(short_run):
    catalog, ckpt, _ = short_run
    head = catalog.tables("main")
    # all four artifact tables present and from single commits
    assert set(head) == {"params", "opt_state", "data_state", "metrics"}
    assert ckpt.latest_step() == 8
    # restore() reads all four artifacts from ONE commit — never a mix
    like = catalog.store.get_json(head["data_state"])
    assert like["step"] == 8
    # the previous complete checkpoint is also reachable (step 4)
    prev = [c for c in catalog.log("main")
            if c.run_id == "ckpt_4" and len(c.tables) >= 4]
    assert prev, "step-4 checkpoint commit not found"


def test_restart_resumes_and_reproduces(short_run):
    """Train 8 steps with a kill at step 5; the restarted run must
    produce the same final loss as an uninterrupted one (bitwise data
    stream thanks to the committed pipeline cursor)."""
    catalog, _, baseline = short_run

    cat2 = Catalog()
    ckpt2 = CheckpointManager(cat2, branch="main")
    tc = TrainConfig(steps=8, ckpt_every=4, seed=0)
    inj = FailureInjector(fail_at=(5,))
    result = resilient_train(
        CFG, pipeline_factory=_pipeline, opt_cfg=AdamWConfig(lr=1e-3),
        tc=tc, ckpt=ckpt2, injector=inj)
    assert inj._fired == {5}
    # restart happened: history covers steps 4..7 after resume
    assert result["history"][-1]["step"] == 7
    np.testing.assert_allclose(result["history"][-1]["loss"],
                               baseline["history"][-1]["loss"],
                               rtol=1e-5)


def test_checkpoint_rejects_nonfinite_params():
    catalog = Catalog()
    ckpt = CheckpointManager(catalog, branch="main")
    params = {"w": np.array([1.0, np.nan], np.float32)}
    with pytest.raises(QualityError):
        ckpt.save(step=1, params=params, opt_state={"m": np.zeros(2)},
                  data_state={"epoch": 0, "shard_order_seed": 0},
                  metrics={})
    # the failed save left main untouched AND an aborted branch to triage
    assert "params" not in catalog.tables("main")
    aborted = [b for b in catalog.branches()
               if catalog.branch_info(b).visibility is Visibility.ABORTED]
    assert aborted


def test_serving_reads_pinned_tag_during_training(short_run):
    """A replica pinned to a tag never sees later checkpoints."""
    catalog, ckpt, result = short_run
    cid = catalog.tag("serving/test", "main")
    like_p = jax.eval_shape(lambda: result["params"])
    # publish a NEW checkpoint on main
    ckpt.save(step=99, params=result["params"],
              opt_state=result["opt_state"],
              data_state={"epoch": 0, "shard_order_seed": 0},
              metrics={"loss": 0.0}, code="later")
    assert catalog.head("serving/test").id == cid          # still pinned
    assert ckpt.latest_step("serving/test") == 8
    assert ckpt.latest_step("main") == 99


def test_data_pipeline_deterministic_resume():
    p1 = _pipeline(seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    state3 = None
    p2 = _pipeline(seed=3)
    for i in range(3):
        p2.next_batch()
    state3 = p2.state
    # a fresh pipeline restored from the state reproduces batches 3,4
    p3 = _pipeline(seed=3)
    p3.state = state3
    for i in (3, 4):
        got = p3.next_batch()
        np.testing.assert_array_equal(got[0], batches[i][0])
        np.testing.assert_array_equal(got[1], batches[i][1])


def test_lease_queue_straggler_reassignment():
    from repro.data.pipeline import ShardLeaseQueue
    clock = {"t": 0.0}
    q = ShardLeaseQueue(3, lease_seconds=10.0, clock=lambda: clock["t"])
    s0 = q.acquire("fast")
    s1 = q.acquire("straggler")
    s2 = q.acquire("fast")
    assert {s0, s1, s2} == {0, 1, 2}
    assert q.complete("fast", s0) and q.complete("fast", s2)
    assert q.acquire("fast") is None            # nothing pending yet
    clock["t"] = 11.0                           # straggler's lease expires
    s4 = q.acquire("fast")                      # work stealing kicks in
    assert s4 == s1
    assert q.complete("fast", s4)
    assert not q.complete("straggler", s1)      # stale lease rejected
    assert q.finished


def test_grad_accumulation_matches_full_batch():
    """accum=M must produce the same update as accum=1 (same global
    batch), up to f32 accumulation order."""
    import jax.numpy as jnp
    from repro.training.train_loop import make_train_step

    cfg = CFG
    params = __import__("repro.models.model", fromlist=["m"]).init_params(
        jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (4, 16)).astype(np.int32))

    outs = {}
    for M in (1, 2, 4):
        tc = TrainConfig(remat=None, block_q=8, block_kv=8, accum=M)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), tc))
        p, o, m = step(params, opt, toks, toks)
        outs[M] = (float(m["loss"]), p)
    assert abs(outs[1][0] - outs[2][0]) < 1e-4
    assert abs(outs[1][0] - outs[4][0]) < 1e-4
    l1 = jax.tree.leaves(outs[1][1])
    l4 = jax.tree.leaves(outs[4][1])
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
