"""AST -> logical-IR compiler (DESIGN.md §13): canonical trees, schema
inference via dummy evaluation, cache-key equivalence of different
spellings, and the pinned unknown-name / shape-violation messages."""
import numpy as np
import pytest

from repro.core import schema as S
from repro.sql.compiler import compile_query
from repro.sql.errors import (SqlCompileError, edit_distance, suggest)

Users = S.Schema.of("users",
                    id=S.Column("id", S.INT64),
                    name=S.Column("name", S.STR),
                    note=S.Column("note", S.STR, nullable=True))
Orders = S.Schema.of("orders",
                     order_id=S.Column("order_id", S.INT64),
                     user_id=S.Column("user_id", S.INT64),
                     amount=S.Column("amount", S.FLOAT64),
                     status=S.Column("status", S.STR))
SCHEMAS = {"users": Users, "orders": Orders}
CTX = "ref 'main' (commit abc123)"


def compile_(q, schemas=SCHEMAS):
    return compile_query(q, name="query", schemas=schemas, context=CTX)


# --- tree shapes and canonicalization --------------------------------------

def test_simple_projection_tree():
    cq = compile_("SELECT name, id FROM users")
    assert cq.node.tree.describe() == \
        "project(['name', 'id'], scan(users))"
    assert cq.tables == ("users",)


def test_where_becomes_filter_below_project():
    cq = compile_("SELECT id FROM users WHERE id > 2")
    assert cq.node.tree.describe() == \
        "project(['id'], filter((id>2), scan(users)))"


def test_join_where_group_order_limit_tree():
    cq = compile_(
        "SELECT u.name, SUM(o.amount) AS total FROM users u "
        "JOIN orders o ON u.id = o.user_id WHERE o.amount > 10 "
        "GROUP BY u.name ORDER BY total DESC LIMIT 5")
    assert cq.node.tree.describe() == (
        "limit(5, sort(keys=['total desc'], project(['name', 'total'], "
        "aggregate(keys=['name'], specs=['sum(amount)->total'], "
        "filter((amount>10), join(scan(users), "
        "project(['user_id AS id', 'amount'], scan(orders)), "
        "on=['id'], how=inner))))))")
    assert cq.node.joins == (("orders", ("id",)),)
    assert cq.node.group_keys == ("name",)
    assert cq.node.agg_specs == (("sum", "amount", "total"),)


def test_two_spellings_share_cache_material():
    a = compile_("SELECT u.name, SUM(o.amount) AS total FROM users u "
                 "JOIN orders o ON u.id = o.user_id "
                 "GROUP BY u.name")
    b = compile_("select   users.name ,  sum( orders.amount )  total\n"
                 "from users join orders on orders.user_id = users.id\n"
                 "group by name")
    assert a.node.tree.describe() == b.node.tree.describe()
    assert a.node.cache_material() == b.node.cache_material()
    assert a.output_schema.fingerprint() == b.output_schema.fingerprint()


def test_query_text_is_not_cache_material():
    a = compile_("SELECT id FROM users")
    b = compile_("SELECT  id  FROM  users  ")
    assert a.node.query != b.node.query
    assert a.node.cache_material() == b.node.cache_material()


def test_same_named_keys_avoid_rename_project():
    # both sides spell the key 'user_id'-free: the right scan enters
    # the join unprojected, leaving join_reorder room to fire.
    X = S.Schema.of("x", k=S.Column("k", S.INT64),
                    v=S.Column("v", S.FLOAT64))
    Y = S.Schema.of("y", k=S.Column("k", S.INT64),
                    w=S.Column("w", S.FLOAT64))
    cq = compile_("SELECT v, w FROM x JOIN y ON x.k = y.k",
                  schemas={"x": X, "y": Y})
    assert cq.node.tree.describe() == (
        "project(['v', 'w'], join(scan(x), scan(y), "
        "on=['k'], how=inner))")


def test_colliding_right_columns_renamed_internally():
    X = S.Schema.of("x", k=S.Column("k", S.INT64),
                    v=S.Column("v", S.FLOAT64))
    Y = S.Schema.of("y", j=S.Column("j", S.INT64),
                    v=S.Column("v", S.FLOAT64))
    cq = compile_("SELECT x.v, y.v AS v2 FROM x JOIN y ON x.k = y.j",
                  schemas={"x": X, "y": Y})
    # y.v collides with x.v: renamed behind a right-side Project, and
    # the internal name never reaches the output contract.
    assert "__q1_v" in cq.node.tree.describe()
    assert list(cq.output_schema.columns()) == ["v", "v2"]


def test_star_expansion_merges_keys_once():
    cq = compile_("SELECT * FROM users u JOIN orders o "
                  "ON u.id = o.user_id")
    names = list(cq.output_schema.columns())
    assert names == ["id", "name", "note",
                     "order_id", "amount", "status"]


def test_qualified_star():
    cq = compile_("SELECT o.*, u.name FROM users u JOIN orders o "
                  "ON u.id = o.user_id")
    assert list(cq.output_schema.columns()) == [
        "order_id", "user_id", "amount", "status", "name"]


# --- inferred output contracts ---------------------------------------------

def test_inferred_dtypes_and_lineage():
    cq = compile_("SELECT name, id, amount * 2 AS dbl FROM users u "
                  "JOIN orders o ON u.id = o.user_id")
    cols = cq.output_schema.columns()
    assert cols["name"].dtype is S.STR
    assert cols["name"].inherited_from == "users.name"
    assert cols["id"].dtype is S.INT64
    assert cols["dbl"].dtype is S.FLOAT64
    assert cols["dbl"].inherited_from is None


def test_left_join_widens_right_nullability():
    cq = compile_("SELECT u.name, o.amount FROM users u "
                  "LEFT JOIN orders o ON u.id = o.user_id")
    cols = cq.output_schema.columns()
    assert not cols["name"].nullable
    assert cols["amount"].nullable          # right side of a LEFT join


def test_aggregate_dtype_contract():
    cq = compile_("SELECT status, SUM(amount) s, COUNT(note) c, "
                  "MIN(order_id) mn, MEAN(order_id) av "
                  "FROM orders o JOIN users u ON o.user_id = u.id "
                  "GROUP BY status")
    cols = cq.output_schema.columns()
    assert cols["s"].dtype is S.FLOAT64      # SUM keeps input dtype
    assert cols["c"].dtype is S.INT64        # COUNT is int64 ...
    assert not cols["c"].nullable            # ... and never NULL
    assert cols["mn"].dtype is S.INT64       # MIN keeps input dtype
    assert cols["av"].dtype is S.FLOAT64     # MEAN is always float64


def test_comparison_and_bool_inference():
    cq = compile_("SELECT id > 2 AS big, note IS NULL AS missing "
                  "FROM users")
    cols = cq.output_schema.columns()
    assert cols["big"].dtype is S.BOOL
    assert cols["missing"].dtype is S.BOOL
    assert not cols["missing"].nullable      # IS NULL never returns NULL


def test_unaliased_items_get_positional_names():
    cq = compile_("SELECT id + 1, name FROM users")
    assert list(cq.output_schema.columns()) == ["col0", "name"]


def test_unaliased_aggregate_gets_value_fn_name():
    cq = compile_("SELECT status, SUM(amount) FROM orders "
                  "GROUP BY status")
    assert list(cq.output_schema.columns()) == ["status", "amount_sum"]


# --- pinned error messages --------------------------------------------------

def test_unknown_table_message_format():
    with pytest.raises(SqlCompileError) as ei:
        compile_("SELECT a FROM userz")
    assert str(ei.value) == (
        "unknown table 'userz' at ref 'main' (commit abc123); "
        "did you mean 'users'? known tables: ['orders', 'users']")


def test_unknown_column_message_format():
    with pytest.raises(SqlCompileError) as ei:
        compile_("SELECT o.amnt FROM orders o")
    assert str(ei.value) == (
        "unknown column 'amnt' in table 'orders' at ref 'main' "
        "(commit abc123); did you mean 'amount'?")


def test_unknown_unqualified_column_suggests_across_scopes():
    with pytest.raises(SqlCompileError) as ei:
        compile_("SELECT nmae FROM users u JOIN orders o "
                 "ON u.id = o.user_id")
    assert "unknown column 'nmae'" in str(ei.value)
    assert "did you mean 'name'?" in str(ei.value)


def test_no_suggestion_when_nothing_is_close():
    with pytest.raises(SqlCompileError) as ei:
        compile_("SELECT zzzzzzzz FROM users")
    assert "did you mean" not in str(ei.value)


def test_unknown_qualifier():
    with pytest.raises(SqlCompileError) as ei:
        compile_("SELECT q.id FROM users u")
    assert "unknown table 'q'" in str(ei.value)


def test_ambiguous_column_requires_qualification():
    X = S.Schema.of("x", k=S.Column("k", S.INT64),
                    v=S.Column("v", S.FLOAT64))
    Y = S.Schema.of("y", j=S.Column("j", S.INT64),
                    v=S.Column("v", S.FLOAT64))
    with pytest.raises(SqlCompileError, match="ambiguous column 'v'"):
        compile_("SELECT v FROM x JOIN y ON x.k = y.j",
                 schemas={"x": X, "y": Y})


def test_on_equated_columns_are_not_ambiguous():
    cq = compile_("SELECT user_id FROM orders o JOIN users u "
                  "ON o.user_id = u.id")
    assert "user_id" in cq.output_schema.columns()


def test_duplicate_table_alias():
    with pytest.raises(SqlCompileError,
                       match="duplicate table alias 'u'"):
        compile_("SELECT 1 x FROM users u JOIN orders u ON u.id = u.id")


def test_join_must_relate_to_earlier_table():
    with pytest.raises(SqlCompileError,
                       match="must relate table 'o' to an earlier"):
        compile_("SELECT 1 x FROM users u JOIN orders o "
                 "ON u.id = u.id")


def test_aggregates_banned_in_where():
    with pytest.raises(SqlCompileError,
                       match="aggregates are not allowed in WHERE"):
        compile_("SELECT status FROM orders WHERE SUM(amount) > 1 "
                 "GROUP BY status")


def test_group_by_requires_an_aggregate():
    with pytest.raises(SqlCompileError,
                       match="GROUP BY requires at least one aggregate"):
        compile_("SELECT status FROM orders GROUP BY status")


def test_aggregate_requires_group_by():
    with pytest.raises(SqlCompileError,
                       match="aggregate SUM requires GROUP BY"):
        compile_("SELECT SUM(amount) FROM orders")


def test_nested_aggregate_rejected():
    with pytest.raises(SqlCompileError,
                       match=r"nested aggregate in SUM\(...\)"):
        compile_("SELECT SUM(MIN(amount)) FROM orders GROUP BY status")


def test_bare_column_must_be_grouped_or_aggregated():
    with pytest.raises(SqlCompileError,
                       match="must appear in GROUP BY or inside"):
        compile_("SELECT amount, SUM(order_id) s FROM orders "
                 "GROUP BY status")


def test_star_banned_with_group_by():
    with pytest.raises(SqlCompileError,
                       match=r"'\*' cannot be combined with GROUP BY"):
        compile_("SELECT *, SUM(amount) s FROM orders GROUP BY status")


def test_sum_of_string_rejected():
    with pytest.raises(SqlCompileError,
                       match="requires a numeric argument"):
        compile_("SELECT SUM(status) s FROM orders GROUP BY user_id")


def test_underscore_output_name_rejected():
    # the Schema metaclass drops '_'-prefixed names silently; the
    # compiler must refuse rather than lose a column.
    with pytest.raises(SqlCompileError,
                       match="must not start with '_'"):
        compile_("SELECT id AS _id FROM users")


def test_duplicate_output_column():
    with pytest.raises(SqlCompileError,
                       match="duplicate output column 'id'"):
        compile_("SELECT id, id FROM users")


def test_order_by_must_be_in_select_list():
    with pytest.raises(SqlCompileError,
                       match="ORDER BY column 'amount' must appear"):
        compile_("SELECT order_id FROM orders ORDER BY amount")


def test_order_by_source_column_through_alias():
    # ORDER BY u.name matches the select item that passes users.name
    # through under a different output name.
    cq = compile_("SELECT u.name AS who FROM users u ORDER BY u.name")
    assert cq.node.tree.describe() == (
        "sort(keys=['who asc'], "
        "project(['name AS who'], scan(users)))")


# --- edit distance ----------------------------------------------------------

def test_edit_distance():
    assert edit_distance("amount", "amount") == 0
    assert edit_distance("amnt", "amount") == 2
    assert edit_distance("AMOUNT", "amount") == 0   # case-insensitive
    assert edit_distance("", "abc") == 3


def test_suggest_radius_and_tiebreak():
    assert suggest("userz", ["users", "orders"]) == "users"
    assert suggest("zzzzzz", ["users", "orders"]) is None
    # ties break lexicographically for deterministic messages
    assert suggest("ac", ["ab", "aa"]) == "aa"


# --- execution sanity for the compiled node ---------------------------------

def test_compiled_node_runs_standalone():
    from repro.data.tables import Table
    cq = compile_("SELECT name FROM users WHERE id > 1")
    out = cq.node.run({"users": Table({
        "id": np.array([1, 2], dtype=np.int64),
        "name": np.array(["a", "b"], dtype=object),
        "note": np.array(["x", None], dtype=object)})})
    assert list(out.column("name")) == ["b"]
