"""Unit tests: schema authoring (paper §3.1, Listings 3 + Appendix A)."""
import datetime

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.errors import (ContractAuthoringError,
                               ContractCompositionError,
                               ContractRuntimeError)


class ParentSchema(S.Schema):  # paper Listing 3, "Node 1"
    col1: str
    col2: datetime.datetime
    _S: int


class ChildSchema(S.Schema):   # "Node 2"
    col2: datetime.datetime
    col4: float
    col5: S.Nullable[str]      # UNION(str, None)


class Grand(S.Schema):         # "Node 3"
    col2: datetime.datetime
    col4: int                  # narrowed from float


def test_annotation_columns():
    cols = ParentSchema.columns()
    assert list(cols) == ["col1", "col2", "_S"]
    assert cols["col1"].dtype == S.STR
    assert cols["col2"].dtype == S.DATETIME
    assert cols["_S"].dtype == S.INT
    assert not cols["col1"].nullable


def test_nullable_marker():
    assert ChildSchema.columns()["col5"].nullable
    assert not ChildSchema.columns()["col4"].nullable


def test_attribute_access_carries_lineage():
    col = ChildSchema.col5
    assert col.inherited_from == "ChildSchema.col5"
    assert col.nullable


def test_notnull_tag_narrows_nullability():
    col = ChildSchema.col5[S.NotNull]
    assert not col.nullable
    assert col.inherited_from == "ChildSchema.col5"


def test_appendix_a_friend_schema():
    class FriendSchema(S.Schema):      # Appendix A "Node 4"
        col2 = ChildSchema.col2
        col4 = Grand.col4
        col5 = ChildSchema.col5[S.NotNull]

    cols = FriendSchema.columns()
    assert cols["col2"].inherited_from == "ChildSchema.col2"
    assert cols["col4"].inherited_from == "Grand.col4"
    assert cols["col5"].inherited_from == "ChildSchema.col5"
    assert not cols["col5"].nullable   # explicitly narrowed


def test_schema_of_programmatic():
    Sch = S.Schema.of("MySch", a=int, b=S.Nullable[str])
    assert Sch.columns()["a"].dtype == S.INT
    assert Sch.columns()["b"].nullable


def test_fingerprint_stable_and_sensitive():
    A = S.Schema.of("A", x=int, y=float)
    B = S.Schema.of("A", x=int, y=float)
    C = S.Schema.of("A", x=int, y=str)
    assert A.fingerprint() == B.fingerprint()
    assert A.fingerprint() != C.fingerprint()


def test_unknown_column_tag_rejected():
    with pytest.raises(ContractAuthoringError):
        ChildSchema.col5["bogus"]


def test_unsupported_type_rejected():
    with pytest.raises(ContractAuthoringError):
        S.Schema.of("Bad", x=complex)


# ---------------------------------------------------------------------------
# type lattice
# ---------------------------------------------------------------------------

def test_widening_within_family():
    assert S.widenable(S.INT32, S.INT64)
    assert S.widenable(S.FLOAT32, S.FLOAT64)
    assert not S.widenable(S.INT64, S.INT32)


def test_int_widens_to_float_not_back():
    assert S.widenable(S.INT, S.FLOAT)
    assert not S.widenable(S.FLOAT, S.INT)


def test_narrowing():
    assert S.narrowable(S.FLOAT, S.INT)        # paper Listing 5 cast
    assert S.narrowable(S.INT64, S.INT32)
    assert not S.narrowable(S.INT, S.FLOAT)    # that's widening
    assert not S.narrowable(S.STR, S.INT)


def test_identity_is_both():
    assert S.widenable(S.STR, S.STR)
    assert S.narrowable(S.STR, S.STR)


# ---------------------------------------------------------------------------
# tensor contracts
# ---------------------------------------------------------------------------

def test_tensor_contract_abstract_symbols():
    import jax
    tc = S.TensorContract(("B", "S"), "int32")
    bindings = {}
    tc.validate_abstract(jax.ShapeDtypeStruct((4, 16), np.int32), bindings)
    assert bindings == {"B": 4, "S": 16}
    with pytest.raises(ContractCompositionError):
        tc.validate_abstract(jax.ShapeDtypeStruct((5, 16), np.int32),
                             bindings)   # B already bound to 4


def test_tensor_contract_dtype_and_rank():
    import jax
    tc = S.TensorContract((4,), "float32")
    with pytest.raises(ContractCompositionError):
        tc.validate_abstract(jax.ShapeDtypeStruct((4,), np.int32), {})
    with pytest.raises(ContractCompositionError):
        tc.validate_abstract(jax.ShapeDtypeStruct((4, 1), np.float32), {})


def test_tensor_contract_concrete_nan_policy():
    import jax.numpy as jnp
    tc = S.TensorContract((2,), "float32")
    tc.validate_concrete(jnp.ones(2, jnp.float32))
    with pytest.raises(ContractRuntimeError):
        tc.validate_concrete(jnp.array([1.0, jnp.nan], jnp.float32))
    ok = S.TensorContract((2,), "float32", allow_nan=True)
    ok.validate_concrete(jnp.array([1.0, jnp.nan], jnp.float32))
