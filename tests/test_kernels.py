"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

Kernels execute in ``interpret=True`` mode (CPU container; TPU is the
compile target). Tolerances: bf16 inputs accumulate in f32 inside both
kernel and oracle, so 1e-2/atol covers rounding differences.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property sweeps need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mlstm.ops import mlstm
from repro.kernels.mlstm.ref import mlstm_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref


def _qkv(key, B, H, K, Sq, Skv, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, hd), dtype)
    k = jax.random.normal(kk, (B, K, Skv, hd), dtype)
    v = jax.random.normal(kv, (B, K, Skv, hd), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,Sq,Skv,hd", [
    (1, 2, 2, 128, 128, 64),       # square causal
    (2, 4, 1, 128, 128, 32),       # MQA
    (1, 4, 2, 256, 256, 64),       # GQA 2:1
    (1, 2, 2, 96, 160, 64),        # ragged: needs padding
    (1, 1, 1, 64, 512, 128),       # long kv (prefill-like)
])
def test_flash_vs_ref_shapes(B, H, K, Sq, Skv, hd, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, K, Sq, Skv, hd, dtype)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = flash_attention_ref(
        q, jnp.repeat(k, H // K, 1), jnp.repeat(v, H // K, 1), causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 128, 511])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 256, 256, 64,
                   jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 2, 2, 128, 128, 64,
                   jnp.float32)
    got = flash_attention(q, k, v, causal=False, interpret=True)
    want = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_q,block_kv", [(64, 64), (128, 256),
                                              (256, 128)])
def test_flash_block_shape_invariance(block_q, block_kv):
    """Output must not depend on the tiling (a pure perf knob)."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 2, 256, 256, 64,
                   jnp.float32)
    got = flash_attention(q, k, v, causal=True, block_q=block_q,
                          block_kv=block_kv, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention_path():
    """The model's pure-XLA blockwise path and the Pallas kernel share
    semantics (same tile structure): cross-validate them."""
    from repro.models.layers import blockwise_attention
    q, k, v = _qkv(jax.random.PRNGKey(4), 2, 4, 2, 192, 192, 32,
                   jnp.float32)
    a = flash_attention(q, k, v, causal=True, interpret=True)
    b = blockwise_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,W", [(1, 128, 128), (2, 256, 256),
                                   (1, 384, 128), (3, 64, 512)])
def test_rglru_vs_ref(B, S, W, dtype):
    key = jax.random.PRNGKey(0)
    # a in (0,1): decay; b: input — the RG-LRU linear recurrence
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, W), dtype))
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, W), dtype)
    got = rglru_scan(a, b, interpret=True)
    want = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(2, 7), w=st.integers(1, 4), seed=st.integers(0, 99))
def test_rglru_property_linear_recurrence(s, w, seed):
    """Property: h_t = a_t * h_{t-1} + b_t exactly (vs numpy loop)."""
    S, W = s * 32, w * 128
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.1, 0.99, (1, S, W)).astype(np.float32)
    b = rng.normal(size=(1, S, W)).astype(np.float32)
    got = np.asarray(rglru_scan(jnp.asarray(a), jnp.asarray(b),
                                interpret=True))
    h = np.zeros((1, W), np.float32)
    want = np.zeros_like(b)
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        want[:, t] = h
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_rglru_block_invariance():
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(5),
                                         (1, 256, 128)))
    b = jax.random.normal(jax.random.PRNGKey(6), (1, 256, 128))
    x1 = rglru_scan(a, b, block_s=64, interpret=True)
    x2 = rglru_scan(a, b, block_s=256, interpret=True)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,hd", [(1, 128, 64), (2, 256, 32),
                                    (1, 512, 64)])
def test_mlstm_vs_ref(B, S, hd):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, hd)) / np.sqrt(hd)
    k = jax.random.normal(ks[1], (B, S, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, hd))
    log_i = -jax.nn.softplus(-jax.random.normal(ks[3], (B, S)))   # <= 0
    log_f = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S)) - 2.0)
    got = mlstm(q, k, v, log_i, log_f, chunk=64, interpret=True)
    want = mlstm_ref(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_chunk_invariance():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    B, S, hd = 1, 256, 32
    q = jax.random.normal(ks[0], (B, S, hd)) / np.sqrt(hd)
    k = jax.random.normal(ks[1], (B, S, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, hd))
    log_i = -jax.nn.softplus(-jax.random.normal(ks[3], (B, S)))
    log_f = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S)) - 2.0)
    x1 = mlstm(q, k, v, log_i, log_f, chunk=32, interpret=True)
    x2 = mlstm(q, k, v, log_i, log_f, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash custom-VJP (the training-path backward; EXPERIMENTS.md §Perf A)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,S,hd,causal,window", [
    (1, 2, 2, 128, 32, True, None),
    (2, 4, 2, 96, 32, True, None),        # GQA + ragged padding
    (1, 2, 2, 160, 32, True, 48),         # sliding window
    (1, 2, 2, 64, 32, False, None),       # non-causal (encoder)
])
def test_flash_vjp_matches_reference_grads(B, H, K, S, hd, causal, window):
    from repro.models.layers import blockwise_attention, full_attention

    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, S, hd), jnp.float32)

    def loss_blk(q, k, v):
        return jnp.sum(jnp.sin(blockwise_attention(
            q, k, v, causal=causal, window=window,
            block_q=64, block_kv=64).astype(jnp.float32)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(full_attention(
            q, k, v, causal=causal, window=window).astype(jnp.float32)))

    g1 = jax.grad(loss_blk, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_bf16_grad_barrier_casts_cotangent():
    from repro.training.train_loop import _bf16_grad_barrier

    x = jnp.ones((4,), jnp.bfloat16)
    g = jax.grad(lambda x: jnp.sum(
        _bf16_grad_barrier(x).astype(jnp.float32) * 2.0))(x)
    assert g.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(g, np.float32), 2.0)


def test_slstm_batched_recurrent_weights_grad():
    """The batch-broadcast R trick must not change sLSTM gradients."""
    from repro.configs import get_smoke_config
    from repro.models import xlstm as X

    cfg = get_smoke_config("xlstm_350m")
    p = X.slstm_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, _ = X.slstm_forward(p, x, cfg)
        return jnp.sum(jnp.square(y.astype(jnp.float32)))

    g = jax.grad(loss)(p)
    # numerical check on a few scalar entries of R
    eps = 1e-3
    for idx in [(0, 0, 0, 0), (1, 1, 2, 3)]:
        pp = jax.tree.map(jnp.array, p)
        r = pp["r"].at[idx].add(eps)
        lp = loss(dict(pp, r=r))
        r = pp["r"].at[idx].add(-eps)
        lm = loss(dict(pp, r=r))
        num = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(float(g["r"][idx]), float(num),
                                   rtol=5e-2, atol=5e-2)
