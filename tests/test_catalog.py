"""Git-for-data semantics (paper §3.2, Listings 6–8) + visibility fix."""
import pytest

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import (BranchExists, BranchNotFound, CatalogError,
                               MergeConflict, RefConflict, VisibilityError)


@pytest.fixture
def cat():
    return Catalog()


def test_initial_state_single_branch_root_commit(cat):
    assert cat.branches() == ["main"]
    head = cat.head("main")
    assert head.tables == {}
    assert head.parents == ()


def test_write_table_advances_head_and_links_parent(cat):
    before = cat.head("main")
    c = cat.write_table("main", "parent_table", "snap1")
    assert cat.head("main").id == c.id
    assert c.parents == (before.id,)
    assert c.tables == {"parent_table": "snap1"}


def test_zero_copy_branch_shares_commits(cat):
    cat.write_table("main", "t", "s1")
    cat.create_branch("feature", "main")
    assert cat.head("feature").id == cat.head("main").id
    # writing to the branch does not move main (logical isolation)
    cat.write_table("feature", "t", "s2")
    assert cat.read_table("main", "t") == "s1"
    assert cat.read_table("feature", "t") == "s2"


def test_branch_name_collision(cat):
    cat.create_branch("dev", "main")
    with pytest.raises(BranchExists):
        cat.create_branch("dev", "main")


def test_tag_is_immutable_pin(cat):
    cat.write_table("main", "t", "s1")
    cid = cat.tag("v1", "main")
    cat.write_table("main", "t", "s2")
    assert cat.head("v1").id == cid
    assert cat.read_table("v1", "t") == "s1"      # pinned
    assert cat.read_table("main", "t") == "s2"


def test_fast_forward_merge(cat):
    cat.write_table("main", "t", "s1")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "s2")
    merged = cat.merge("f", into="main")
    assert cat.head("main").id == merged.id
    assert cat.read_table("main", "t") == "s2"
    # fast-forward: no new commit object created (head == f's head)
    assert cat.head("f").id == merged.id


def test_three_way_merge_disjoint_tables(cat):
    cat.write_table("main", "a", "a0")
    cat.write_table("main", "b", "b0")
    cat.create_branch("f", "main")
    cat.write_table("f", "a", "a1")
    cat.write_table("main", "b", "b1")     # main moved: not a FF
    m = cat.merge("f", into="main")
    assert len(m.parents) == 2
    assert cat.read_table("main", "a") == "a1"
    assert cat.read_table("main", "b") == "b1"


def test_merge_conflict_same_table(cat):
    cat.write_table("main", "t", "s0")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "left")
    cat.write_table("main", "t", "right")
    with pytest.raises(MergeConflict):
        cat.merge("f", into="main")


def test_merge_noop_when_source_behind(cat):
    cat.write_table("main", "t", "s0")
    cat.create_branch("f", "main")
    cat.write_table("main", "t", "s1")
    head = cat.head("main")
    assert cat.merge("f", into="main").id == head.id


def test_optimistic_cas_on_write(cat):
    h = cat.head("main").id
    cat.write_table("main", "t", "s1")     # another writer wins the race
    with pytest.raises(RefConflict):
        cat.write_table("main", "t", "s2", expected_head=h)


def test_with_retry_recovers_from_conflict(cat):
    attempts = []

    def op():
        attempts.append(1)
        if len(attempts) < 3:
            raise RefConflict("simulated")
        return cat.write_table("main", "t", "s")

    c = cat.with_retry(op)
    assert c.tables["t"] == "s"
    assert len(attempts) == 3


def test_log_and_diff(cat):
    cat.write_table("main", "a", "a0")
    cat.write_table("main", "b", "b0")
    log = cat.log("main")
    assert [c.message for c in log[:2]] == ["write b", "write a"]
    cat.create_branch("f", "main")
    cat.write_table("f", "a", "a1")
    assert cat.diff("main", "f") == {"a": ("a0", "a1")}


def test_delete_branch_guards(cat):
    with pytest.raises(CatalogError):
        cat.delete_branch("main")
    with pytest.raises(BranchNotFound):
        cat.delete_branch("ghost")


def test_read_missing_table(cat):
    with pytest.raises(CatalogError):
        cat.read_table("main", "nope")


# ---------------------------------------------------------------------------
# Visibility classes — the Fig. 4 guardrail (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _aborted_branch(cat):
    cat.write_table("main", "P", "p0")
    cat.create_branch("txn/r1", "main", visibility=Visibility.TXN,
                      owner_run="r1")
    cat.write_table("txn/r1", "P", "p1", _system=True)
    cat.mark("txn/r1", Visibility.ABORTED, _system=True)
    return "txn/r1"


def test_user_cannot_write_live_txn_branch(cat):
    cat.create_branch("txn/r9", "main", visibility=Visibility.TXN,
                      owner_run="r9")
    with pytest.raises(VisibilityError):
        cat.write_table("txn/r9", "t", "s")          # not _system
    cat.write_table("txn/r9", "t", "s", _system=True)


def test_aborted_branch_is_readable_not_mergeable(cat):
    b = _aborted_branch(cat)
    assert cat.read_table(b, "P") == "p1"            # debugging read OK
    with pytest.raises(VisibilityError, match="aborted"):
        cat.merge(b, into="main")                    # Fig. 4 prevented
    with pytest.raises(VisibilityError):
        cat.write_table(b, "P", "p2")                # frozen


def test_branch_from_aborted_requires_allow_reuse(cat):
    b = _aborted_branch(cat)
    with pytest.raises(VisibilityError, match="allow_reuse"):
        cat.create_branch("retry", b)
    cat.create_branch("retry", b, allow_reuse=True)
    assert cat.branch_info("retry").visibility is Visibility.QUARANTINED


def test_quarantined_merge_blocked_until_verified(cat):
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    cat.write_table("retry", "C", "c-fixed")
    with pytest.raises(VisibilityError, match="quarantined"):
        cat.merge("retry", into="main")
    # after re-verification the idempotent-re-run optimization is legal
    cat.mark("retry", Visibility.QUARANTINED, verified=True)
    cat.merge("retry", into="main")
    assert cat.read_table("main", "C") == "c-fixed"
    assert cat.read_table("main", "P") == "p1"       # reused parent


def test_quarantine_is_contagious(cat):
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    with pytest.raises(VisibilityError):
        cat.create_branch("retry2", "retry")         # still quarantined
    cat.create_branch("retry2", "retry", allow_reuse=True)
    assert cat.branch_info("retry2").visibility is Visibility.QUARANTINED


# ---------------------------------------------------------------------------
# Laundering by raw commit id / tag (the visibility-bypass regression)
# ---------------------------------------------------------------------------

def test_merge_aborted_head_by_commit_id_refused(cat):
    """Regression: merging the ABORTED branch's raw COMMIT ID used to
    skip every src_info visibility check and republish the partial run."""
    b = _aborted_branch(cat)
    cid = cat.head(b).id
    with pytest.raises(VisibilityError, match="republish"):
        cat.merge(cid, into="main")
    assert cat.read_table("main", "P") == "p0"       # main untouched


def test_merge_live_txn_head_by_commit_id_refused(cat):
    cat.create_branch("txn/live", "main", visibility=Visibility.TXN,
                      owner_run="r2")
    cat.write_table("txn/live", "Q", "q-uncommitted", _system=True)
    cid = cat.head("txn/live").id
    with pytest.raises(VisibilityError):
        cat.merge(cid, into="main")


def test_merge_tag_of_aborted_head_refused(cat):
    """A tag on an aborted head must not legitimize it."""
    b = _aborted_branch(cat)
    cat.tag("triage-pin", b)
    with pytest.raises(VisibilityError):
        cat.merge("triage-pin", into="main")


def test_merge_published_commit_id_still_allowed(cat):
    """Commits reachable from USER branches stay mergeable by id."""
    cat.write_table("main", "t", "s1")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "s2")
    cid = cat.head("f").id
    merged = cat.merge(cid, into="main")
    assert cat.head("main").id == merged.id
    assert cat.read_table("main", "t") == "s2"


# ---------------------------------------------------------------------------
# delete_branch / mark privilege holes
# ---------------------------------------------------------------------------

def test_delete_live_txn_branch_requires_system(cat):
    cat.create_branch("txn/r5", "main", visibility=Visibility.TXN,
                      owner_run="r5")
    with pytest.raises(VisibilityError, match="live transactional"):
        cat.delete_branch("txn/r5")                  # mid-run delete
    cat.delete_branch("txn/r5", _system=True)
    assert "txn/r5" not in cat.branches()


def test_delete_aborted_branch_requires_system(cat):
    b = _aborted_branch(cat)
    with pytest.raises(VisibilityError, match="triage"):
        cat.delete_branch(b)                         # preserved per §3.3
    cat.delete_branch(b, _system=True)


def test_mark_cannot_unabort_without_system(cat):
    b = _aborted_branch(cat)
    with pytest.raises(VisibilityError, match="un-marking"):
        cat.mark(b, Visibility.USER)                 # laundering attempt
    # system (e.g. an operator tool) may still do it explicitly
    cat.mark(b, Visibility.USER, _system=True)
    assert cat.branch_info(b).visibility is Visibility.USER


def test_mark_cannot_release_unverified_quarantine(cat):
    """Regression: flipping an UNVERIFIED quarantined branch to USER
    would skip the merge gate entirely."""
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    with pytest.raises(VisibilityError, match="unverified"):
        cat.mark("retry", Visibility.USER)
    # after re-verification, releasing is the sanctioned path
    cat.mark("retry", Visibility.QUARANTINED, verified=True)
    cat.mark("retry", Visibility.USER)
    cat.merge("retry", into="main")


def test_merge_tag_of_deleted_aborted_branch_refused(cat):
    """Regression: once the aborted branch is cleaned up, its head is
    reachable only via the tag — still not publishable."""
    b = _aborted_branch(cat)
    cat.tag("triage-pin", b)
    cat.delete_branch(b, _system=True)
    with pytest.raises(VisibilityError, match="not reachable"):
        cat.merge("triage-pin", into="main")


def test_mark_live_txn_branch_requires_system(cat):
    cat.create_branch("txn/r6", "main", visibility=Visibility.TXN,
                      owner_run="r6")
    with pytest.raises(VisibilityError):
        cat.mark("txn/r6", Visibility.USER)
    # QUARANTINED re-verification stays user-facing (DESIGN.md §6)
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    cat.mark("retry", Visibility.QUARANTINED, verified=True)  # no _system
    assert cat.branch_info("retry").verified


# ---------------------------------------------------------------------------
# write_tables: the multi-table atomic commit
# ---------------------------------------------------------------------------

def test_write_tables_single_commit(cat):
    before = cat.head("main")
    c = cat.write_tables("main", {"a": "a0", "b": "b0", "c": "c0"},
                         message="one run")
    assert cat.head("main").id == c.id
    assert c.parents == (before.id,)
    assert c.tables == {"a": "a0", "b": "b0", "c": "c0"}
    # exactly ONE commit was appended for three tables
    assert [x.id for x in cat.log("main")] == [c.id, before.id]


def test_write_tables_empty_is_noop(cat):
    head = cat.head("main")
    assert cat.write_tables("main", {}).id == head.id
    assert cat.head("main").id == head.id


def test_write_tables_cas(cat):
    h = cat.head("main").id
    cat.write_table("main", "t", "s1")
    with pytest.raises(RefConflict):
        cat.write_tables("main", {"a": "a0"}, expected_head=h)


# ---------------------------------------------------------------------------
# rebase: replay changes onto a new base (DESIGN.md §7)
# ---------------------------------------------------------------------------

def test_rebase_replays_changes_onto_new_head(cat):
    cat.write_table("main", "p", "p0")
    cat.create_branch("f", "main")
    cat.write_table("f", "x", "x1")
    cat.write_table("main", "p", "p1")               # main moved
    new_head = cat.head("main").id
    c = cat.rebase("f", new_head)
    assert c.parents == (new_head,)
    assert c.tables == {"p": "p1", "x": "x1"}
    assert cat.head("f").id == c.id
    # now a CAS merge against new_head fast-forwards
    merged = cat.merge("f", into="main", expected_head=new_head)
    assert merged.id == c.id


def test_rebase_conflict(cat):
    cat.write_table("main", "t", "t0")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "left")
    cat.write_table("main", "t", "right")
    with pytest.raises(MergeConflict):
        cat.rebase("f", cat.head("main").id)


def test_rebase_no_changes_fast_forwards(cat):
    cat.create_branch("f", "main")
    cat.write_table("main", "t", "t1")
    head = cat.head("main").id
    c = cat.rebase("f", head)
    assert c.id == head
    assert cat.head("f").id == head
