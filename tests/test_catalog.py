"""Git-for-data semantics (paper §3.2, Listings 6–8) + visibility fix."""
import pytest

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import (BranchExists, BranchNotFound, CatalogError,
                               MergeConflict, RefConflict, VisibilityError)


@pytest.fixture
def cat():
    return Catalog()


def test_initial_state_single_branch_root_commit(cat):
    assert cat.branches() == ["main"]
    head = cat.head("main")
    assert head.tables == {}
    assert head.parents == ()


def test_write_table_advances_head_and_links_parent(cat):
    before = cat.head("main")
    c = cat.write_table("main", "parent_table", "snap1")
    assert cat.head("main").id == c.id
    assert c.parents == (before.id,)
    assert c.tables == {"parent_table": "snap1"}


def test_zero_copy_branch_shares_commits(cat):
    cat.write_table("main", "t", "s1")
    cat.create_branch("feature", "main")
    assert cat.head("feature").id == cat.head("main").id
    # writing to the branch does not move main (logical isolation)
    cat.write_table("feature", "t", "s2")
    assert cat.read_table("main", "t") == "s1"
    assert cat.read_table("feature", "t") == "s2"


def test_branch_name_collision(cat):
    cat.create_branch("dev", "main")
    with pytest.raises(BranchExists):
        cat.create_branch("dev", "main")


def test_tag_is_immutable_pin(cat):
    cat.write_table("main", "t", "s1")
    cid = cat.tag("v1", "main")
    cat.write_table("main", "t", "s2")
    assert cat.head("v1").id == cid
    assert cat.read_table("v1", "t") == "s1"      # pinned
    assert cat.read_table("main", "t") == "s2"


def test_fast_forward_merge(cat):
    cat.write_table("main", "t", "s1")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "s2")
    merged = cat.merge("f", into="main")
    assert cat.head("main").id == merged.id
    assert cat.read_table("main", "t") == "s2"
    # fast-forward: no new commit object created (head == f's head)
    assert cat.head("f").id == merged.id


def test_three_way_merge_disjoint_tables(cat):
    cat.write_table("main", "a", "a0")
    cat.write_table("main", "b", "b0")
    cat.create_branch("f", "main")
    cat.write_table("f", "a", "a1")
    cat.write_table("main", "b", "b1")     # main moved: not a FF
    m = cat.merge("f", into="main")
    assert len(m.parents) == 2
    assert cat.read_table("main", "a") == "a1"
    assert cat.read_table("main", "b") == "b1"


def test_merge_conflict_same_table(cat):
    cat.write_table("main", "t", "s0")
    cat.create_branch("f", "main")
    cat.write_table("f", "t", "left")
    cat.write_table("main", "t", "right")
    with pytest.raises(MergeConflict):
        cat.merge("f", into="main")


def test_merge_noop_when_source_behind(cat):
    cat.write_table("main", "t", "s0")
    cat.create_branch("f", "main")
    cat.write_table("main", "t", "s1")
    head = cat.head("main")
    assert cat.merge("f", into="main").id == head.id


def test_optimistic_cas_on_write(cat):
    h = cat.head("main").id
    cat.write_table("main", "t", "s1")     # another writer wins the race
    with pytest.raises(RefConflict):
        cat.write_table("main", "t", "s2", expected_head=h)


def test_with_retry_recovers_from_conflict(cat):
    attempts = []

    def op():
        attempts.append(1)
        if len(attempts) < 3:
            raise RefConflict("simulated")
        return cat.write_table("main", "t", "s")

    c = cat.with_retry(op)
    assert c.tables["t"] == "s"
    assert len(attempts) == 3


def test_log_and_diff(cat):
    cat.write_table("main", "a", "a0")
    cat.write_table("main", "b", "b0")
    log = cat.log("main")
    assert [c.message for c in log[:2]] == ["write b", "write a"]
    cat.create_branch("f", "main")
    cat.write_table("f", "a", "a1")
    assert cat.diff("main", "f") == {"a": ("a0", "a1")}


def test_delete_branch_guards(cat):
    with pytest.raises(CatalogError):
        cat.delete_branch("main")
    with pytest.raises(BranchNotFound):
        cat.delete_branch("ghost")


def test_read_missing_table(cat):
    with pytest.raises(CatalogError):
        cat.read_table("main", "nope")


# ---------------------------------------------------------------------------
# Visibility classes — the Fig. 4 guardrail (DESIGN.md §6)
# ---------------------------------------------------------------------------

def _aborted_branch(cat):
    cat.write_table("main", "P", "p0")
    cat.create_branch("txn/r1", "main", visibility=Visibility.TXN,
                      owner_run="r1")
    cat.write_table("txn/r1", "P", "p1", _system=True)
    cat.mark("txn/r1", Visibility.ABORTED)
    return "txn/r1"


def test_user_cannot_write_live_txn_branch(cat):
    cat.create_branch("txn/r9", "main", visibility=Visibility.TXN,
                      owner_run="r9")
    with pytest.raises(VisibilityError):
        cat.write_table("txn/r9", "t", "s")          # not _system
    cat.write_table("txn/r9", "t", "s", _system=True)


def test_aborted_branch_is_readable_not_mergeable(cat):
    b = _aborted_branch(cat)
    assert cat.read_table(b, "P") == "p1"            # debugging read OK
    with pytest.raises(VisibilityError, match="aborted"):
        cat.merge(b, into="main")                    # Fig. 4 prevented
    with pytest.raises(VisibilityError):
        cat.write_table(b, "P", "p2")                # frozen


def test_branch_from_aborted_requires_allow_reuse(cat):
    b = _aborted_branch(cat)
    with pytest.raises(VisibilityError, match="allow_reuse"):
        cat.create_branch("retry", b)
    cat.create_branch("retry", b, allow_reuse=True)
    assert cat.branch_info("retry").visibility is Visibility.QUARANTINED


def test_quarantined_merge_blocked_until_verified(cat):
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    cat.write_table("retry", "C", "c-fixed")
    with pytest.raises(VisibilityError, match="quarantined"):
        cat.merge("retry", into="main")
    # after re-verification the idempotent-re-run optimization is legal
    cat.mark("retry", Visibility.QUARANTINED, verified=True)
    cat.merge("retry", into="main")
    assert cat.read_table("main", "C") == "c-fixed"
    assert cat.read_table("main", "P") == "p1"       # reused parent


def test_quarantine_is_contagious(cat):
    b = _aborted_branch(cat)
    cat.create_branch("retry", b, allow_reuse=True)
    with pytest.raises(VisibilityError):
        cat.create_branch("retry2", "retry")         # still quarantined
    cat.create_branch("retry2", "retry", allow_reuse=True)
    assert cat.branch_info("retry2").visibility is Visibility.QUARANTINED
