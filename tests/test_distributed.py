"""Distribution layer: sharding rules, elastic resharding, gradient
compression (error feedback), pipeline parallelism, serving loop.

All on the single CPU device (1x1 meshes) — semantics, not placement,
is what these tests pin down; placement is proven by the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.elastic import param_spec, params_sharding, reshard
from repro.distributed.grad_compression import (compressed_psum_pod,
                                                dequantize_int8,
                                                quantize_int8)
from repro.distributed.sharding import (AxisRules, DECODE_RULES, FSDP_RULES,
                                        TRAIN_RULES, lshard, make_rules,
                                        safe_spec, use_rules)
from repro.launch.mesh import make_host_mesh


# ---------------------------------------------------------------------------
# logical axis rules
# ---------------------------------------------------------------------------

def test_rules_resolve_known_axes():
    mesh = make_host_mesh(1, 1)
    r = AxisRules(TRAIN_RULES, mesh)
    spec = r.resolve("batch", "seq", "embed")
    assert spec == P(("data",), None, None)   # pod dropped: not in mesh


def test_rules_drop_missing_mesh_axes():
    mesh = make_host_mesh(1, 1)           # no 'pod' axis
    r = AxisRules(TRAIN_RULES, mesh)
    assert r.resolve("batch") == P(("data",))
    r2 = AxisRules(TRAIN_RULES, None)
    assert r2.resolve("batch") == P(("pod", "data"))


def test_fsdp_rules_extend_train_rules():
    assert FSDP_RULES["p_embed"] == ("data",)
    assert TRAIN_RULES["p_embed"] is None
    assert DECODE_RULES["kv_seq"] == "model"


def test_make_rules_seq_parallel():
    r = make_rules("train", None, seq_parallel=True)
    assert r.rules["seq"] == "model"
    r2 = make_rules("train", None)
    assert r2.rules["seq"] is None


def test_lshard_noop_without_rules():
    x = jnp.ones((4, 4))
    with use_rules(None):
        assert lshard(x, "batch", None) is x


def test_safe_spec_drops_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate a 16-way axis via a fake mesh dict — use the real one:
    spec = safe_spec(P("model", None), (7, 4), mesh)   # 7 % 1 == 0: kept
    assert spec == P("model", None)


def test_param_spec_heuristics():
    rules = AxisRules(TRAIN_RULES, None)
    leaf2 = jax.ShapeDtypeStruct((64, 128), jnp.float32)

    class FakeKey:
        def __init__(self, key):
            self.key = key

    spec = param_spec((FakeKey("embed"),), leaf2, rules)
    assert spec == P("model", None)
    spec = param_spec((FakeKey("mix"), FakeKey("wq")), leaf2, rules)
    assert spec == P(None, "model")           # column-parallel
    spec = param_spec((FakeKey("ffn"), FakeKey("w_down")), leaf2, rules)
    assert spec == P("model", None)           # row-parallel


def test_param_spec_expert_fallback_nondivisible():
    """40 experts on a 16-way model axis: EP falls back to intra-expert
    TP (the granite fix)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = AxisRules(TRAIN_RULES, mesh)
    # divisible case on the 1-wide axis: EP kept
    leaf = jax.ShapeDtypeStruct((40, 64, 128), jnp.float32)

    class K:
        def __init__(self, key):
            self.key = key

    spec = param_spec((K("experts"), K("w_up")), leaf, rules)
    assert spec == P("model", None, None)


# ---------------------------------------------------------------------------
# elastic rescaling
# ---------------------------------------------------------------------------

def test_reshard_roundtrip_preserves_values():
    from repro.configs import get_smoke_config
    from repro.models import model as MDL

    cfg = get_smoke_config("phi4_mini_3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    mesh_a = make_host_mesh(1, 1)
    rules = make_rules("train", mesh_a)
    placed = reshard(params, mesh_a, rules)
    # values unchanged by placement
    a = jax.tree.leaves(params)[3]
    b = jax.tree.leaves(placed)[3]
    np.testing.assert_array_equal(np.asarray(a, np.float32),
                                  np.asarray(b, np.float32))
    # re-placing onto a "different" mesh (same devices, new object) works
    mesh_b = make_host_mesh(1, 1)
    placed2 = reshard(placed, mesh_b, make_rules("decode", mesh_b))
    np.testing.assert_array_equal(np.asarray(b, np.float32),
                                  np.asarray(jax.tree.leaves(placed2)[3],
                                             np.float32))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = quantize_int8(x)
    back = dequantize_int8(q.astype(jnp.int32), scale, x.size, x.shape)
    err = np.abs(np.asarray(back - x))
    # per-block max error <= scale/2 ≈ max|x|/254 per block
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 127.0


def test_compressed_psum_no_pod_axis_passthrough():
    mesh = make_host_mesh(1, 1)
    grads = {"w": jnp.ones((8, 8))}
    red, err = compressed_psum_pod(grads, mesh)
    np.testing.assert_array_equal(np.asarray(red["w"]),
                                  np.asarray(grads["w"]))


def test_compressed_psum_error_feedback_accumulates():
    """Property: with error feedback, the quantization residual is
    carried — repeated reductions of the same gradient converge to the
    true mean (error does not accumulate unboundedly)."""
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(256,)).astype(np.float32))}
    err = None
    acc = np.zeros(256, np.float32)
    T = 8
    for t in range(T):
        red, err = compressed_psum_pod(g, mesh, error=err)
        acc += np.asarray(red["w"])
        # the carried residual itself stays bounded by one quant step
        assert float(jnp.max(jnp.abs(err["w"]))) <= \
            float(jnp.max(jnp.abs(g["w"]))) / 64.0
    # CUMULATIVE transmitted gradient tracks the true sum to within one
    # quantization step — the error-feedback guarantee (it does not grow
    # with T, unlike naive quantization whose bias is O(T)).
    cum_err = np.max(np.abs(acc - T * np.asarray(g["w"])))
    one_step = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert cum_err <= 2 * one_step


# ---------------------------------------------------------------------------
# pipeline parallelism (1-stage degenerate + algebraic check)
# ---------------------------------------------------------------------------

def test_pipeline_forward_single_stage_identity():
    from repro.distributed.pipeline_parallel import pipeline_forward
    mesh = jax.make_mesh((1,), ("pipe",))
    params = {"w": jnp.full((1, 4), 2.0)}     # leading dim = stages

    def stage(p, x):
        return x * p["w"]

    x = jnp.arange(8.0).reshape(2, 4)
    y = pipeline_forward(stage, params, x, mesh=mesh, num_microbatches=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x * 2.0))


# ---------------------------------------------------------------------------
# serving loop
# ---------------------------------------------------------------------------

def test_serve_loop_continuous_batching():
    from repro.configs import get_smoke_config
    from repro.models import model as MDL
    from repro.serving.serve_loop import Request, ServeLoop

    cfg = get_smoke_config("xlstm_350m")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    loop = ServeLoop(cfg, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 5).astype(
                        np.int32),
                    max_new=4) for i in range(5)]
    for r in reqs:
        loop.submit(r)
    loop.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab_size for r in reqs for t in r.out)


def test_serving_decode_matches_forward():
    """Teacher-forced decode over a prompt produces the same logits as a
    single forward pass (cache correctness)."""
    from repro.configs import get_smoke_config
    from repro.models import model as MDL

    cfg = get_smoke_config("phi4_mini_3b")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _ = MDL.forward(params, cfg, toks)
    caches = MDL.init_cache(cfg, B, 32)
    outs = []
    for t in range(S):
        lg, caches = MDL.decode_step(params, cfg, toks[:, t:t + 1], caches)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_fp8_kv_cache_decode_accuracy():
    """fp8 KV storage (decode default in the dry-run) must preserve
    greedy decoding: teacher-forced decode vs full forward, argmax
    agreement 100% on the smoke config."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import model as MDL

    cfg = get_smoke_config("phi4_mini_3b")
    cfg8 = dataclasses.replace(cfg, kv_dtype="float8_e4m3fn")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = MDL.forward(params, cfg, toks)
    caches = MDL.init_cache(cfg8, B, 32)
    outs = []
    for t in range(S):
        lg, caches = MDL.decode_step(params, cfg8, toks[:, t:t + 1],
                                     caches)
        outs.append(lg[:, 0])
    dec = np.asarray(jnp.stack(outs, 1), np.float32)
    ref = np.asarray(full, np.float32)
    assert np.abs(dec - ref).max() < 0.25
    np.testing.assert_array_equal(dec.argmax(-1), ref.argmax(-1))
