"""Masked hash-probe kernel validation (kernels/hash_join).

The masked probe is the fused ``filter_select``-into-join primitive of
the optimizer's probe-fusion rewrite: probe rows whose mask is 0 must
report ``count == 0`` (and a zeroed start) exactly as if they had been
filtered out before probing — but without ever materializing the
filtered probe side; in the Pallas kernel the mask rides into VMEM
beside the probe slots and the dropped rows never leave it. Mirrors
``test_hash_join_kernel.py``: brute-force oracle parity across shape
sweeps (padding on both axes), block-shape invariance, the ops-level
dispatch contract (numpy fallback == XLA ref == Pallas kernel,
bit-exact int32), plus the mask-specific edges: all-filtered,
none-filtered, and mask values beyond {0, 1}.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.hash_join.kernel import (  # noqa: E402
    masked_hash_probe_kernel)
from repro.kernels.hash_join.ops import (  # noqa: E402
    build_probe_table_np, hash_probe_np, masked_hash_probe,
    masked_hash_probe_np)
from repro.kernels.hash_join.ref import masked_hash_probe_ref  # noqa: E402


def _case(n_build, n_probe, table_size, seed):
    r = np.random.default_rng(seed)
    slots = np.sort(r.integers(0, table_size, n_build)).astype(np.int32)
    probes = r.integers(-2, table_size + 2, n_probe).astype(np.int32)
    mask = (r.random(n_probe) < 0.6).astype(np.int32)
    return slots, probes, mask


def _oracle(slots_sorted, probes, mask, table_size):
    """Filter-then-probe, row by row: the semantics being fused."""
    starts = np.zeros(len(probes), np.int32)
    counts = np.zeros(len(probes), np.int32)
    for i, p in enumerate(probes):
        if mask[i] and 0 <= p < table_size:
            run = np.flatnonzero(slots_sorted == p)
            if len(run):
                starts[i] = run[0]
                counts[i] = len(run)
    return starts, counts


def _all_impls(ts, tc, probes, mask):
    return [
        masked_hash_probe_np(ts, tc, probes, mask),
        masked_hash_probe_ref(jnp.asarray(ts), jnp.asarray(tc),
                              jnp.asarray(probes), jnp.asarray(mask)),
        masked_hash_probe_kernel(jnp.asarray(ts), jnp.asarray(tc),
                                 jnp.asarray(probes), jnp.asarray(mask),
                                 block_n=64, block_t=16, interpret=True),
    ]


@pytest.mark.parametrize("n_build,n_probe,table_size", [
    (200, 501, 37),      # ragged everything
    (256, 512, 64),      # exact block multiples
    (3, 5, 2),           # smaller than any block
    (0, 7, 4),           # empty build side
    (100, 0, 16),        # empty probe side
])
def test_masked_probe_matches_brute_force(n_build, n_probe, table_size):
    slots, probes, mask = _case(n_build, n_probe, table_size,
                                seed=n_probe)
    ts, tc = build_probe_table_np(slots, table_size)
    want_s, want_c = _oracle(slots, probes, mask, table_size)
    for got_s, got_c in _all_impls(ts, tc, probes, mask):
        got_s, got_c = np.asarray(got_s), np.asarray(got_c)
        np.testing.assert_array_equal(got_c, want_c)
        hit = want_c > 0
        np.testing.assert_array_equal(got_s[hit], want_s[hit])
        # masked-off rows must read as a clean miss, not stale state
        off = mask == 0
        assert not got_c[off].any()
        assert not got_s[off].any()


@pytest.mark.parametrize("fill", [0, 1])
def test_degenerate_masks(fill):
    """none-filtered (mask all 1) must equal the unmasked probe;
    all-filtered (mask all 0) must return all-zero outputs."""
    slots, probes, _ = _case(300, 700, 50, seed=9)
    ts, tc = build_probe_table_np(slots, 50)
    mask = np.full(len(probes), fill, dtype=np.int32)
    if fill:
        want_s, want_c = hash_probe_np(ts, tc, probes)
        # unmasked probe may leave starts nonzero on miss rows; the
        # masked contract zeroes them — compare on hits + counts.
        hit = want_c > 0
    else:
        want_s = want_c = np.zeros(len(probes), np.int32)
        hit = want_c > 0
    for got_s, got_c in _all_impls(ts, tc, probes, mask):
        np.testing.assert_array_equal(np.asarray(got_c), want_c)
        np.testing.assert_array_equal(np.asarray(got_s)[hit],
                                      want_s[hit])


def test_mask_is_truthiness_not_equality():
    """Any nonzero mask value keeps the row (the backends hand in
    bool-derived int32, but the kernel contract is mask != 0)."""
    slots = np.sort(np.array([1, 1, 3], np.int32))
    ts, tc = build_probe_table_np(slots, 5)
    probes = np.array([1, 1, 3, 3], np.int32)
    mask = np.array([2, 0, -7, 0], np.int32)
    for s, c in _all_impls(ts, tc, probes, mask):
        assert np.asarray(c).tolist() == [2, 0, 1, 0]


def test_kernel_block_shape_invariance():
    """Tiling is a perf knob: output must not depend on block sizes."""
    slots, probes, mask = _case(777, 1234, 123, seed=3)
    ts, tc = build_probe_table_np(slots, 123)
    outs = []
    for block_n, block_t in ((32, 8), (256, 64), (1024, 512)):
        s, c = masked_hash_probe_kernel(
            jnp.asarray(ts), jnp.asarray(tc), jnp.asarray(probes),
            jnp.asarray(mask), block_n=block_n, block_t=block_t,
            interpret=True)
        outs.append((np.asarray(s), np.asarray(c)))
    for s, c in outs[1:]:
        np.testing.assert_array_equal(s, outs[0][0])
        np.testing.assert_array_equal(c, outs[0][1])


def test_ops_wrapper_dispatches_pallas_and_ref():
    slots, probes, mask = _case(300, 700, 50, seed=4)
    ts, tc = build_probe_table_np(slots, 50)
    a = masked_hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                          jnp.asarray(probes), jnp.asarray(mask),
                          use_pallas=False)
    b = masked_hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                          jnp.asarray(probes), jnp.asarray(mask),
                          use_pallas=True, block_n=128, block_t=32,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_kernel_stays_int32_under_x64_scope():
    """The sharded backend calls the masked probe inside an enable_x64
    scope; accumulators and the mask slab are dtype-pinned int32."""
    slots, probes, mask = _case(100, 200, 20, seed=5)
    ts, tc = build_probe_table_np(slots, 20)
    with jax.experimental.enable_x64():
        s, c = masked_hash_probe(jnp.asarray(ts), jnp.asarray(tc),
                                 jnp.asarray(probes), jnp.asarray(mask),
                                 use_pallas=True, block_n=64, block_t=8,
                                 interpret=True)
    want_s, want_c = masked_hash_probe_np(ts, tc, probes, mask)
    np.testing.assert_array_equal(np.asarray(c), want_c)
    hit = want_c > 0
    np.testing.assert_array_equal(np.asarray(s)[hit], want_s[hit])
