"""Multi-device semantics on 8 forced host devices (subprocess-isolated:
the main pytest process must keep seeing 1 CPU device).

These are the strongest CPU-side checks of large-scale runnability:
numerical EQUALITY between the sharded and single-device programs, real
elastic rescaling across mesh shapes, and a real pipeline-parallel run.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8, timeout: int = 420) -> str:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n}"
        import jax
        assert jax.device_count() == {n}, jax.devices()
        import numpy as np
        import jax.numpy as jnp
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """A TP+DP train step on a (2,2,2) pod/data/model mesh produces the
    same loss and parameters as the unsharded single-device step."""
    run_with_devices("""
        from repro.configs import get_smoke_config
        from repro.distributed.sharding import make_rules, use_rules
        from repro.launch.specs import safe_params_sharding, _with_rules
        from repro.models import model as MDL
        from repro.training.optimizer import AdamWConfig, adamw_init
        from repro.training.train_loop import TrainConfig, make_train_step
        from jax.sharding import NamedSharding

        cfg = get_smoke_config("phi4_mini_3b")
        params = MDL.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (4, 32)).astype(np.int32))
        tc = TrainConfig(remat=None, block_q=16, block_kv=16)
        step = make_train_step(cfg, AdamWConfig(lr=1e-3), tc)

        # reference: single-device jit
        p1, o1, m1 = jax.jit(step)(params, opt, toks, toks)

        # sharded: (pod,data,model) = (2,2,2)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = make_rules("train", mesh, seq_parallel=True)
        with use_rules(rules):
            psh = safe_params_sharding(params, mesh, rules)
            osh = safe_params_sharding(opt, mesh, rules)
            tsh = NamedSharding(mesh, rules.resolve("batch", None))
        with mesh:
            jitted = jax.jit(_with_rules(step, rules),
                             in_shardings=(psh, osh, tsh, tsh))
            p2, o2, m2 = jitted(params, opt, toks, toks)

        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \\
            (float(m1["loss"]), float(m2["loss"]))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=5e-2, atol=5e-3)
        print("SHARDED_MATCHES_SINGLE ok")
    """)


def test_elastic_rescale_8_to_4_to_2():
    """Restore the same logical params onto shrinking meshes (losing a
    'pod'), continuing with identical forward results — the paper's
    partial-vs-total-failure upgrade applied to cluster capacity."""
    run_with_devices("""
        from repro.configs import get_smoke_config
        from repro.distributed.elastic import reshard
        from repro.distributed.sharding import make_rules
        from repro.models import model as MDL

        cfg = get_smoke_config("xlstm_350m")
        params = MDL.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.zeros((4, 16), jnp.int32)
        ref, _ = MDL.forward(params, cfg, toks)
        ref = np.asarray(ref, np.float32)

        host = jax.tree.map(np.asarray, params)
        for shape, axes in (((2, 2, 2), ("pod", "data", "model")),
                            ((2, 2), ("data", "model")),
                            ((2, 1), ("data", "model"))):
            ndev = int(np.prod(shape))
            devs = np.array(jax.devices()[:ndev]).reshape(shape)
            mesh = jax.sharding.Mesh(devs, axes)
            rules = make_rules("train", mesh)
            placed = reshard(host, mesh, rules)
            with mesh:
                out, _ = jax.jit(lambda p, t: MDL.forward(p, cfg, t))(
                    placed, toks)
            np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                                       rtol=2e-2, atol=2e-2)
            print(f"RESHARD {shape} ok")
    """)


def test_pipeline_parallel_two_stages():
    """GPipe-style pipeline over a real 2-device 'pipe' axis equals the
    sequential composition of the stages."""
    run_with_devices("""
        from repro.distributed.pipeline_parallel import pipeline_forward

        S, M, B, D = 2, 4, 8, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (S, D, D)) * 0.3

        def stage(p, x):
            return jnp.tanh(x @ p["w"])

        x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
        want = x
        for s in range(S):
            want = jnp.tanh(want @ ws[s])

        mesh = jax.make_mesh((2,), ("pipe",))
        got = pipeline_forward(stage, {"w": ws}, x, mesh=mesh,
                               num_microbatches=M)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE ok")
    """, n=2)


def test_grad_compression_real_pod_axis():
    """int8+error-feedback psum over a REAL 2-pod axis: the compressed
    all-reduce of identical per-pod grads equals the plain mean."""
    run_with_devices("""
        from repro.distributed.grad_compression import compressed_psum_pod

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jnp.asarray(np.random.default_rng(0).normal(
            size=(512,)).astype(np.float32))}
        red, err = compressed_psum_pod(g, mesh)
        np.testing.assert_allclose(np.asarray(red["w"]),
                                   np.asarray(g["w"]),
                                   rtol=0, atol=float(
                                       jnp.max(jnp.abs(g["w"]))) / 100)
        print("COMPRESSED_PSUM ok")
    """)
