"""Contract composition — the paper's three checking moments (§3.1)."""
import datetime

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.contracts import (CastDecl, check_edge, check_node,
                                  check_wellformed, provable_postconditions,
                                  validate_table)
from repro.core.errors import ContractCompositionError, ContractRuntimeError
from repro.data.tables import Table


class ParentSchema(S.Schema):
    col1: str
    col2: datetime.datetime
    _S: int


class ChildSchema(S.Schema):
    col2: datetime.datetime
    col4: float
    col5: S.Nullable[str]


class Grand(S.Schema):
    col2: datetime.datetime
    col4: int


def test_listing3_inherit_fresh_narrow():
    """Paper Listing 3: col2 as-is, col4/col5 fresh, then col4 narrowed."""
    r1 = check_edge(ParentSchema, ChildSchema)
    assert set(r1.inherited) == {"col2"}
    assert set(r1.fresh) == {"col4", "col5"}

    # Grand narrows col4 float->int: requires the Listing-5 arrow_cast.
    with pytest.raises(ContractCompositionError, match="without an explicit cast"):
        check_edge(ChildSchema, Grand)
    r2 = check_edge(ChildSchema, Grand,
                    casts=[CastDecl("col4", S.INT)])
    assert "col4" in r2.narrowed


def test_cast_target_must_match_declared_type():
    with pytest.raises(ContractCompositionError, match="cast target"):
        check_edge(ChildSchema, Grand, casts=[CastDecl("col4", S.INT32)])


def test_incompatible_types_rejected():
    Up = S.Schema.of("Up", a=str)
    Down = S.Schema.of("Down", a=int)
    with pytest.raises(ContractCompositionError, match="incompatible"):
        check_edge(Up, Down)


def test_widening_needs_no_cast():
    Up = S.Schema.of("Up", a=int)
    Down = S.Schema.of("Down", a=float)
    r = check_edge(Up, Down)
    assert "a" in r.inherited and "a" not in r.narrowed


def test_schema_type_change_breaks_downstream():
    """Paper §2 failure mode 1: col3 becomes float upstream — the child
    contract that assumed int now fails at the CONTROL PLANE, not at
    runtime."""
    RawV1 = S.Schema.of("Raw", col3=int)
    RawV2 = S.Schema.of("Raw", col3=str)       # semantic shift
    Consumer = S.Schema.of("Consumer", col3=int)
    check_edge(RawV1, Consumer)                # composes
    with pytest.raises(ContractCompositionError):
        check_edge(RawV2, Consumer)            # caught before any run


def test_nullability_narrowing_requires_declaration():
    Up = S.Schema.of("Up", a=S.Nullable[str])
    # fresh declaration of NOT NULL `a` downstream without [NotNull]:
    Down = S.Schema.of("Down", a=str)
    with pytest.raises(ContractCompositionError, match="nullability"):
        check_edge(Up, Down)
    # with explicit [NotNull] lineage it composes (Appendix A)
    DownOk = S.Schema.of("DownOk", a=Up.a[S.NotNull])
    r = check_edge(Up, DownOk)
    assert "a" in r.narrowed


def test_nullability_widening_always_safe():
    Up = S.Schema.of("Up", a=str)
    Down = S.Schema.of("Down", a=S.Nullable[str])
    check_edge(Up, Down)


def test_appendix_a_binary_node():
    class FriendSchema(S.Schema):
        col2 = ChildSchema.col2
        col4 = Grand.col4
        col5 = ChildSchema.col5[S.NotNull]

    r = check_node({"child_table": ChildSchema, "grand_child": Grand},
                   FriendSchema)
    assert set(r.inherited) == {"col2", "col4", "col5"}
    assert "col5" in r.narrowed     # null-ness narrowed, declared


def test_lineage_to_missing_input_rejected():
    class Lonely(S.Schema):
        col4 = Grand.col4

    with pytest.raises(ContractCompositionError, match="lineage"):
        check_node({"child": ChildSchema}, Lonely)   # Grand not an input


def test_wellformed_rejects_bad_lineage():
    bad = S.Schema.of("Bad", a=int)
    bad._columns_["a"] = S.Column("a", S.INT, inherited_from="noDotHere")
    with pytest.raises(Exception):
        check_wellformed(bad)


# ---------------------------------------------------------------------------
# Moment 3: worker-side physical validation
# ---------------------------------------------------------------------------

def _child_table(with_null_col4=False):
    col4 = np.array([1.5, 2.5, np.nan]) if with_null_col4 else \
        np.array([1.5, 2.5, 3.5])
    return Table({
        "col2": np.array(["2026-01-01", "2026-01-02", "2026-01-03"],
                         dtype="datetime64[ns]"),
        "col4": col4,
        "col5": np.array(["a", None, "c"], dtype=object),  # nullable
    })


def test_validate_table_happy():
    validate_table(_child_table(), ChildSchema)


def test_validate_table_missing_column():
    t = Table({"col2": np.array([], dtype="datetime64[ns]")})
    with pytest.raises(ContractRuntimeError, match="missing columns"):
        validate_table(t, ChildSchema)


class ChildStrict(S.Schema):
    """Like ChildSchema but col5 is declared NOT NULL."""
    col2: datetime.datetime
    col4: float
    col5: str


def test_validate_table_nulls_in_notnull_column():
    t = _child_table()   # col5 contains a None
    with pytest.raises(ContractRuntimeError, match="NOT NULL"):
        validate_table(t, ChildStrict)


def test_validate_table_elision_skips_check():
    t = _child_table()
    validate_table(t, ChildStrict, elide=frozenset({"col5"}))


def test_validate_table_wrong_physical_dtype():
    t = Table({
        "col2": np.array(["2026-01-01"], dtype="datetime64[ns]"),
        "col4": np.array([1], dtype=np.int64),   # declared float
        "col5": np.array(["x"], dtype=object),
    })
    with pytest.raises(ContractRuntimeError, match="physical dtype"):
        validate_table(t, ChildSchema)


# ---------------------------------------------------------------------------
# "Dafny-style" static discharge (Appendix A)
# ---------------------------------------------------------------------------

def test_provable_postconditions_inspectable_preserving():
    Up = S.Schema.of("Up", a=str, b=S.Nullable[str])
    Down = S.Schema.of("Down", a=str, c=int)
    prov = provable_postconditions({"up": Up}, Down, inspectable=True,
                                   null_preserving=True)
    assert prov == frozenset({"a"})   # inherited not-null; c is fresh


def test_provable_postconditions_opaque_node_discharges_nothing():
    Up = S.Schema.of("Up", a=str)
    Down = S.Schema.of("Down", a=str)
    assert provable_postconditions({"up": Up}, Down, inspectable=False,
                                   null_preserving=True) == frozenset()


def test_provable_postconditions_nullable_upstream_not_provable():
    Up = S.Schema.of("Up", a=S.Nullable[str])
    Down = S.Schema.of("Down", a=Up.a[S.NotNull])
    # upstream nullable: the filter must be physically checked
    assert provable_postconditions({"up": Up}, Down, inspectable=True,
                                   null_preserving=True) == frozenset()


# ---------------------------------------------------------------------------
# By-name resolution across multiple inputs must not depend on ordering
# ---------------------------------------------------------------------------

def test_ambiguous_by_name_resolution_raises():
    """Inputs A(x: int32) and B(x: int64): the verdict used to depend on
    dict ordering (x silently bound to whichever input came first)."""
    A = S.Schema.of("A", x=S.INT32)
    B = S.Schema.of("B", x=S.INT64)
    Out = S.Schema.of("Out", x=S.INT64)
    for inputs in ({"a": A, "b": B}, {"b": B, "a": A}):   # both orders
        with pytest.raises(ContractCompositionError, match="multiple"):
            check_node(inputs, Out)


def test_ambiguous_nullability_also_raises():
    A = S.Schema.of("A", x=S.Nullable[str])
    B = S.Schema.of("B", x=str)
    with pytest.raises(ContractCompositionError, match="multiple"):
        check_node({"a": A, "b": B}, S.Schema.of("Out", x=S.Nullable[str]))


def test_explicit_lineage_disambiguates():
    A = S.Schema.of("A", x=S.INT32)
    B = S.Schema.of("B", x=S.INT64)
    OutA = S.Schema.of("OutA", x=A.x)          # lineage: A.x, widens
    r = check_node({"a": A, "b": B}, OutA)
    assert "x" in r.inherited
    # binding to B instead requires a declared narrowing cast — and the
    # verdict is now the same whichever order the inputs arrive in.
    OutB = S.Schema.of("OutB", x=S.Column("x", S.INT32,
                                          inherited_from="B.x"))
    with pytest.raises(ContractCompositionError, match="explicit cast"):
        check_node({"a": A, "b": B}, OutB)
    check_node({"a": A, "b": B}, OutB, casts=[CastDecl("x", S.INT32)])


def test_agreeing_duplicate_columns_still_compose_by_name():
    """Identical declarations across inputs (the natural-join idiom —
    e.g. a shared join key) stay legal: the verdict cannot depend on
    which input the column binds to."""
    L = S.Schema.of("L", k=str, a=int)
    R = S.Schema.of("R", k=str, b=int)
    r = check_node({"l": L, "r": R}, S.Schema.of("J", k=str, a=int, b=int))
    assert set(r.inherited) == {"k", "a", "b"}
