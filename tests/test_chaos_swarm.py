"""Agent-swarm stress + linearizability checker (DESIGN.md §15).

The acceptance gate for the chaos tier: 240 seeded adversarial
schedules (contention, crashes at every publication seam, failed store
writes, abandoned branches, quarantine reuse, concurrent GC) with ZERO
linearizability violations, every crash point leaving a readable and
GC-recoverable catalog, and the checker itself proven non-vacuous
against hand-built bad histories.
"""
import dataclasses

import pytest

from repro.chaos import (FaultPlan, FaultRule, InjectedCrash, SwarmConfig,
                         check_history, check_swarm, fault_injection,
                         run_swarm)
from repro.chaos.swarm import AgentRecord
from repro.core.catalog import Catalog, Visibility
from repro.core.transactions import RunRegistry, TransactionalRun

BASE_RULES = (FaultRule("txn.commit.post_merge", "crash", 0.10),
              FaultRule("txn.begin.post_branch", "crash", 0.03),
              FaultRule("txn.commit.pre_merge", "delay", 0.20,
                        delay_s=0.001),
              FaultRule("store.put", "fail", 0.08))

# four regimes x 60 seeds = 240 adversarial schedules
REGIMES = {
    "calm": SwarmConfig(n_agents=6, runs_per_agent=2, gc_every=3),
    # the pre_merge delay holds publishers between verification and
    # CAS, so concurrent merges actually land in the window
    "contended": SwarmConfig(n_agents=8, runs_per_agent=2, hot_tables=1,
                             p_contended=0.8, p_multi=0.0, p_violate=0.0,
                             p_abandon=0.0, p_reuse=0.0, gc_every=4,
                             fault_rules=(FaultRule(
                                 "txn.commit.pre_merge", "delay", 0.8,
                                 delay_s=0.003),)),
    "faulted": SwarmConfig(n_agents=6, runs_per_agent=2, gc_every=3,
                           use_store=True, fault_rules=BASE_RULES,
                           fault_budget=8),
    "hostile": SwarmConfig(
        n_agents=6, runs_per_agent=2, gc_every=2, use_store=True,
        p_violate=0.2, p_abandon=0.15, p_reuse=0.2,
        fault_rules=BASE_RULES + (
            FaultRule("txn.commit.pre_rebase", "crash", 0.05),
            FaultRule("txn.commit.post_rebase", "crash", 0.05)),
        fault_budget=12),
}


@pytest.mark.parametrize("regime", sorted(REGIMES))
@pytest.mark.parametrize("batch", range(3))
def test_seeded_swarms_are_linearizable(regime, batch):
    base = REGIMES[regime]
    for i in range(20):
        seed = f"{regime}-{batch * 20 + i}"
        res = run_swarm(dataclasses.replace(base, seed=seed))
        violations = check_swarm(res)
        assert not violations, (
            f"seed {seed!r} (replayable): {violations}\n"
            f"injected={res.plan.injected}")
        assert len(res.records) == base.n_agents * base.runs_per_agent


def test_single_agent_swarm_replays_exactly():
    """With one agent the schedule is sequential, so a seed replays the
    ENTIRE history — outcomes, fault log, final heads — bit for bit."""
    cfg = SwarmConfig(n_agents=1, runs_per_agent=8, seed="replay",
                      use_store=True, fault_rules=BASE_RULES, gc_every=3)
    a, b = run_swarm(cfg), run_swarm(cfg)
    assert [(r.run_id, r.intent, r.outcome, r.tables)
            for r in a.records] == \
           [(r.run_id, r.intent, r.outcome, r.tables)
            for r in b.records]
    assert a.plan.injected == b.plan.injected
    assert a.catalog.tables("main") == b.catalog.tables("main")


def test_swarm_registry_agrees_with_records():
    res = run_swarm(SwarmConfig(n_agents=6, runs_per_agent=2, seed=5))
    by_id = {s.run_id: s for s in res.registry.runs()}
    for r in res.records:
        if r.outcome == "committed":
            assert by_id[r.run_id].status == "committed"
            assert by_id[r.run_id].final_commit == r.final_commit
        elif r.outcome == "aborted":
            assert by_id[r.run_id].status == "aborted"
        elif r.outcome == "abandoned":
            # walked away without abort: registry still says running —
            # exactly the record GC's liveness input must override
            assert by_id[r.run_id].status == "running"


def test_swarm_final_gc_leaves_no_txn_debris():
    cfg = SwarmConfig(n_agents=8, runs_per_agent=3, seed=11,
                      p_abandon=0.3, use_store=True,
                      fault_rules=BASE_RULES, fault_budget=10)
    res = run_swarm(cfg)
    assert not check_swarm(res)
    for b in res.catalog.branches():
        vis = res.catalog.branch_info(b).visibility
        assert vis not in (Visibility.TXN, Visibility.ABORTED), (
            f"{b} survived the final sweep as {vis}")


def test_swarm_contention_exercises_rebase_and_backoff():
    res = run_swarm(dataclasses.replace(REGIMES["contended"],
                                        seed="backoff"))
    assert not check_swarm(res)
    # a conflicted publisher retried (and may then have committed or
    # aborted on the hot-table rebase conflict — both are legal)
    attempts = [s.publish_attempts for s in res.registry.runs()]
    assert attempts and max(attempts) > 1, (
        "contended regime never conflicted — not stressing publication")
    assert res.clock.sleep_count > 0      # backoff went through FakeClock


# ---------------------------------------------------------------------------
# every crash point leaves a readable, recoverable catalog
# ---------------------------------------------------------------------------

CRASH_POINTS = ["txn.begin.post_branch", "txn.commit.pre_merge",
                "txn.commit.post_merge", "store.put"]


@pytest.mark.parametrize("point", CRASH_POINTS)
def test_crash_point_recovery(point):
    cfg = SwarmConfig(
        n_agents=3, runs_per_agent=2, seed=f"crash-{point}",
        use_store=True,
        fault_rules=(FaultRule(point, "crash", 1.0),), fault_budget=3)
    res = run_swarm(cfg)
    assert not check_swarm(res)           # includes catalog-readable
    crashed = [r for r in res.records if r.outcome == "crashed"]
    assert crashed, f"rate-1.0 crash rule at {point} never fired"
    # recovery: after the final sweep a fresh run publishes normally
    with TransactionalRun(res.catalog, "main", run_id="after") as txn:
        txn.write_tables({"after": "s@after"})
        txn.verify(lambda read: read("after"))
    assert res.catalog.tables("main")["after"] == "s@after"


def test_mid_rebase_crash_under_contention():
    """Crash at the rebase seams specifically, with enough contention
    that rebases actually happen."""
    cfg = SwarmConfig(
        n_agents=8, runs_per_agent=2, seed="rebase-crash", hot_tables=1,
        p_contended=0.9, p_multi=0.0, p_violate=0.0, p_abandon=0.0,
        p_reuse=0.0,
        fault_rules=(FaultRule("txn.commit.pre_rebase", "crash", 0.3),
                     FaultRule("txn.commit.post_rebase", "crash", 0.3)),
        fault_budget=5)
    res = run_swarm(cfg)
    assert not check_swarm(res)


# ---------------------------------------------------------------------------
# the checker is not vacuous: hand-built bad histories must be flagged
# ---------------------------------------------------------------------------

def _rec(**kw):
    base = dict(agent=0, idx=0, run_id="r0", intent="disjoint")
    base.update(kw)
    return AgentRecord(**base)


def _one_good_run(cat, rid, tables):
    reg = RunRegistry()
    with TransactionalRun(cat, "main", run_id=rid, registry=reg) as txn:
        txn.write_tables(tables)
        txn.verify(lambda read: None)
    return txn.final_commit.id


def test_checker_flags_partial_publication():
    cat = Catalog()
    cid = _one_good_run(cat, "r0", {"a": "a@r0"})
    rec = _rec(run_id="r0", outcome="committed", final_commit=cid,
               verified_head=cid, tables={"a": "a@r0", "b": "b@r0"})
    [v] = check_history(cat, [rec])
    assert "partial publication" in v


def test_checker_flags_early_visibility():
    cat = Catalog()
    cat.write_table("main", "a", "a@r0")          # leaked BEFORE publish
    cid = _one_good_run(cat, "r0", {"a": "a@r0", "b": "b@r0"})
    rec = _rec(run_id="r0", outcome="committed", final_commit=cid,
               verified_head=cid, tables={"a": "a@r0", "b": "b@r0"})
    violations = check_history(cat, [rec])
    assert any("BEFORE publication" in v for v in violations)


def test_checker_flags_aborted_leak():
    cat = Catalog()
    cat.write_table("main", "a", "a@dead", run_id=None)
    rec = _rec(run_id="dead", outcome="aborted", tables={"a": "a@dead"})
    [v] = check_history(cat, [rec])
    assert "leaked" in v


def test_checker_flags_aborted_run_with_chain_commit():
    cat = Catalog()
    _one_good_run(cat, "dead", {"a": "a@dead"})
    rec = _rec(run_id="dead", outcome="aborted", tables={"a": "a@dead"})
    violations = check_history(cat, [rec])
    assert any("are on 'main'" in v for v in violations)


def test_checker_flags_unverified_publication():
    cat = Catalog()
    cid = _one_good_run(cat, "r0", {"a": "a@r0"})
    rec = _rec(run_id="r0", outcome="committed", final_commit=cid,
               verified_head="somethingelse", tables={"a": "a@r0"})
    violations = check_history(cat, [rec])
    assert any("unverified state" in v for v in violations)


def test_checker_flags_mystery_publication():
    cat = Catalog()
    _one_good_run(cat, "ghost", {"a": "a@ghost"})
    violations = check_history(cat, [])           # nobody owns that run
    assert any("mystery publication" in v for v in violations)


def test_checker_flags_illegal_quarantine_merge_and_branch_loss():
    cat = Catalog()
    violations = check_history(cat, [
        _rec(run_id="q0", outcome="released", illegal_merge=True),
        _rec(run_id="l0", outcome="branch_lost", error="gone")])
    assert any("Fig. 4" in v for v in violations)
    assert any("GC collected live state" in v for v in violations)


def test_checker_accepts_lost_ack_crash_as_published():
    """A crash after merge (lost ack) is held to committed-run rules —
    and passes them when the publication was in fact atomic."""
    cat = Catalog()
    reg = RunRegistry()
    txn = TransactionalRun(cat, "main", run_id="r0", registry=reg)
    txn.begin()
    txn.write_tables({"a": "a@r0", "b": "b@r0"})
    plan = FaultPlan(0, (FaultRule("txn.commit.post_merge",
                                   "crash", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            txn.commit()
    rec = _rec(run_id="r0", outcome="crashed",
               tables={"a": "a@r0", "b": "b@r0"}, branch=txn.branch)
    assert check_history(cat, [rec]) == []
    # ... and is still checked: claim a table the commit doesn't carry
    rec2 = _rec(run_id="r0", outcome="crashed",
                tables={"a": "a@r0", "c": "c@r0"})
    assert any("partial publication" in v
               for v in check_history(cat, [rec2]))
