"""Fault-injection layer (DESIGN.md §15): seeded determinism, the
FaultyStore wrapper, crash-consistent FileStore writes (including the
crash-at-every-byte torn-ref regression), the fake clock, and the
jittered publication backoff."""
import time

import pytest

from repro.chaos import (FakeClock, FaultPlan, FaultRule, FaultyStore,
                         InjectedCrash, InjectedFault, fault_injection,
                         install_fault_hook)
from repro.core.catalog import Catalog
from repro.core.errors import PublicationConflict
from repro.core.hooks import fault_point
from repro.core.store import FileStore, MemoryStore
from repro.core.transactions import TransactionalRun

POINTS = ["txn.begin.post_branch", "txn.commit.pre_merge",
          "txn.commit.post_merge", "store.put", "store.put_ref"]


def _drive(plan, sequence):
    """Replay a fixed visit sequence; collect what fired."""
    fired = []
    with fault_injection(plan):
        for p in sequence:
            try:
                fault_point(p)
            except InjectedFault:
                fired.append((p, "fail"))
            except InjectedCrash:
                fired.append((p, "crash"))
    return fired


# ---------------------------------------------------------------------------
# FaultPlan: seeded, deterministic, budgeted
# ---------------------------------------------------------------------------

def test_same_seed_same_decisions():
    rules = (FaultRule("txn.commit", "fail", 0.4),
             FaultRule("store.", "crash", 0.3))
    seq = POINTS * 40
    a = _drive(FaultPlan(7, rules), seq)
    b = _drive(FaultPlan(7, rules), seq)
    assert a == b and a   # identical AND non-empty (rates actually fire)
    assert FaultPlan(7, rules, ).seed == 7


def test_injected_log_replays_decisions():
    rules = (FaultRule("txn", "fail", 0.5),)
    plan = FaultPlan("s1", rules)
    _drive(plan, POINTS * 20)
    replay = FaultPlan("s1", rules)
    _drive(replay, POINTS * 20)
    assert plan.injected == replay.injected


def test_different_seeds_diverge():
    rules = (FaultRule("", "fail", 0.5),)
    logs = {tuple(_drive(FaultPlan(s, rules), POINTS * 10))
            for s in range(5)}
    assert len(logs) > 1


def test_rate_bounds():
    assert not _drive(FaultPlan(0, (FaultRule("txn", "fail", 0.0),)),
                      POINTS * 10)
    always = _drive(FaultPlan(0, (FaultRule("txn.commit.pre_merge",
                                            "fail", 1.0),)),
                    ["txn.commit.pre_merge"] * 5)
    assert len(always) == 5
    with pytest.raises(ValueError):
        FaultRule("x", "fail", 1.5)
    with pytest.raises(ValueError):
        FaultRule("x", "explode")


def test_budget_caps_total_injections():
    plan = FaultPlan(1, (FaultRule("", "fail", 1.0),), budget=3)
    fired = _drive(plan, POINTS * 10)
    assert len(fired) == 3 and plan.faults_injected == 3
    # after exhaustion the plan is a pure passthrough
    with fault_injection(plan):
        fault_point("txn.commit.pre_merge")   # does not raise


def test_delays_do_not_consume_budget():
    slept = []
    plan = FaultPlan(1, (FaultRule("txn", "delay", 1.0, delay_s=0.01),),
                     budget=0, sleep=slept.append)
    _drive(plan, ["txn.commit.pre_merge"] * 4)
    assert len(slept) == 4 and all(0 <= s <= 0.01 for s in slept)
    assert plan.faults_injected == 0


def test_fault_injection_scope_restores_previous_hook():
    seen = []
    prev = install_fault_hook(lambda p, ctx: seen.append(p))
    try:
        with fault_injection(FaultPlan(0)):
            fault_point("a")
        fault_point("b")
        assert seen == ["b"]   # outer hook back in force
    finally:
        install_fault_hook(prev)


# ---------------------------------------------------------------------------
# FaultyStore
# ---------------------------------------------------------------------------

def test_faulty_store_passthrough_without_hook():
    fs = FaultyStore(MemoryStore())
    k = fs.put(b"data")
    assert fs.get(k) == b"data" and k in fs
    fs.put_ref("r", k)
    assert fs.get_ref("r") == k and list(fs.refs()) == ["r"]
    assert fs.delete_ref("r") and not fs.delete_ref("r")


def test_faulty_store_ops_fail_under_plan():
    fs = FaultyStore(MemoryStore())
    plan = FaultPlan(0, (FaultRule("store.put", "fail", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedFault):
            fs.put(b"x")
        with pytest.raises(InjectedFault):
            fs.put_ref("r", "k")   # prefix "store.put" matches put_ref
    assert b"x" not in [fs.get(k) for k in fs.keys()]


def test_faulty_store_delegates_backend_surface(tmp_path):
    fs = FaultyStore(FileStore(str(tmp_path)))
    assert hasattr(fs, "sweep_tmp") and fs.sweep_tmp() == 0
    assert not hasattr(FaultyStore(MemoryStore()), "sweep_tmp")


def test_manifest_write_failure_does_not_kill_published_run():
    """The audit manifest is observational: a store failure while
    anchoring it (AFTER the merge moved the ref) must leave the run
    committed — it just reads back untraced."""
    import repro.obs as obs
    store = FaultyStore(MemoryStore())
    cat = Catalog(store)
    plan = FaultPlan(0, (FaultRule("store.put_ref", "fail", 1.0),))
    with obs.tracing():
        with fault_injection(plan):
            txn = TransactionalRun(cat, "main", run_id="r0")
            txn.begin()
            txn.write_tables({"t": "s"})
            merged = txn.commit()          # must not raise
    assert cat.tables("main")["t"] == "s"
    assert cat.run_manifest(merged.id) is None


# ---------------------------------------------------------------------------
# FileStore crash consistency
# ---------------------------------------------------------------------------

def test_put_crash_leaks_tmp_invisible_then_swept(tmp_path):
    store = FileStore(str(tmp_path))
    plan = FaultPlan(0, (FaultRule("filestore.put.pre_replace",
                                   "crash", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            store.put(b"payload")
    assert list(store.keys()) == []       # torn write is not an object
    assert store.sweep_tmp() == 1         # exactly the leaked temp
    key = store.put(b"payload")           # recovery: clean retry works
    assert store.get(key) == b"payload"
    assert store.sweep_tmp() == 0


def test_put_ref_crash_at_every_byte_keeps_old_value(tmp_path):
    """Regression for the torn-ref window: simulate dying after writing
    any prefix of the new ref (0..N bytes) — the reader must ALWAYS see
    the complete old value, never a prefix of the new one."""
    store = FileStore(str(tmp_path))
    old = store.put(b"old")
    new = store.put(b"new")
    store.put_ref("heads/main", old)
    for nbytes in range(len(new) + 1):
        def torn_hook(point, ctx, _n=nbytes):
            if point == "filestore.put_ref.pre_replace":
                with open(ctx["tmp"], "r+b") as f:
                    f.truncate(_n)
                raise InjectedCrash(point)
        prev = install_fault_hook(torn_hook)
        try:
            with pytest.raises(InjectedCrash):
                store.put_ref("heads/main", new)
        finally:
            install_fault_hook(prev)
        assert store.get_ref("heads/main") == old, (
            f"torn ref visible after crash at byte {nbytes}")
        assert list(store.refs()) == ["heads/main"]
    assert store.sweep_tmp() == len(new) + 1   # one leak per crash
    store.put_ref("heads/main", new)           # clean write lands whole
    assert store.get_ref("heads/main") == new


def test_sweep_tmp_respects_min_age(tmp_path):
    store = FileStore(str(tmp_path))
    plan = FaultPlan(0, (FaultRule("filestore.put.pre_replace",
                                   "crash", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            store.put(b"x")
    assert store.sweep_tmp(min_age_s=3600) == 0   # too young: in-flight?
    assert store.sweep_tmp(min_age_s=0) == 1


def test_plan_torn_kind_truncates_and_crashes(tmp_path):
    store = FileStore(str(tmp_path))
    k = store.put(b"v1")
    store.put_ref("r", k)
    plan = FaultPlan(3, (FaultRule("filestore.put_ref.pre_replace",
                                   "torn", 1.0),))
    with fault_injection(plan):
        with pytest.raises(InjectedCrash):
            store.put_ref("r", store.put(b"v2"))
    assert plan.injected[-1][2] == "torn"
    assert store.get_ref("r") == k        # old value intact
    assert store.sweep_tmp() >= 1


# ---------------------------------------------------------------------------
# FakeClock + backoff
# ---------------------------------------------------------------------------

def test_fake_clock_accumulates_without_wall_time():
    clock = FakeClock()
    t0 = time.monotonic()
    for _ in range(1000):
        clock.sleep(1.0)
    assert clock.now_s == pytest.approx(1000.0)
    assert clock.sleep_count == 1000
    assert time.monotonic() - t0 < 5.0     # virtual seconds, real millis


def _delays(run, n=8):
    return [run._backoff_delay(i + 1) for i in range(n)]


def test_decorrelated_backoff_bounded_and_seeded():
    cat = Catalog()
    a = TransactionalRun(cat, "main", backoff_seed="s",
                         publish_backoff_s=0.001,
                         publish_backoff_cap_s=0.05)
    b = TransactionalRun(cat, "main", backoff_seed="s",
                         publish_backoff_s=0.001,
                         publish_backoff_cap_s=0.05)
    da, db = _delays(a), _delays(b)
    assert da == db                        # replayable from seed
    assert all(0.001 <= d <= 0.05 for d in da)
    c = TransactionalRun(cat, "main", backoff_seed="other",
                         publish_backoff_s=0.001,
                         publish_backoff_cap_s=0.05)
    assert _delays(c) != da                # decorrelated across runs


def test_linear_backoff_is_the_old_schedule():
    run = TransactionalRun(Catalog(), "main", backoff="linear",
                           publish_backoff_s=0.002)
    assert _delays(run, 4) == [0.002, 0.004, 0.006, 0.008]
    with pytest.raises(ValueError):
        TransactionalRun(Catalog(), "main", backoff="fibonacci")


def test_zero_base_backoff_never_sleeps():
    run = TransactionalRun(Catalog(), "main", publish_backoff_s=0.0)
    assert _delays(run) == [0.0] * 8


def test_retry_budget_exhaustion_aborts_with_publication_conflict():
    cat = Catalog()
    clock = FakeClock()
    txn = TransactionalRun(cat, "main", publish_retry_budget_s=0.0,
                           max_publish_attempts=100, clock=clock)
    txn.begin()
    txn.write_tables({"t": "s1"})
    cat.write_table("main", "t", "other")   # move the target: conflict
    with pytest.raises(PublicationConflict, match="retry budget"):
        txn.commit()
    assert cat.branch_info(txn.branch).visibility.value == "aborted"
    assert clock.sleep_count == 0           # budget refused the sleep


def test_backoff_sleeps_go_through_injected_clock():
    cat = Catalog()
    clock = FakeClock()
    txn = TransactionalRun(cat, "main", clock=clock,
                           max_publish_attempts=10,
                           publish_backoff_s=0.01,
                           publish_backoff_cap_s=0.01)
    txn.begin()
    txn.write_tables({"mine": "s"})
    # move main a few times so commit() retries through the clock
    cat.write_table("main", "theirs", "x1")
    merged = txn.commit()
    assert merged.tables["mine"] == "s" and merged.tables["theirs"] == "x1"
    assert txn.publish_attempts >= 2
    assert clock.sleep_count >= 1 and clock.now_s > 0
    assert txn.backoff_spent_s == pytest.approx(clock.now_s)
