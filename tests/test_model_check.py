"""Executable Alloy model (paper §4): adequacy, counterexample, fix.

The unguarded variant must REACH the paper's Fig. 4 inconsistent state
(that is what makes the model adequate); the guarded variant must make
the same trace — and every trace hypothesis can find — safe.
"""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property search needs hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import HealthCheck, settings
from hypothesis import strategies as st
from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                 precondition, rule)

from repro.core.errors import ReproError, VisibilityError
from repro.core.model_check import LakehouseModel

PLAN = ("P", "C", "G")


# ---------------------------------------------------------------------------
# Adequacy: reproduce Fig. 3 (top and bottom)
# ---------------------------------------------------------------------------

def test_fig3_top_direct_mode_reaches_torn_state():
    m = LakehouseModel(guarded=True)
    ok = m.begin_run(PLAN, mode="direct")
    while not ok.done:
        m.step_run(ok)
    m.finish_run(ok)
    assert m.is_consistent()

    bad = m.begin_run(PLAN, mode="direct")
    m.step_run(bad)           # writes P** directly to main …
    m.fail_run(bad)           # … then dies
    assert not m.is_consistent()          # {P**, C*, G*}: torn
    assert m.torn_runs() == [bad.run_id]


def test_fig3_bottom_txn_mode_never_tears():
    m = LakehouseModel(guarded=True)
    ok = m.begin_run(PLAN, mode="txn")
    while not ok.done:
        m.step_run(ok)
        assert m.is_consistent()          # mid-run: main untouched
    m.finish_run(ok)
    assert m.is_consistent()

    bad = m.begin_run(PLAN, mode="txn")
    m.step_run(bad)
    m.fail_run(bad)
    assert m.is_consistent()              # total failure, not partial
    # the aborted branch remains reachable for debugging
    assert bad.branch in m.catalog.branches()


# ---------------------------------------------------------------------------
# The Fig. 4 counterexample
# ---------------------------------------------------------------------------

def _drive_fig4(m: LakehouseModel):
    """A user's txn run fails after P; an agent branches off the aborted
    branch, does arbitrary work, and merges back to main."""
    bad = m.begin_run(PLAN, mode="txn")
    m.step_run(bad)                         # P written on txn branch
    m.fail_run(bad)                         # aborted, branch dangling
    agent = m.actor_branch(bad.branch)      # agent sees it as available
    m.actor_write(agent, "X")               # arbitrary work
    m.actor_merge(agent, into="main")       # ← the hazard
    return bad


def test_fig4_unguarded_model_admits_counterexample():
    m = LakehouseModel(guarded=False)
    bad = _drive_fig4(m)
    # main now exposes P from the aborted run: globally inconsistent.
    assert not m.is_consistent()
    assert bad.run_id in m.torn_runs()


def test_fig4_guarded_model_rejects_trace():
    m = LakehouseModel(guarded=True)
    with pytest.raises(VisibilityError):
        _drive_fig4(m)
    assert m.is_consistent()                # main never tainted


def test_guarded_reuse_path_requires_verification():
    """The paper's idempotent-re-run optimization survives the fix:
    branching WITH allow_reuse gives a quarantined branch that cannot
    merge until re-verified."""
    m = LakehouseModel(guarded=True)
    bad = m.begin_run(PLAN, mode="txn")
    m.step_run(bad)
    m.fail_run(bad)
    retry = m.actor_branch(bad.branch, allow_reuse=True)
    m.actor_write(retry, "C")               # re-run child from parent
    with pytest.raises(VisibilityError):
        m.actor_merge(retry, into="main")   # still quarantined
    m.catalog.mark(retry, m.catalog.branch_info(retry).visibility,
                   verified=True)
    m.actor_merge(retry, into="main")       # re-verified: legal
    # NOTE: main now includes P from the aborted run *by design* — the
    # re-verification step is what re-legitimizes it (DESIGN.md §6).


# ---------------------------------------------------------------------------
# Hypothesis stateful search: no trace of the guarded model tears main
# ---------------------------------------------------------------------------

class GuardedLakehouse(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        self.m = LakehouseModel(guarded=True)
        self.runs = []
        self.branches = ["main"]

    # -- run lifecycle ---------------------------------------------------
    @rule(n=st.integers(1, 3))
    def begin(self, n):
        tables = ["P", "C", "G", "H"][:n]
        self.runs.append(self.m.begin_run(tuple(tables), mode="txn"))

    @precondition(lambda self: any(
        r.status == "running" and not r.done for r in self.runs))
    @rule()
    def step(self):
        r = next(r for r in self.runs
                 if r.status == "running" and not r.done)
        self.m.step_run(r)

    @precondition(lambda self: any(
        r.status == "running" and r.done for r in self.runs))
    @rule()
    def finish(self):
        r = next(r for r in self.runs if r.status == "running" and r.done)
        try:
            self.m.finish_run(r)
        except ReproError:
            self.m.fail_run(r)   # e.g. concurrent merge conflict → abort

    @precondition(lambda self: any(
        r.status == "running" for r in self.runs))
    @rule()
    def fail(self):
        r = next(r for r in self.runs if r.status == "running")
        self.m.fail_run(r)

    @precondition(lambda self: any(
        r.status == "running" for r in self.runs))
    @rule()
    def abandon(self):
        r = next(r for r in self.runs if r.status == "running")
        self.m.abandon_run(r)

    # -- janitor + readers (DESIGN.md §15) ---------------------------------
    @rule()
    def janitor_gc(self):
        self.m.gc()

    @rule(b=st.integers(0, 10))
    def reader_pin(self, b):
        candidates = self.m.catalog.branches()
        try:
            self.m.pin_branch(candidates[b % len(candidates)])
        except ReproError:
            pass

    # -- adversarial actor (the Fig. 4 agent) ------------------------------
    @rule(reuse=st.booleans(),
          src=st.integers(0, 10))
    def agent_branch(self, reuse, src):
        candidates = self.m.catalog.branches()
        name = candidates[src % len(candidates)]
        try:
            self.branches.append(
                self.m.actor_branch(name, allow_reuse=reuse))
        except ReproError:
            pass   # refusal is fine; tearing is not

    @rule(t=st.sampled_from(["P", "C", "G", "X"]),
          b=st.integers(0, 10))
    def agent_write(self, t, b):
        name = self.branches[b % len(self.branches)]
        try:
            self.m.actor_write(name, t)
        except ReproError:
            pass

    @rule(b=st.integers(0, 10))
    def agent_merge(self, b):
        name = self.branches[b % len(self.branches)]
        try:
            self.m.actor_merge(name, into="main")
        except ReproError:
            pass

    # -- the global safety properties ---------------------------------------
    @invariant()
    def main_is_never_torn(self):
        torn = self.m.torn_runs("main")
        assert not torn, f"guarded model reached torn state: {torn}"

    @invariant()
    def publications_are_verified(self):
        stale = self.m.stale_publications()
        assert not stale, (
            f"rebase-and-revalidate published unverified state: {stale}")

    @invariant()
    def gc_never_collects_live_state(self):
        bad = self.m.collected_live_branches()
        assert not bad, f"GC collected live/pinned state: {bad}"

    @invariant()
    def gc_never_strands_a_run(self):
        # every still-running txn run must still own its branch
        for r in self.runs:
            if r.status == "running" and r.branch is not None:
                assert r.branch in self.m.catalog.branches(), (
                    f"run {r.run_id} lost branch {r.branch} to GC "
                    f"while live")


GuardedLakehouse.TestCase.settings = settings(
    max_examples=60, stateful_step_count=40, deadline=None,
    suppress_health_check=[HealthCheck.too_slow])

TestGuardedLakehouse = GuardedLakehouse.TestCase


def test_unguarded_model_found_by_same_search():
    """Sanity: the identical agent behaviours DO tear the unguarded
    model (so the invariant above is not vacuous)."""
    m = LakehouseModel(guarded=False)
    bad = m.begin_run(("P", "C"), mode="txn")
    m.step_run(bad)
    m.fail_run(bad)
    agent = m.actor_branch(bad.branch)
    m.actor_merge(agent, into="main")
    assert not m.is_consistent()


# ---------------------------------------------------------------------------
# Concurrent publication: stale-verification merges (DESIGN.md §7)
# ---------------------------------------------------------------------------

def test_stale_publication_counterexample_without_cas():
    """The pre-fix protocol: target moves after begin; the plain merge
    silently publishes a combined state NO verifier ever observed."""
    m = LakehouseModel(guarded=True, publication="stale")
    r = m.begin_run(("P",), mode="txn")
    m.step_run(r)
    m.actor_write("main", "X")          # main moves mid-run
    m.finish_run(r)                     # silent three-way merge
    assert m.stale_publications() == [r.run_id]
    # the torn-run predicate does NOT catch this (r committed, nothing
    # partial) — which is exactly why the new predicate is needed.
    assert m.is_consistent()


def test_rebase_publication_closes_counterexample():
    """The shipped protocol on the identical trace: rebase onto the
    moved head, re-verify, then fast-forward — published == verified."""
    m = LakehouseModel(guarded=True, publication="rebase")
    r = m.begin_run(("P",), mode="txn")
    m.step_run(r)
    m.actor_write("main", "X")
    m.finish_run(r)
    assert m.publications_verified()
    # the published commit carries BOTH the concurrent write and the
    # run's table, and the verifiers validated that exact state
    pub = dict(m.catalog.commit(r.published_commit).tables)
    assert pub == r.verified_tables
    assert "X" in pub and "P" in pub


def test_rebase_publication_conflict_aborts_cleanly():
    """Same table changed on both sides: rebase must conflict, the run
    must abort, and main keeps the concurrent writer's value."""
    m = LakehouseModel(guarded=True, publication="rebase")
    r = m.begin_run(("P",), mode="txn")
    m.step_run(r)
    m.actor_write("main", "P")          # same table on main
    with pytest.raises(ReproError):
        m.finish_run(r)
    m.fail_run(r)
    assert m.is_consistent()
    assert m.publications_verified()


# ---------------------------------------------------------------------------
# Branch GC: liveness, pins, and the unsafe-janitor adequacy case
# ---------------------------------------------------------------------------

def test_unsafe_janitor_collects_live_branch_adequacy():
    """The pre-fix cron janitor deletes EVERY txn branch — including one
    whose run is mid-flight. The predicate must catch it (adequacy),
    and the stranded run must then fail to publish."""
    m = LakehouseModel(guarded=True)
    r = m.begin_run(("P",), mode="txn")
    m.step_run(r)                       # running, branch live
    collected = m.gc(unsafe=True)
    assert r.branch in collected
    assert m.collected_live_branches(), "predicate missed a live collection"
    with pytest.raises(ReproError):
        m.finish_run(r)                 # branch gone: publication strands


def test_safe_gc_keeps_live_collects_dead():
    """The shipped GC on the same shape of state: the live run's branch
    survives, the abandoned one goes, and nothing live was touched."""
    m = LakehouseModel(guarded=True)
    live = m.begin_run(("P",), mode="txn")
    m.step_run(live)
    dead = m.begin_run(("C",), mode="txn")
    m.step_run(dead)
    m.abandon_run(dead)                 # owner walked away
    collected = m.gc()
    assert dead.branch in collected
    assert live.branch not in collected
    assert not m.collected_live_branches()
    m.finish_run(live)                  # still publishes fine
    assert m.is_consistent() and m.publications_verified()


def test_safe_gc_respects_pins_and_quarantine():
    """Pinned aborted heads (triage in progress) and quarantined
    branches awaiting re-verification are never collected."""
    m = LakehouseModel(guarded=True)
    r1 = m.begin_run(("P",), mode="txn")
    m.step_run(r1)
    m.fail_run(r1)                      # aborted, preserved
    m.pin_branch(r1.branch)             # a reader is triaging it
    r2 = m.begin_run(("C",), mode="txn")
    m.step_run(r2)
    m.fail_run(r2)
    q = m.actor_branch(r2.branch, allow_reuse=True)  # quarantined
    collected = m.gc()
    assert r1.branch not in collected, "pinned aborted head collected"
    assert q not in collected, "quarantined branch collected"
    assert r2.branch in collected       # unpinned aborted: fair game
    assert not m.collected_live_branches()
    # the quarantine reuse path still works after GC
    m.catalog.mark(q, m.catalog.branch_info(q).visibility, verified=True)
    m.actor_merge(q, into="main")


def test_second_counterexample_live_txn_branch_laundering():
    """Found BY the stateful search above (not in the paper): an agent
    branches from a LIVE transactional branch (run still in flight) and
    merges to main — laundering uncommitted state. The guarded catalog
    refuses the branch without allow_reuse, and quarantines it with."""
    m = LakehouseModel(guarded=True)
    r = m.begin_run(("P",), mode="txn")
    m.step_run(r)                       # P written, run NOT finished
    with pytest.raises(VisibilityError):
        m.actor_branch(r.branch)        # refused
    b = m.actor_branch(r.branch, allow_reuse=True)   # quarantined
    with pytest.raises(VisibilityError):
        m.actor_merge(b, into="main")   # cannot merge unverified
    assert m.is_consistent()
