"""The SQL front door's proof obligation (DESIGN.md §13): a query
compiled from text must be bit-for-bit identical to the hand-built
declarative pipeline computing the same thing — on every registered
execution backend, optimized and unoptimized.

Fixtures mirror the adversarial set of test_optimizer_differential:
inner and LEFT joins, NULL-validity and NaN join keys (SQL
match-nothing semantics), a computed WHERE, and a GROUP BY exercising
all five aggregate functions."""
import numpy as np
import pytest

from repro import exec as exec_backends
from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.planner import plan
from repro.core.runner import Client
from repro.data.tables import Table, _ColumnData, col, lit

BACKENDS = exec_backends.available_backends()

_rng = np.random.default_rng(11)
_N = 300

Fact = S.Schema.of("fact", user_id=int, amount=float, segment=int)
Users = S.Schema.of("users", user_id=int, tier=int)


def _sources():
    return {
        "fact": Table({
            "user_id": _rng.integers(0, 40, _N),
            "amount": _rng.normal(size=_N),
            "segment": _rng.integers(0, 8, _N)}),
        "users": Table({
            "user_id": np.arange(25, dtype=np.int64),
            "tier": (np.arange(25) % 4).astype(np.int64)}),
    }


def _null_key_sources():
    """NaN payloads AND invalid entries on the join key: SQL semantics
    say neither matches anything."""
    uid = _rng.integers(0, 12, 120).astype(np.float64)
    uid[::5] = np.nan
    valid = np.ones(120, dtype=bool)
    valid[::7] = False
    FactN = S.Schema.of("fact", user_id=S.Column(
        "user_id", S.FLOAT64, nullable=True),
        amount=S.Column("amount", S.FLOAT64))
    UsersN = S.Schema.of("users", user_id=S.Column(
        "user_id", S.FLOAT64), tier=S.Column("tier", S.INT64))
    src = {
        "fact": Table({"user_id": _ColumnData(uid, valid),
                       "amount": _rng.normal(size=120)}),
        "users": Table({"user_id": np.arange(12, dtype=np.float64),
                        "tier": (np.arange(12) % 3).astype(np.int64)}),
    }
    return FactN, UsersN, src


# each fixture: (id, sql text, hand-built pipeline factory, sources)

def _fx_inner_join():
    q = ("SELECT f.user_id, f.amount, u.tier FROM fact f "
         "JOIN users u ON f.user_id = u.user_id WHERE u.tier > 1")

    def build():
        p = Pipeline("hand")
        p.source("fact", Fact)
        p.source("users", Users)
        p.sql(name="out", inputs={"f": "fact", "u": "users"},
              input_schemas={"f": Fact, "u": Users},
              output_schema=S.Schema.of(
                  "out", user_id=int, amount=float, tier=int),
              join_with="users", join_on=["user_id"],
              filter_expr=(col("tier") > lit(1)),
              exprs=[col("user_id"), col("amount"), col("tier")])
        return p

    return q, build, _sources()


def _fx_left_join():
    q = ("SELECT f.user_id, f.amount, u.tier FROM fact f "
         "LEFT JOIN users u ON f.user_id = u.user_id")

    def build():
        p = Pipeline("hand")
        p.source("fact", Fact)
        p.source("users", Users)
        p.sql(name="out", inputs={"f": "fact", "u": "users"},
              input_schemas={"f": Fact, "u": Users},
              output_schema=S.Schema.of(
                  "out", user_id=S.Column("user_id", S.INT64),
                  amount=S.Column("amount", S.FLOAT64),
                  tier=S.Column("tier", S.INT64, nullable=True)),
              join_with="users", join_on=["user_id"], join_how="left",
              exprs=[col("user_id"), col("amount"), col("tier")])
        return p

    # fact keys range to 40, users stop at 25: unmatched rows NULL-fill
    return q, build, _sources()


def _fx_null_nan_keys():
    FactN, UsersN, src = _null_key_sources()
    q = ("SELECT f.user_id, f.amount, u.tier FROM fact f "
         "JOIN users u ON f.user_id = u.user_id")

    def build():
        p = Pipeline("hand")
        p.source("fact", FactN)
        p.source("users", UsersN)
        p.sql(name="out", inputs={"f": "fact", "u": "users"},
              input_schemas={"f": FactN, "u": UsersN},
              output_schema=S.Schema.of(
                  "out",
                  user_id=S.Column("user_id", S.FLOAT64, nullable=True,
                                   inherited_from="fact.user_id"),
                  amount=S.Column("amount", S.FLOAT64),
                  tier=S.Column("tier", S.INT64)),
              join_with="users", join_on=["user_id"],
              exprs=[col("user_id"), col("amount"), col("tier")])
        return p

    return q, build, src


def _fx_computed_where():
    q = ("SELECT user_id, amount FROM fact "
         "WHERE amount * 2.0 > 0.5 AND NOT segment = 3")

    def build():
        p = Pipeline("hand")
        p.source("fact", Fact)
        p.sql(name="out", inputs={"f": "fact"},
              input_schemas={"f": Fact},
              output_schema=S.Schema.of(
                  "out", user_id=int, amount=float),
              filter_expr=((col("amount") * lit(2.0) > lit(0.5))
                           & ~(col("segment") == lit(3))),
              exprs=[col("user_id"), col("amount")])
        return p

    return q, build, _sources()


def _fx_group_by_all_aggs():
    q = ("SELECT segment, SUM(amount) AS amount_sum, "
         "COUNT(amount) AS amount_count, MIN(amount) AS amount_min, "
         "MAX(amount) AS amount_max, MEAN(amount) AS amount_mean "
         "FROM fact GROUP BY segment")

    def build():
        p = Pipeline("hand")
        p.source("fact", Fact)
        p.sql(name="out", inputs={"f": "fact"},
              input_schemas={"f": Fact},
              output_schema=S.Schema.of(
                  "out",
                  segment=S.Column("segment", S.INT64),
                  amount_sum=S.Column("amount_sum", S.FLOAT64),
                  amount_count=S.Column("amount_count", S.INT64),
                  amount_min=S.Column("amount_min", S.FLOAT64),
                  amount_max=S.Column("amount_max", S.FLOAT64),
                  amount_mean=S.Column("amount_mean", S.FLOAT64)),
              group_keys=["segment"],
              agg_specs=[("sum", "amount"), ("count", "amount"),
                         ("min", "amount"), ("max", "amount"),
                         ("mean", "amount")])
        return p

    return q, build, _sources()


FIXTURES = [_fx_inner_join, _fx_left_join, _fx_null_nan_keys,
            _fx_computed_where, _fx_group_by_all_aggs]


def _hand_built_fingerprint(build, sources, backend):
    c = Client()
    for t, tab in sources.items():
        c.write_source_table("main", t, tab)
    with exec_backends.use_backend(backend):
        c.run(plan(build()), "main", cache=False)
    return c.read_table("main", "out").fingerprint()


def _sql_fingerprints(q, sources, backend):
    c = Client()
    for t, tab in sources.items():
        c.write_source_table("main", t, tab)
    with exec_backends.use_backend(backend):
        opt = c.sql(q, cache=False)
        raw = c.sql(q, optimizer_passes=(), cache=False)
    return opt, raw


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("make", FIXTURES,
                         ids=lambda f: f.__name__.lstrip("_fx_"))
def test_sql_equals_hand_built_bit_for_bit(make, backend):
    q, build, sources = make()
    want = _hand_built_fingerprint(build, sources, backend)
    opt, raw = _sql_fingerprints(q, sources, backend)
    assert opt.fingerprint() == want        # optimized SQL == hand-built
    assert raw.fingerprint() == want        # unoptimized SQL == hand-built
    # and the inferred contract names exactly the hand-declared columns
    assert (list(opt.schema.columns())
            == list(build().nodes["out"].output_schema.columns()))


def test_left_join_actually_produces_nulls():
    """Guard against the LEFT fixture silently testing an inner join."""
    q, _, sources = _fx_left_join()
    c = Client()
    for t, tab in sources.items():
        c.write_source_table("main", t, tab)
    r = c.sql(q)
    tier = r.table._data["tier"]
    assert tier.valid is not None and not tier.valid.all()
    assert r.schema.columns()["tier"].nullable


def test_null_nan_keys_match_nothing():
    q, _, sources = _fx_null_nan_keys()
    c = Client()
    for t, tab in sources.items():
        c.write_source_table("main", t, tab)
    r = c.sql(q)
    got = np.asarray(r.table.column("user_id"))
    assert len(got) > 0
    assert not np.isnan(got).any()          # NaN keys dropped
