"""Columnar Table: relational ops, null semantics, snapshots, property
tests (hypothesis) for the invariants the runner depends on."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property sweeps need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.store import MemoryStore
from repro.data.tables import Table, arrow_cast, col, lit, str_lit


def people():
    return Table({
        "name": np.array(["ann", "bob", None, "dan"], dtype=object),
        "age": np.array([30, 40, 50, 60], dtype=np.int64),
        "score": np.array([0.5, 0.25, 0.75, 1.0]),
    })


def test_select_and_alias():
    t = people().select([col("age"), (col("score") * 2).alias("s2")])
    assert t.column_names() == ["age", "s2"]
    np.testing.assert_allclose(t.column("s2"), [1.0, 0.5, 1.5, 2.0])


def test_filter_null_predicate_drops_row():
    """SQL semantics: a NULL predicate drops the row."""
    t = people().filter(col("name") == lit("ann"))
    assert t.num_rows == 1
    # row with NULL name never matches (even for != comparisons)
    t2 = people().filter(col("name") != lit("ann"))
    assert t2.num_rows == 2


def test_is_not_null():
    t = people().filter(col("name").is_not_null())
    assert t.num_rows == 3
    assert not t.has_nulls("name")


def test_arrow_cast_listing5():
    t = people().select([
        arrow_cast(col("score"), str_lit("Int64")).alias("score")])
    assert t.column("score").dtype == np.int64


def test_join_inner():
    left = Table({"k": np.array([1, 2, 3]), "a": np.array([10, 20, 30])})
    right = Table({"k": np.array([2, 3, 4]), "b": np.array([200, 300,
                                                            400])})
    j = left.join(right, on=["k"])
    assert j.num_rows == 2
    np.testing.assert_array_equal(j.column("k"), [2, 3])
    np.testing.assert_array_equal(j.column("b"), [200, 300])


def test_group_by_sum_listing1():
    t = Table({"col1": np.array(["a", "a", "b"], dtype=object),
               "col3": np.array([1, 2, 3], dtype=np.int64)})
    g = t.group_by_sum(["col1"], "col3", out="_S")
    assert g.num_rows == 2
    np.testing.assert_array_equal(g.column("_S"), [3, 3])


def test_snapshot_roundtrip_identity():
    store = MemoryStore()
    t = people()
    key = t.to_blobs(store)
    t2 = Table.from_blobs(store, key)
    assert t.fingerprint() == t2.fingerprint()
    assert t2.has_nulls("name")


def test_snapshot_content_addressed_dedup():
    store = MemoryStore()
    assert people().to_blobs(store) == people().to_blobs(store)


@settings(max_examples=30, deadline=None)
@given(vals=st.lists(st.integers(-1000, 1000), min_size=1, max_size=50),
       thresh=st.integers(-1000, 1000))
def test_property_filter_partition(vals, thresh):
    """filter(p) ∪ filter(¬p) is a partition of the rows."""
    t = Table({"x": np.array(vals, dtype=np.int64)})
    lo = t.filter(col("x") < lit(thresh))
    hi = t.filter(col("x") >= lit(thresh))
    assert lo.num_rows + hi.num_rows == t.num_rows
    merged = sorted(lo.column("x").tolist() + hi.column("x").tolist())
    assert merged == sorted(vals)


@settings(max_examples=30, deadline=None)
@given(vals=st.lists(st.floats(allow_nan=False, allow_infinity=False,
                               width=32),
                     min_size=1, max_size=40))
def test_property_snapshot_roundtrip(vals):
    store = MemoryStore()
    t = Table({"x": np.array(vals, dtype=np.float32)})
    t2 = Table.from_blobs(store, t.to_blobs(store))
    np.testing.assert_array_equal(t.column("x"), t2.column("x"))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 30), seed=st.integers(0, 99))
def test_property_group_by_sum_total(n, seed):
    """Σ over groups == Σ over rows."""
    rng = np.random.default_rng(seed)
    t = Table({"k": rng.integers(0, 5, n).astype(np.int64),
               "v": rng.integers(-100, 100, n).astype(np.int64)})
    g = t.group_by_sum(["k"], "v", out="s")
    assert g.column("s").sum() == t.column("v").sum()
