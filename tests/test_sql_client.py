"""The SQL front door's entry points (DESIGN.md §13):
``Client.sql(query, ref=...)`` — catalog discovery at a pinned ref,
compile-time errors naming the ref, content-addressed caching where two
spellings of one query share an entry — and ``Pipeline.sql_query`` as a
node-authoring surface inside transactional runs."""
import numpy as np
import pytest

from repro.core import schema as S
from repro.core.dag import Pipeline
from repro.core.errors import PlanError
from repro.core.planner import plan
from repro.core.runner import Client, QueryResult
from repro.data.tables import Table, col
from repro.sql.errors import SqlCompileError

Q_ACCEPT = ("SELECT u.name, SUM(o.amount) AS total FROM users u "
            "JOIN orders o ON u.id = o.user_id WHERE o.amount > 10 "
            "GROUP BY u.name ORDER BY total DESC LIMIT 5")


def users_table():
    return Table({
        "id": np.array([1, 2, 3, 4], dtype=np.int64),
        "name": np.array(["ann", "bob", "cyd", "dee"], dtype=object)})


def orders_table():
    return Table({
        "order_id": np.array([10, 11, 12, 13, 14], dtype=np.int64),
        "user_id": np.array([1, 2, 3, 3, 3], dtype=np.int64),
        "amount": np.array([20.0, 30.0, 40.0, 50.0, 5.0]),
        "status": np.array(["ok", "ok", "ok", "late", "ok"],
                           dtype=object)})


@pytest.fixture()
def client():
    c = Client()
    c.write_source_table("main", "users", users_table())
    c.write_source_table("main", "orders", orders_table())
    return c


# --- end-to-end -------------------------------------------------------------

def test_acceptance_query_end_to_end(client):
    r = client.sql(Q_ACCEPT)
    assert isinstance(r, QueryResult)
    assert r.table.column_names() == ["name", "total"]
    assert list(r.table.column("name")) == ["cyd", "bob", "ann"]
    assert list(r.table.column("total")) == [90.0, 30.0, 20.0]
    assert r.executed == ("query",) and r.cached == ()
    assert r.query == Q_ACCEPT
    assert r.commit_id == client.catalog.head("main").id
    cols = r.schema.columns()
    assert cols["name"].dtype is S.STR
    assert cols["name"].inherited_from == "users.name"
    assert cols["total"].dtype is S.FLOAT64


def test_rerun_same_commit_is_pure_cache_hit(client):
    r1 = client.sql(Q_ACCEPT)
    r2 = client.sql(Q_ACCEPT)
    assert r2.executed == ()                 # zero nodes executed
    assert r2.cached == ("query",)
    assert r2.fingerprint() == r1.fingerprint()
    assert r2.snapshot == r1.snapshot


def test_two_spellings_share_one_cache_entry(client):
    r1 = client.sql(Q_ACCEPT)
    respelled = ("select   users.name, sum( orders.amount )  total  "
                 "from users  join orders on orders.user_id = users.id "
                 "where orders.amount > 10 "
                 "group by name order by total desc limit 5")
    r2 = client.sql(respelled)
    assert r2.executed == ()                 # same logical tree: free hit
    assert r2.fingerprint() == r1.fingerprint()


def test_new_commit_invalidates_the_hit(client):
    r1 = client.sql(Q_ACCEPT)
    extra = Table({
        "order_id": np.array([99], dtype=np.int64),
        "user_id": np.array([4], dtype=np.int64),
        "amount": np.array([100.0]),
        "status": np.array(["ok"], dtype=object)})
    client.write_source_table("main", "orders", extra)
    r2 = client.sql(Q_ACCEPT)
    assert r2.executed == ("query",)         # inputs moved: must rerun
    assert r2.fingerprint() != r1.fingerprint()


def test_ref_pinning_reads_the_named_commit(client):
    old = client.catalog.head("main").id
    client.write_source_table("main", "orders", Table({
        "order_id": np.array([99], dtype=np.int64),
        "user_id": np.array([1], dtype=np.int64),
        "amount": np.array([1000.0]),
        "status": np.array(["ok"], dtype=object)}))
    r_old = client.sql(Q_ACCEPT, ref=old)
    r_new = client.sql(Q_ACCEPT)
    assert list(r_old.table.column("name")) == ["cyd", "bob", "ann"]
    assert list(r_new.table.column("total")) == [1000.0]
    assert r_old.commit_id == old != r_new.commit_id


def test_unoptimized_matches_optimized(client):
    r_opt = client.sql(Q_ACCEPT)
    r_raw = client.sql(Q_ACCEPT, optimizer_passes=(), cache=False)
    assert r_raw.fingerprint() == r_opt.fingerprint()
    assert r_raw.plan.optimizer_passes == ()
    assert r_opt.plan.optimizer_passes != ()


def test_cache_false_always_executes(client):
    client.sql(Q_ACCEPT)
    r = client.sql(Q_ACCEPT, cache=False)
    assert r.executed == ("query",)


# --- EXPLAIN output ----------------------------------------------------------

def test_describe_pins_query_header_format(client):
    r = client.sql("SELECT   name\nFROM users\nWHERE id > 1")
    lines = r.describe().splitlines()
    assert lines[0].startswith("plan sql (code=")
    # pinned: the original text, whitespace-normalized, right after
    # the plan header and before any wave line.
    assert lines[1] == "  query[query]: SELECT name FROM users WHERE id > 1"
    assert lines[2].startswith("  [wave 0]")


def test_describe_shows_optimizer_provenance(client):
    r = client.sql(Q_ACCEPT)
    text = r.describe()
    assert "optimizer: passes=" in text
    assert "filter_pushdown" in text


# --- compile-time errors name the ref ----------------------------------------

def test_unknown_table_names_ref_and_commit(client):
    cid = client.catalog.head("main").id
    with pytest.raises(SqlCompileError) as ei:
        client.sql("SELECT x FROM userz")
    assert str(ei.value) == (
        f"unknown table 'userz' at ref 'main' (commit {cid}); "
        f"did you mean 'users'? known tables: ['orders', 'users']")


def test_unknown_column_names_ref_and_commit(client):
    cid = client.catalog.head("main").id
    with pytest.raises(SqlCompileError) as ei:
        client.sql("SELECT o.amnt FROM orders o")
    assert str(ei.value) == (
        f"unknown column 'amnt' in table 'orders' at ref 'main' "
        f"(commit {cid}); did you mean 'amount'?")


def test_discovery_infers_nullability_from_snapshot(client):
    client.write_source_table("main", "notes", Table({
        "k": np.array([1, 2], dtype=np.int64),
        "txt": np.array(["a", None], dtype=object)}))
    r = client.sql("SELECT txt FROM notes")
    assert r.schema.columns()["txt"].nullable
    r2 = client.sql("SELECT k FROM notes")
    assert not r2.schema.columns()["k"].nullable


# --- Pipeline.sql_query -------------------------------------------------------

def test_sql_query_node_in_transactional_run(client):
    p = Pipeline("sqlnodes")
    p.source("users", _discover(client, "users"))
    p.source("orders", _discover(client, "orders"))
    spend = p.sql_query(
        name="spend",
        query="SELECT u.name, SUM(o.amount) AS total FROM users u "
              "JOIN orders o ON u.id = o.user_id GROUP BY u.name")
    # downstream nodes can consume the inferred contract like any other
    p.sql(name="big", inputs={"s": "spend"},
          input_schemas={"s": spend.output_schema},
          output_schema=S.Schema.of(
              "big",
              name=S.Column("name", S.STR,
                            inherited_from="spend_schema.name"),
              total=S.Column("total", S.FLOAT64,
                             inherited_from="spend_schema.total")),
          filter_expr=(col("total") > 25.0),
          exprs=[col("name"), col("total")])
    res = client.run(plan(p), "main")
    assert res.state.status == "committed"
    big = client.read_table("main", "big")
    assert sorted(big.column("name")) == ["bob", "cyd"]


def _discover(client, table):
    from repro.sql.discovery import schema_from_snapshot
    snap = client.catalog.head("main").tables[table]
    return schema_from_snapshot(client.store, snap, table)


def test_sql_query_unknown_column_names_pipeline():
    p = Pipeline("bad")
    p.source("users", S.Schema.of(
        "users", id=S.Column("id", S.INT64),
        name=S.Column("name", S.STR)))
    with pytest.raises(SqlCompileError) as ei:
        p.sql_query(name="q", query="SELECT nme FROM users")
    assert str(ei.value) == ("unknown column 'nme' at pipeline 'bad'; "
                             "did you mean 'name'?")


def test_sql_query_sees_upstream_node_outputs():
    Users = S.Schema.of("users", id=S.Column("id", S.INT64),
                        name=S.Column("name", S.STR))
    p = Pipeline("chain")
    p.source("users", Users)
    p.sql_query(name="ids", query="SELECT id FROM users WHERE id > 1")
    node = p.sql_query(name="doubled",
                       query="SELECT id * 2 AS twice FROM ids")
    assert node.inputs == {"ids": "ids"}
    assert node.output_schema.columns()["twice"].dtype is S.INT64


# --- satellite: sugar/joins mutual exclusion ---------------------------------

def test_pipeline_sql_rejects_sugar_plus_joins_chain():
    Users = S.Schema.of("users", user_id=S.Column("user_id", S.INT64))
    Orders = S.Schema.of("orders", user_id=S.Column("user_id", S.INT64),
                         amount=S.Column("amount", S.FLOAT64))
    Out = S.Schema.of(
        "out", user_id=S.Column("user_id", S.INT64,
                                inherited_from="users.user_id"))
    p = Pipeline("mixed")
    p.source("users", Users)
    p.source("orders", Orders)
    with pytest.raises(PlanError, match=r"node 'out': pass either the "
                                        r"single-join sugar"):
        p.sql(name="out", inputs={"u": "users", "o": "orders"},
              input_schemas={"u": Users, "o": Orders},
              output_schema=Out,
              join_with="orders", join_on=["user_id"],
              joins=[("orders", ["user_id"])],
              exprs=[col("user_id")])
    # each spelling alone still registers
    p.sql(name="a", inputs={"u": "users", "o": "orders"},
          input_schemas={"u": Users, "o": Orders},
          output_schema=Out,
          join_with="orders", join_on=["user_id"],
          exprs=[col("user_id")])
    p.sql(name="b", inputs={"u": "users", "o": "orders"},
          input_schemas={"u": Users, "o": Orders},
          output_schema=Out,
          joins=[("orders", ["user_id"])], exprs=[col("user_id")])
