"""End-to-end: the paper's running example (Listings 1–6) on the real
control plane (planner) + worker (runner) + catalog."""
import datetime

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.contracts import CastDecl
from repro.core.dag import Pipeline
from repro.core.errors import (ContractCompositionError, PlanError,
                               QualityError, TransactionAborted)
from repro.core.planner import plan
from repro.core.quality import (expect_in_range, expect_not_null,
                                expect_row_count)
from repro.core.runner import Client
from repro.data.tables import Table, arrow_cast, col, lit, str_lit


# --- the paper's schemas (Listing 3) ---------------------------------------

class RawSchema(S.Schema):
    col1: str
    col2: datetime.datetime
    col3: int


class ParentSchema(S.Schema):
    col1: str
    col2: datetime.datetime
    _S: int


class ChildSchema(S.Schema):
    col2: datetime.datetime
    col4: float
    col5: S.Nullable[str]


class Grand(S.Schema):
    col2: datetime.datetime
    col4: int


def paper_pipeline() -> Pipeline:
    """Listings 4–5: SQL parent + imperative child/grand_child."""
    p = Pipeline("paper_example")
    p.source("raw_table", RawSchema)

    # -- parent_table: ParentSchema <- raw_table (Listing 4)
    p.sql(name="parent_table",
          inputs={"raw": "raw_table"},
          input_schemas={"raw": RawSchema},
          output_schema=ParentSchema,
          exprs=[col("col1"), col("col2")],
          # GROUP BY col1,col2 SUM(col3) handled by group_by in runner's
          # declarative node; here we model SELECT+SUM via group keys:
          join_with=None)

    @p.node()
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        return df.select([
            col("col2"),
            lit(0.25).alias("col4"),
            lit(None).alias("col5"),
        ])

    @p.node(casts=[CastDecl("col4", S.INT)])
    def grand_child(df: ChildSchema = child_table) -> Grand:
        return df.select([
            col("col2"),
            arrow_cast(col("col4"), str_lit("Int64")).alias("col4"),
        ])

    return p


def raw_table() -> Table:
    return Table({
        "col1": np.array(["a", "a", "b"], dtype=object),
        "col2": np.array(["2026-01-01"] * 3, dtype="datetime64[ns]"),
        "col3": np.array([1, 2, 3], dtype=np.int64),
    })


@pytest.fixture
def client():
    c = Client()
    c.write_source_table("main", "raw_table", raw_table())
    return c


def _mk_parent(raw: Table) -> Table:
    return raw.group_by_sum(["col1", "col2"], "col3", out="_S")


def test_plan_composes_and_orders(client):
    p = paper_pipeline()
    pl = plan(p)
    assert [s.node.name for s in pl.steps] == [
        "parent_table", "child_table", "grand_child"]
    # grand_child narrows col4 with a declared cast
    g = next(s for s in pl.steps if s.node.name == "grand_child")
    assert "col4" in g.report.narrowed


def test_plan_rejects_missing_cast_at_control_plane():
    """Fail-fast moment 2: the ill-typed DAG is rejected BEFORE any
    execution (never reaches a worker)."""
    p = Pipeline("bad")
    p.source("raw_table", RawSchema)

    @p.node()   # narrowing float->int with NO cast declared
    def child(df: RawSchema = "raw_table") -> S.Schema.of("Bad", col3=S.INT32):
        return df

    with pytest.raises(ContractCompositionError):
        plan(p)


def test_plan_rejects_cycles_and_missing_inputs():
    p = Pipeline("cyclic")
    A = S.Schema.of("A", x=int)

    @p.node()
    def n1(df: A = "n2") -> A:
        return df

    @p.node()
    def n2(df: A = "n1") -> A:
        return df

    with pytest.raises(PlanError, match="cycle"):
        plan(p)

    q = Pipeline("dangling")

    @q.node()
    def n3(df: A = "ghost_table") -> A:
        return df

    with pytest.raises(PlanError):
        plan(q)


def test_run_happy_path_atomic(client):
    p = Pipeline("ok")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return _mk_parent(df)

    @p.node()
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        return df.select([col("col2"), lit(0.25).alias("col4"),
                          lit(None).alias("col5")])

    @p.node(casts=[CastDecl("col4", S.INT)])
    def grand_child(df: ChildSchema = child_table) -> Grand:
        return df.select([col("col2"),
                          arrow_cast(col("col4"),
                                     str_lit("Int64")).alias("col4")])

    result = client.run(plan(p), "main")
    assert result.state.status == "committed"
    assert set(result.tables) == {"parent_table", "child_table",
                                  "grand_child"}
    out = client.read_table("main", "grand_child")
    assert out.logical_dtype("col4") in ("int", "int64")  # cast applied
    # run_id → (data commit, code hash): Listing 6 reproducibility
    st = client.get_run(result.state.run_id)
    assert st.ref and st.code_hash


def test_run_failure_aborts_atomically(client):
    p = Pipeline("fails")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return _mk_parent(df)

    @p.node()
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        return df.select([col("col2"), lit(0.25).alias("col4"),
                          lit(None).alias("col5")])

    before = client.catalog.tables("main")
    with pytest.raises(TransactionAborted) as ei:
        client.run(plan(p), "main", fail_after="parent_table")
    # main unchanged: the half-written pipeline is invisible
    assert client.catalog.tables("main") == before
    # the aborted branch holds the partial result for triage
    branch = ei.value.branch
    assert client.catalog.read_table(branch, "parent_table")


def test_worker_moment_output_violating_schema(client):
    """Moment 3: a node returning data that violates its declared output
    schema is caught BEFORE persisting."""
    p = Pipeline("liar")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return df.select([col("col1")])     # missing col2/_S!

    before = client.catalog.tables("main")
    with pytest.raises(TransactionAborted):
        client.run(plan(p), "main")
    assert client.catalog.tables("main") == before


def test_quality_verifiers_run_before_publish(client):
    p = Pipeline("quality")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return _mk_parent(df)

    verifiers = {"parent_table": [expect_row_count(10, None)]}  # will fail
    with pytest.raises(TransactionAborted):
        client.run(plan(p), "main", verifiers=verifiers)

    ok = {"parent_table": [expect_row_count(1, 100),
                           expect_not_null("col1"),
                           expect_in_range("_S", 0, 100)]}
    res = client.run(plan(p), "main", verifiers=ok)
    assert res.state.status == "committed"


def test_listing6_workflow_branch_run_merge_reproduce(client):
    """Listing 6 verbatim: feature branch → run → merge → reproduce."""
    p = Pipeline("dag")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return _mk_parent(df)

    client.create_branch("feature", from_ref="main")
    run_state = client.run(plan(p), "feature").state
    assert run_state.run_id and run_state.ref
    client.merge("feature", into="main")
    assert client.read_table("main", "parent_table").num_rows == 2

    # later: reproduce from the run_id — same data commit + code hash
    prod = client.get_run(run_state.run_id)
    client.create_branch("repro", from_ref="feature")
    rerun = client.run(plan(p), "repro").state
    assert rerun.code_hash == prod.code_hash
    t1 = client.read_table("main", "parent_table")
    t2 = client.read_table("repro", "parent_table")
    assert t1.fingerprint() == t2.fingerprint()     # bitwise reproducible


def test_dry_run_touches_nothing(client):
    p = Pipeline("dry")
    p.source("raw_table", RawSchema)

    @p.node()
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return _mk_parent(df)

    head = client.catalog.head("main").id
    res = client.run(plan(p), "main", dry_run=True)
    assert res.state.status == "dry"
    assert client.catalog.head("main").id == head
    assert client.catalog.branches() == ["main"]


def test_static_discharge_elides_null_checks(client):
    """Appendix A: not-null checks provably preserved by declarative
    nodes are elided from the worker."""
    p = Pipeline("elide")
    p.source("raw_table", RawSchema)
    Passthrough = S.Schema.of("Passthrough", col1=str, col3=int)
    p.sql(name="pass_table", inputs={"raw": "raw_table"},
          input_schemas={"raw": RawSchema}, output_schema=Passthrough,
          exprs=[col("col1"), col("col3")])
    pl = plan(p)
    step = pl.steps[0]
    assert step.elided_null_checks == frozenset({"col1", "col3"})


def test_paper_pipeline_config_module():
    """The canonical paper DAG (configs/paper_pipeline.py), including the
    Appendix-A binary node, plans and runs end to end."""
    from repro.configs.paper_pipeline import build_pipeline, seed_lake
    from repro.core.runner import Client as C2

    c = C2()
    seed_lake(c)
    pl = plan(build_pipeline(with_friend=True))
    names = [s.node.name for s in pl.steps]
    assert names[:3] == ["parent_table", "child_table", "grand_child"]
    assert "family_friend" in names
    res = c.run(pl, "main")
    assert res.state.status == "committed"
    ff = c.read_table("main", "family_friend")
    assert not ff.has_nulls("col5")        # [NotNull] enforced physically
