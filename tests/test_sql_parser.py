"""Tokenizer + recursive-descent parser for the SQL front door
(DESIGN.md §13): token shapes, precedence, join/group/order clauses,
and the pinned ``syntax error at position N`` message format."""
import pytest

from repro.core.errors import PlanError
from repro.sql import ast as A
from repro.sql.errors import SqlError, SqlParseError
from repro.sql.parser import parse
from repro.sql.tokens import tokenize


# --- tokenizer -------------------------------------------------------------

def test_tokenize_kinds_and_positions():
    toks = tokenize("SELECT a.b, 'x''y' FROM t WHERE n >= 1.5e3")
    kinds = [(t.kind, t.text) for t in toks]
    assert kinds == [
        ("KEYWORD", "SELECT"), ("IDENT", "a"), ("PUNCT", "."),
        ("IDENT", "b"), ("PUNCT", ","), ("STRING", "x'y"),
        ("KEYWORD", "FROM"), ("IDENT", "t"), ("KEYWORD", "WHERE"),
        ("IDENT", "n"), ("OP", ">="), ("FLOAT", "1.5e3"), ("EOF", ""),
    ]
    # positions are character offsets into the query text
    assert toks[0].pos == 0
    assert toks[5].pos == 12          # the string literal's quote
    assert toks[-1].pos == len("SELECT a.b, 'x''y' FROM t WHERE n >= 1.5e3")


def test_tokenize_keywords_case_insensitive_idents_keep_case():
    toks = tokenize("select Foo frOm Bar")
    assert [(t.kind, t.text) for t in toks[:-1]] == [
        ("KEYWORD", "SELECT"), ("IDENT", "Foo"),
        ("KEYWORD", "FROM"), ("IDENT", "Bar")]


def test_tokenize_longest_operator_wins():
    toks = tokenize("a<=b <> c != d == e")
    ops = [t.text for t in toks if t.kind == "OP"]
    assert ops == ["<=", "<>", "!=", "=="]


def test_tokenize_numbers():
    toks = tokenize("1 2.5 .5 1e3 1.5E-2")
    assert [(t.kind, t.text) for t in toks[:-1]] == [
        ("INT", "1"), ("FLOAT", "2.5"), ("FLOAT", ".5"),
        ("FLOAT", "1e3"), ("FLOAT", "1.5E-2")]


def test_tokenize_unterminated_string():
    with pytest.raises(SqlParseError, match="unterminated string"):
        tokenize("SELECT 'oops FROM t")


def test_tokenize_unexpected_character():
    with pytest.raises(SqlParseError,
                       match=r"unexpected character '#' at position 7"):
        tokenize("SELECT #")


# --- parser: shapes --------------------------------------------------------

def test_parse_minimal_select():
    q = parse("SELECT a FROM t")
    assert q.from_table == A.TableRef("t", None, pos=q.from_table.pos)
    assert len(q.items) == 1
    assert q.items[0].expr == A.ColumnRef(None, "a", q.items[0].expr.pos)
    assert q.items[0].alias is None
    assert q.joins == () and q.where is None
    assert q.group_by == () and q.order_by == () and q.limit is None


def test_parse_aliases_with_and_without_as():
    q = parse("SELECT a AS x, b y FROM t AS u")
    assert [i.alias for i in q.items] == ["x", "y"]
    assert q.from_table.alias == "u"
    q2 = parse("SELECT a x FROM t u")
    assert q2.items[0].alias == "x" and q2.from_table.alias == "u"


def test_parse_star_and_qualified_star():
    q = parse("SELECT *, u.* FROM t JOIN u ON t.k = u.k")
    assert q.items[0].expr == A.Star(None, q.items[0].expr.pos)
    assert q.items[1].expr == A.Star("u", q.items[1].expr.pos)


def test_parse_join_variants():
    q = parse("SELECT a FROM t JOIN u ON t.k = u.k "
              "LEFT JOIN v ON u.j = v.j AND u.m = v.m "
              "LEFT OUTER JOIN w ON v.i = w.i "
              "INNER JOIN x ON w.h = x.h")
    assert [j.how for j in q.joins] == ["inner", "left", "left", "inner"]
    assert len(q.joins[1].on) == 2
    a, b = q.joins[0].on[0]
    assert (a.table, a.name) == ("t", "k")
    assert (b.table, b.name) == ("u", "k")


def test_parse_where_precedence():
    # OR binds loosest: (a=1 AND b=2) OR NOT c=3
    q = parse("SELECT a FROM t WHERE a = 1 AND b = 2 OR NOT c = 3")
    w = q.where
    assert isinstance(w, A.BinOp) and w.op == "OR"
    assert isinstance(w.left, A.BinOp) and w.left.op == "AND"
    assert isinstance(w.right, A.UnaryOp) and w.right.op == "NOT"
    assert isinstance(w.right.operand, A.BinOp)
    assert w.right.operand.op == "="


def test_parse_arithmetic_precedence():
    # a + b * -c  parses as  a + (b * (-c))
    q = parse("SELECT a + b * -c FROM t")
    e = q.items[0].expr
    assert isinstance(e, A.BinOp) and e.op == "+"
    assert isinstance(e.right, A.BinOp) and e.right.op == "*"
    assert isinstance(e.right.right, A.UnaryOp)
    assert e.right.right.op == "-"


def test_parse_comparison_normalization():
    for spelled, canon in [("=", "="), ("==", "="),
                           ("!=", "!="), ("<>", "!=")]:
        q = parse(f"SELECT a FROM t WHERE a {spelled} 1")
        assert q.where.op == canon, spelled


def test_parse_is_null_and_is_not_null():
    q = parse("SELECT a FROM t WHERE a IS NULL AND b IS NOT NULL")
    left, right = q.where.left, q.where.right
    assert isinstance(left, A.IsNull) and not left.negated
    assert isinstance(right, A.IsNull) and right.negated


def test_parse_literals():
    q = parse("SELECT 1, 2.5, 'it''s', TRUE, FALSE, NULL FROM t")
    vals = [i.expr.value for i in q.items]
    assert vals == [1, 2.5, "it's", True, False, None]
    assert isinstance(vals[0], int) and isinstance(vals[1], float)


def test_parse_aggregates_and_avg_synonym():
    q = parse("SELECT SUM(a), COUNT(b), MIN(c), MAX(d), MEAN(e), AVG(e) "
              "FROM t GROUP BY k")
    fns = [i.expr.fn for i in q.items]
    assert fns == ["sum", "count", "min", "max", "mean", "mean"]
    assert q.group_by == (A.ColumnRef(None, "k", q.group_by[0].pos),)


def test_parse_count_star_rejected():
    with pytest.raises(SqlParseError,
                       match=r"COUNT\(\*\) is not supported"):
        parse("SELECT COUNT(*) FROM t GROUP BY k")


def test_parse_order_by_and_limit():
    q = parse("SELECT a, b FROM t ORDER BY a DESC, b, t.a ASC LIMIT 7")
    assert [(o.ref.display(), o.ascending) for o in q.order_by] == [
        ("a", False), ("b", True), ("t.a", True)]
    assert q.limit == 7


def test_parse_parenthesized_expressions():
    q = parse("SELECT (a + b) * 2 FROM t")
    e = q.items[0].expr
    assert e.op == "*" and e.left.op == "+"


# --- parser: errors (pinned format) ----------------------------------------

def test_parse_error_format_position_and_got():
    with pytest.raises(
            SqlParseError,
            match=r"syntax error at position 11: expected FROM, got 'c'"):
        parse("SELECT a b c")   # alias consumed 'b'; 'c' has no home


def test_parse_error_end_of_query():
    with pytest.raises(SqlParseError,
                       match="expected an expression, got end of query"):
        parse("SELECT a FROM t WHERE")


def test_parse_trailing_garbage():
    with pytest.raises(SqlParseError, match="expected end of query"):
        parse("SELECT a FROM t LIMIT 1 extra")


def test_parse_empty_query():
    with pytest.raises(SqlParseError, match="empty query"):
        parse("   ")


def test_parse_limit_requires_integer():
    with pytest.raises(SqlParseError, match="expected an integer LIMIT"):
        parse("SELECT a FROM t LIMIT 1.5")


def test_parse_join_on_requires_column_equality():
    with pytest.raises(SqlParseError,
                       match="'=' between join key columns"):
        parse("SELECT a FROM t JOIN u ON t.k < u.k")


def test_sql_errors_are_plan_errors():
    # an unparseable query is an ill-typed pipeline: one except clause
    # catches both hand-built and SQL-authored planning failures.
    with pytest.raises(PlanError):
        parse("SELECT")
    assert issubclass(SqlParseError, SqlError)
    assert issubclass(SqlError, PlanError)
