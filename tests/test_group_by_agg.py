"""Differential + regression suite for multi-function ``group_by_agg``.

The aggregation refactor's proof obligations, in one place:

- every registered backend reproduces the ``reference`` oracle on
  every aggregate fn (sum/count/min/max/mean) over adversarial
  fixtures — NULL values, all-NULL groups, NULL and NaN keys, object
  payloads, empty tables — bit for bit, except the documented float
  SUM/MEAN summation-order carve-out (compared with *absolute*
  tolerance: regrouped near-zero float sums drift absolutely, not
  relatively);
- integer aggregates (including MEAN, finalized as an exact float64
  division of exact sums) fingerprint identically across ALL backends
  — no tolerance anywhere;
- the ``group_by_sum`` wrapper stays byte-identical to the general
  path (the PR 2/PR 4 NULL-semantics pins ride on it);
- the ``auto`` policy's ``choose_group_by_agg`` decision table as a
  pure function, and its cache token (policy v2, composed delegate
  tokens);
- the optimizer over ``Aggregate``: key-only filter pushdown below
  the aggregation (with the float-key guard), column pruning through
  it (including contract anchors released by ``computed=``), and the
  ``partial_agg`` routing rewrite — optimized vs unoptimized
  fingerprints exactly equal on integer fixtures.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import exec as exec_backends
from repro.core import schema as S
from repro.core.contracts import referenced_columns
from repro.core.dag import Pipeline
from repro.core.logical import Aggregate, Filter, Scan
from repro.core.planner import plan
from repro.data.tables import Table, _ColumnData, col
from repro.exec.base import AGG_FNS, normalize_agg_specs
from repro.exec.stats import TableStats

BACKENDS = exec_backends.available_backends()
OTHERS = [b for b in BACKENDS if b != "reference"]

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------

def _masked(values, valid):
    return _ColumnData(np.asarray(values), np.asarray(valid, dtype=bool))


def adversarial_table(n: int, seed: int) -> Table:
    """Every landmine at once: negative int keys, NULL-masked keys and
    values, NaN float keys (each its own group) AND NaN float values
    (propagate through MIN/MAX), object-int values with None, an
    all-NULL-valued key group."""
    r = np.random.default_rng(seed)
    ki = r.integers(-3, 6, n).astype(np.int64)
    kf = r.normal(size=n)
    kf[r.random(n) < 0.1] = np.nan
    ks = np.array([None if r.random() < 0.2 else f"g{int(x) % 3}"
                   for x in ki], dtype=object)
    f = r.normal(size=n)
    f[r.random(n) < 0.1] = np.nan
    vo = np.array([None if r.random() < 0.25 else int(r.integers(-9, 9))
                   for _ in range(n)], dtype=object)
    t = Table({"kf": kf, "ks": ks, "f": f, "vo": vo})
    t._data["ki"] = _masked(ki, r.random(n) > 0.1)
    t._data["v32"] = _masked(r.integers(-1000, 1000, n).astype(np.int32),
                             r.random(n) > 0.2)
    # key ki == 5 carries only NULL values in v32: the all-NULL group
    t._data["v32"].valid[ki == 5] = False
    return t


ALL_SPECS = tuple((fn, v) for fn in AGG_FNS for v in ("v32", "f", "vo"))
KEYSETS = (["ki"], ["kf"], ["ks"], ["ki", "ks"])
# float SUM/MEAN outputs: the one tolerance (absolute — near-zero sums
# of N(0,1) values drift absolutely under regrouping)
FLOAT_CARVEOUT = {"f_sum", "f_mean"}


def assert_agg_equal(got: Table, want: Table):
    assert got.column_names() == want.column_names()
    assert len(got) == len(want)
    for c in got.column_names():
        assert got.validity(c).tolist() == want.validity(c).tolist(), c
        if c in FLOAT_CARVEOUT:
            m = want.validity(c)
            np.testing.assert_allclose(
                np.asarray(got.column(c)[m], dtype=float),
                np.asarray(want.column(c)[m], dtype=float),
                rtol=1e-9, atol=1e-9)
        else:
            # repr equality: NaN == NaN, None == None, dtype-faithful
            assert ([repr(x) for x in got.column(c)]
                    == [repr(y) for y in want.column(c)]), c


# ---------------------------------------------------------------------------
# differential: every fn x adversarial fixture x every backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", OTHERS)
@pytest.mark.parametrize("keys", KEYSETS)
def test_group_by_agg_matches_reference(backend, keys):
    for seed in range(3):
        t = adversarial_table(300, seed)
        want = t.group_by(keys).agg(*ALL_SPECS, backend="reference")
        got = t.group_by(keys).agg(*ALL_SPECS, backend=backend)
        assert_agg_equal(got, want)


@pytest.mark.parametrize("backend", OTHERS)
def test_integer_aggregates_fingerprint_identically(backend):
    """No carve-out for int values: SUM (associative even under
    wraparound), COUNT, MIN, MAX, and MEAN (exact float64 division of
    exact sums) are bit-for-bit across every backend."""
    r = np.random.default_rng(42)
    n = 5000
    t = Table({"k": r.integers(0, 97, n).astype(np.int64)})
    t._data["v"] = _masked(r.integers(-10**6, 10**6, n).astype(np.int32),
                           r.random(n) > 0.1)
    specs = tuple((fn, "v") for fn in AGG_FNS)
    want = t.group_by(["k"]).agg(*specs, backend="reference")
    got = t.group_by(["k"]).agg(*specs, backend=backend)
    assert got.fingerprint() == want.fingerprint()


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_by_agg_empty_table(backend):
    t = Table({"k": np.array([], dtype=np.int64),
               "v": np.array([], dtype=np.int32)})
    g = t.group_by(["k"]).agg(*[(fn, "v") for fn in AGG_FNS],
                              backend=backend)
    assert len(g) == 0
    assert g.column_names() == ["k", "v_sum", "v_count", "v_min",
                                "v_max", "v_mean"]
    ref = t.group_by(["k"]).agg(*[(fn, "v") for fn in AGG_FNS],
                                backend="reference")
    assert g.fingerprint() == ref.fingerprint()


@pytest.mark.parametrize("backend", BACKENDS)
def test_count_is_int64_never_null(backend):
    t = Table({"k": np.array(["a", "a", "b"], dtype=object),
               "v": np.array([None, 1, None], dtype=object)})
    g = t.group_by(["k"]).agg(("count", "v", "n"), backend=backend)
    assert g.to_pydict() == {"k": ["a", "b"], "n": [1, 0]}
    assert g.column("n").dtype == np.int64
    assert not g.has_nulls("n")


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_null_group_aggregates_to_null_except_count(backend):
    t = Table({"k": np.array(["a", "b"], dtype=object),
               "v": np.array([None, 3], dtype=object)})
    g = t.group_by(["k"]).agg(("sum", "v"), ("min", "v"), ("max", "v"),
                              ("mean", "v"), ("count", "v", "n"),
                              backend=backend)
    assert g.to_pydict() == {
        "k": ["a", "b"], "v_sum": [None, 3], "v_min": [None, 3],
        "v_max": [None, 3], "v_mean": [None, 3.0], "n": [0, 1]}


@pytest.mark.parametrize("backend", BACKENDS)
def test_mean_of_ints_is_exact_float64(backend):
    t = Table({"k": np.array([1, 1, 1, 2], dtype=np.int64),
               "v": np.array([1, 2, 4, 9], dtype=np.int64)})
    g = t.group_by(["k"]).agg(("mean", "v"), backend=backend)
    assert g.column("v_mean").dtype == np.float64
    assert g.column("v_mean").tolist() == [7 / 3, 9.0]


@pytest.mark.parametrize("backend", BACKENDS)
def test_nan_value_in_valid_lane_propagates_minmax(backend):
    t = Table({"k": np.array([1, 1, 2, 2], dtype=np.int64),
               "v": np.array([1.0, np.nan, 3.0, 4.0])})
    g = t.group_by(["k"]).agg(("min", "v"), ("max", "v"),
                              backend=backend)
    assert np.isnan(g.column("v_min")[0]) and np.isnan(
        g.column("v_max")[0])
    assert g.column("v_min")[1] == 3.0 and g.column("v_max")[1] == 4.0


# ---------------------------------------------------------------------------
# group_by_sum back-compat: the wrapper is the general path
# ---------------------------------------------------------------------------

def _pin_fixtures():
    """The PR 2 / PR 4 NULL-semantics fixtures the wrapper's pins ride
    on: empty table, all-NULL group, NaN float keys, object keys."""
    empty = Table({"k": np.array([], dtype=np.int64),
                   "v": np.array([], dtype=np.int64)})
    all_null = Table({"k": np.array(["a", "b"], dtype=object),
                      "v": np.array([None, 3], dtype=object)})
    nan_keys = Table({"k": np.array([np.nan, 1.0, np.nan, 1.0]),
                      "v": np.array([1, 2, 4, 8], dtype=np.int64)})
    obj_keys = Table({"k": np.array([None, "a", None], dtype=object),
                      "v": np.array([1, 2, 4], dtype=np.int64)})
    return {"empty": empty, "all_null": all_null,
            "nan_keys": nan_keys, "obj_keys": obj_keys}


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_by_sum_wrapper_byte_identical(backend):
    """group_by_sum == group_by().agg(single sum) — same fingerprint,
    same column names — per backend, on every pin fixture."""
    for name, t in _pin_fixtures().items():
        a = t.group_by_sum(["k"], "v", out="s", backend=backend)
        b = t.group_by(["k"]).agg(("sum", "v", "s"), backend=backend)
        assert a.fingerprint() == b.fingerprint(), (name, backend)
        assert a.column_names() == ["k", "s"], (name, backend)


@pytest.mark.parametrize("backend", OTHERS)
def test_group_by_sum_pins_match_reference(backend):
    for name, t in _pin_fixtures().items():
        want = t.group_by_sum(["k"], "v", out="s", backend="reference")
        got = t.group_by_sum(["k"], "v", out="s", backend=backend)
        assert got.fingerprint() == want.fingerprint(), (name, backend)


def test_host_backend_cache_tokens_unchanged():
    """The refactor must not move host-backend cache keys: nothing
    about their execution state changed, so cached results stay valid."""
    assert exec_backends.get_backend("reference").cache_token() \
        == "reference"
    assert exec_backends.get_backend("vectorized").cache_token() \
        == "vectorized"


# ---------------------------------------------------------------------------
# spec normalization (Table API + backend layer)
# ---------------------------------------------------------------------------

def test_agg_default_names_and_decollision():
    t = Table({"k": np.array([1, 1], dtype=np.int64),
               "v": np.array([2, 3], dtype=np.int64)})
    g = t.group_by(["k"]).agg(("sum", "v"), ("sum", "v"), ("mean", "v"))
    assert g.column_names() == ["k", "v_sum", "v_sum_1", "v_mean"]
    assert g.column("v_sum").tolist() == g.column("v_sum_1").tolist()


def test_agg_explicit_out_collisions_raise():
    t = Table({"k": np.array([1], dtype=np.int64),
               "v": np.array([2], dtype=np.int64)})
    with pytest.raises(ValueError, match="collides with a group key"):
        t.group_by(["k"]).agg(("sum", "v", "k"))
    with pytest.raises(ValueError, match="more than one spec"):
        t.group_by(["k"]).agg(("sum", "v", "s"), ("min", "v", "s"))
    with pytest.raises(ValueError, match="at least one"):
        t.group_by(["k"]).agg()
    with pytest.raises(ValueError, match="expected"):
        t.group_by(["k"]).agg(("sum",))


def test_normalize_agg_specs_validates():
    cols = {"k": (np.array([1]), None), "v": (np.array([1]), None)}
    with pytest.raises(ValueError, match="unknown aggregate fn"):
        normalize_agg_specs(cols, ["k"], [("median", "v", "m")])
    with pytest.raises(KeyError, match="unknown aggregate value"):
        normalize_agg_specs(cols, ["k"], [("sum", "nope", "s")])
    with pytest.raises(ValueError, match="collides"):
        normalize_agg_specs(cols, ["k"], [("sum", "v", "k")])


# ---------------------------------------------------------------------------
# auto policy: choose_group_by_agg as a pure function + cache token
# ---------------------------------------------------------------------------

def _gb_stats(n, lo=0, hi=999):
    return TableStats(n_rows=n, key_kinds=("i",), int_key_lo=lo,
                      int_key_hi=hi)


def test_choose_group_by_agg_decision_table():
    from repro.exec.auto import choose_group_by_agg
    i32 = (np.dtype(np.int32),)
    # tiny -> reference
    assert choose_group_by_agg(_gb_stats(10), i32,
                               jax_available=True) == "reference"
    # large + mesh + dense single int key + lowerable -> sharded
    assert choose_group_by_agg(
        _gb_stats(500_000), i32, n_devices=8, sharded_available=True,
        jax_available=True) == "sharded"
    # same but single device -> jax
    assert choose_group_by_agg(
        _gb_stats(500_000), i32, n_devices=1, sharded_available=True,
        jax_available=True) == "jax"
    # sparse span blocks the sharded row (dense rebase unaffordable)
    assert choose_group_by_agg(
        _gb_stats(500_000, lo=0, hi=2**40), i32, n_devices=8,
        sharded_available=True, jax_available=True) == "jax"
    # one non-lowerable value dtype spoils the whole lowering
    assert choose_group_by_agg(
        _gb_stats(500_000), (np.dtype(np.int32), np.dtype(object)),
        n_devices=8, sharded_available=True,
        jax_available=True) == "vectorized"
    # large but no jax -> vectorized
    assert choose_group_by_agg(_gb_stats(500_000), i32,
                               jax_available=False) == "vectorized"
    # non-int key blocks the sharded row
    assert choose_group_by_agg(
        TableStats(n_rows=500_000, key_kinds=("O",)), i32, n_devices=8,
        sharded_available=True, jax_available=True) == "jax"


def test_choose_group_by_delegates_to_agg_table():
    from repro.exec.auto import choose_group_by, choose_group_by_agg
    st = _gb_stats(500_000)
    dt = np.dtype(np.int32)
    assert choose_group_by(st, dt, jax_available=True) \
        == choose_group_by_agg(st, (dt,), jax_available=True)


def test_auto_cache_token_is_v2_and_composes_delegates():
    tok = exec_backends.get_backend("auto").cache_token()
    assert tok.startswith("auto[v2;")
    # the sharded delegate's own token (or its absence marker) is
    # folded in: a mesh change moves auto's key too
    assert ("sharded" in tok) or ("sharded=-" in tok)


def test_auto_group_by_agg_matches_reference_across_sizes():
    """auto is a router: whatever it picks must agree with reference
    (int values -> bit-for-bit, both sides of the tiny threshold)."""
    for n in (40, 5000):
        r = np.random.default_rng(n)
        t = Table({"k": r.integers(0, 7, n).astype(np.int64),
                   "v": r.integers(-100, 100, n).astype(np.int64)})
        specs = tuple((fn, "v") for fn in AGG_FNS)
        assert (t.group_by(["k"]).agg(*specs, backend="auto")
                .fingerprint()
                == t.group_by(["k"]).agg(*specs, backend="reference")
                .fingerprint())


# ---------------------------------------------------------------------------
# optimizer: Aggregate-aware passes
# ---------------------------------------------------------------------------

Src = S.Schema.of("GbSrc", k=int, kf=float, v=int, junk=float)
Agg = S.Schema.of("GbAgg", k=int, v_sum=int, n=int)


def _agg_pipeline(filter_expr=None, keys=("k",)):
    p = Pipeline("gb")
    p.source("src", Src)
    p.sql(name="out", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=Agg, group_keys=list(keys),
          agg_specs=[("sum", "v"), ("count", "v", "n")],
          filter_expr=filter_expr)
    return p


def _src_table(n=400, seed=0):
    r = np.random.default_rng(seed)
    return Table({"k": r.integers(0, 9, n).astype(np.int64),
                  "kf": r.normal(size=n),
                  "v": r.integers(-50, 50, n).astype(np.int64),
                  "junk": r.normal(size=n)})


def test_declarative_aggregate_lowers_and_runs():
    p = _agg_pipeline(filter_expr=col("v") > 0)
    node = p.nodes["out"]
    assert "aggregate(keys=['k']" in node.logical_tree().describe()
    t = _src_table()
    got = node.run({"src": t})
    want = t.filter(col("v") > 0).group_by(["k"]).agg(
        ("sum", "v"), ("count", "v", "n"), backend="reference")
    assert got.fingerprint() == want.fingerprint()


def test_column_pruning_sees_through_aggregate():
    from repro.optimizer import optimize
    pl = plan(_agg_pipeline())
    opt = optimize(pl, passes=["column_pruning"])
    tree = opt.steps[0].logical
    scans = [op for op in [tree] + list(tree.children())
             if isinstance(op, Scan)]
    assert scans and scans[0].columns == ("k", "v")
    assert any("column_pruning" in m for m in opt.steps[0].provenance)
    t = _src_table()
    assert (opt.steps[0].execute({"src": t}).fingerprint()
            == pl.steps[0].execute({"src": t}).fingerprint())


def test_referenced_columns_computed_releases_agg_outputs():
    """An agg output reusing an input column's name must not anchor
    that input column against elision — it is manufactured, not
    inherited."""
    Out = S.Schema.of("GbOut", k=int, junk=int)
    refs = referenced_columns({"s": Src}, Out, computed={"junk"})
    assert refs == {"s": {"k"}}
    # without the computed marker, the by-name anchor persists
    # (conservative for non-aggregate nodes)
    refs = referenced_columns({"s": Src}, Out)
    assert refs == {"s": {"k", "junk"}}


def _filter_above_aggregate_plan(pred):
    """Hand-build the Filter(Aggregate(...)) shape (the authored DAG
    puts WHERE below GROUP BY, so the pushdown target is built
    directly, as a rewritten tree would present it)."""
    pl = plan(_agg_pipeline())
    step = pl.steps[0]
    return dataclasses.replace(
        pl, steps=(dataclasses.replace(
            step, logical=Filter(step.logical, pred)),))


def test_filter_pushdown_below_aggregate_bit_for_bit():
    from repro.optimizer import filter_pushdown
    pl = _filter_above_aggregate_plan(col("k") > 3)
    opt = filter_pushdown(pl)
    tree = opt.steps[0].logical
    # pushed: root is the Aggregate again, filter sits on its child
    assert isinstance(tree, Aggregate)
    assert isinstance(tree.child, Filter)
    assert any("below aggregate" in m
               for m in opt.steps[0].provenance)
    t = _src_table()
    for backend in BACKENDS:
        with exec_backends.use_backend(backend):
            a = pl.steps[0].execute({"src": t})
            b = opt.steps[0].execute({"src": t})
        assert a.fingerprint() == b.fingerprint(), backend


def test_filter_pushdown_float_key_guard():
    """A float group key can distinguish bit-distinct but value-equal
    representatives (-0.0 == 0.0): the predicate must stay above."""
    from repro.optimizer import filter_pushdown
    p = Pipeline("gbf")
    p.source("src", Src)
    p.sql(name="out", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=S.Schema.of("GbF", kf=float, v_sum=int),
          group_keys=["kf"], agg_specs=[("sum", "v")])
    pl = plan(p)
    step = pl.steps[0]
    pl = dataclasses.replace(
        pl, steps=(dataclasses.replace(
            step, logical=Filter(step.logical, col("kf") > 0)),))
    opt = filter_pushdown(pl)
    assert isinstance(opt.steps[0].logical, Filter)   # not pushed
    assert opt.steps[0].provenance == ()


def test_filter_pushdown_value_predicate_stays_above():
    from repro.optimizer import filter_pushdown
    pl = _filter_above_aggregate_plan(col("v_sum") > 0)
    opt = filter_pushdown(pl)
    assert isinstance(opt.steps[0].logical, Filter)   # refs ⊄ keys
    assert opt.steps[0].provenance == ()


def test_partial_agg_noop_on_single_device():
    """In-process (1 CPU device): the pass must leave every tree
    untouched — routing to a 1-device mesh buys nothing and would
    move cache keys for no reason."""
    from repro.optimizer import partial_agg
    pl = plan(_agg_pipeline(),
              table_stats={"src": TableStats(n_rows=10**6,
                                             key_kinds=("i",))})
    opt = partial_agg(pl)
    assert opt.steps[0].logical.describe() \
        == pl.steps[0].logical.describe()
    assert opt.steps[0].provenance == ()


_PARTIAL_AGG_BODY = """
    import dataclasses
    import numpy as np
    from repro.core import schema as S
    from repro.core.dag import Pipeline
    from repro.core.planner import plan
    from repro.data.tables import Table
    from repro.exec.stats import TableStats
    from repro.optimizer import optimize

    Src = S.Schema.of("Src", k=int, v=int)
    Agg = S.Schema.of("Agg", k=int, v_sum=int, v_min=int, v_max=int,
                      n=int, v_mean=float)
    p = Pipeline("gb")
    p.source("src", Src)
    p.sql(name="out", inputs={"s": "src"}, input_schemas={"s": Src},
          output_schema=Agg, group_keys=["k"],
          agg_specs=[("sum", "v"), ("min", "v"), ("max", "v"),
                     ("count", "v", "n"), ("mean", "v")])
    pl = plan(p, table_stats={"src": TableStats(n_rows=400_000,
                                                key_kinds=("i",))})
    opt = optimize(pl)
    tree = opt.steps[0].logical
    assert "strategy=partial" in tree.describe(), tree.describe()
    assert any("partial_agg" in m for m in opt.steps[0].provenance)
    # strategy moves the cache material
    assert opt.steps[0].cache_material() != pl.steps[0].cache_material()

    r = np.random.default_rng(0)
    n = 400_000
    t = Table({"k": r.integers(0, 4096, n).astype(np.int32),
               "v": r.integers(-1000, 1000, n).astype(np.int32)})
    a = pl.steps[0].execute({"src": t})
    b = opt.steps[0].execute({"src": t})
    assert a.fingerprint() == b.fingerprint()
    print("PARTIAL_AGG ok", jax.device_count())
"""


def test_partial_agg_optimized_vs_unoptimized_on_mesh():
    """8 forced host devices (subprocess, like test_sharded_join):
    the partial_agg rewrite fires and the optimized plan's output
    fingerprints exactly equal the unoptimized plan's (int values —
    no carve-out in play)."""
    pytest.importorskip("jax")
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import jax
        assert jax.device_count() == 8, jax.devices()
    """) + textwrap.dedent(_PARTIAL_AGG_BODY)
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "PARTIAL_AGG ok 8" in r.stdout


# ---------------------------------------------------------------------------
# sharded partial path (in-process, 1-device mesh still exercises the
# shard_map partial-aggregation protocol end to end)
# ---------------------------------------------------------------------------

@pytest.mark.skipif("jax" not in BACKENDS, reason="requires jax")
def test_sharded_partial_agg_matches_reference_inprocess():
    r = np.random.default_rng(9)
    n = 4000
    t = Table({"k": r.integers(-50, 50, n).astype(np.int32)})
    t._data["v"] = _masked(r.integers(-1000, 1000, n).astype(np.int32),
                           r.random(n) > 0.15)
    specs = tuple((fn, "v") for fn in AGG_FNS)
    want = t.group_by(["k"]).agg(*specs, backend="reference")
    got = t.group_by(["k"]).agg(*specs, backend="sharded")
    assert got.fingerprint() == want.fingerprint()
