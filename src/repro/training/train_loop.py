"""Training loop: contracts at every boundary, transactional publication.

The loop is itself a pipeline in the paper's sense:

    data batch --(TensorContract)--> train_step --(finite check)-->
    checkpoint tables --(TransactionalRun)--> branch commit

- the batch contract is validated before the step (worker moment);
- train_step is a pure jit'd function: loss (z-loss + CE) + AdamW;
- every ``ckpt_every`` steps the manager atomically publishes
  {params, opt_state, data_state, metrics} (paper §3.3);
- on restart, :func:`train` resumes from the branch head — bitwise
  identical stream thanks to the committed pipeline cursor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.schema import TensorContract
from repro.data.pipeline import DataPipeline
from repro.models import model as MDL
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                      adamw_update)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    remat: str | None = None
    z_loss: float = 1e-4
    aux_weight: float = 1e-2
    block_q: int = 512
    block_kv: int = 512
    seed: int = 0
    # microbatch gradient accumulation: the global batch is split into
    # `accum` microbatches scanned sequentially; live activation memory
    # shrinks ~accum× while grads accumulate in f32 sharded like params
    # (the standard big-model memory lever; see EXPERIMENTS.md §Perf A3).
    accum: int = 1


def batch_contract(cfg: ModelConfig, batch: int, seq: int
                   ) -> dict[str, TensorContract]:
    return {
        "inputs": TensorContract((batch, seq), "int32"),
        "targets": TensorContract((batch, seq), "int32"),
    }


@jax.custom_vjp
def _bf16_grad_barrier(x):
    """Identity whose COTANGENT is cast to bf16.

    The chunked-CE einsum runs with preferred_element_type=f32 (numerics),
    so the cotangent flowing back into the model is f32 — which would ride
    the whole residual stream in f32 and double every TP activation-grad
    all-reduce (measured 2× on command-r train_4k, EXPERIMENTS.md §Perf
    A5). Activations are bf16; their grads can be too.
    """
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    import jax.numpy as _jnp
    return (g.astype(_jnp.bfloat16),)


_bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def loss_fn(params, cfg: ModelConfig, inputs, targets, *,
            z_loss: float, aux_weight: float, remat=None,
            block_q=512, block_kv=512, extra=None,
            loss_chunk: int = 512):
    """Chunked cross-entropy: the (B, S, V) logits tensor is never
    materialized — the LM head + CE are computed per seq-chunk inside a
    rematerialized scan (e.g. command-r train_4k would need 4.2 GB/chip
    for full logits; chunked it is ~0.5 GB live)."""
    hidden, aux = MDL.forward(params, cfg, inputs, remat=remat,
                              block_q=block_q, block_kv=block_kv,
                              mode="hidden", **(extra or {}))
    hidden = _bf16_grad_barrier(hidden)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    B, S, D = hidden.shape
    chunk = min(loss_chunk, S)
    assert S % chunk == 0
    hc = hidden.reshape(B, S // chunk, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, S // chunk, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_ce(h, t):
        logits = jnp.einsum("bsd,dv->bsv", h, head,
                            preferred_element_type=jnp.float32)
        if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding cols
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask, -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - tgt), jnp.sum(jnp.square(logz))

    def body(acc, inp):
        h, t = inp
        ce_c, z_c = chunk_ce(h, t)
        return (acc[0] + ce_c, acc[1] + z_c), None

    (ce_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc))
    n = B * S
    ce = ce_sum / n
    zl = z_loss * z_sum / n
    total = ce + zl + aux_weight * aux
    return total, {"ce": ce, "z": zl, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    tc: TrainConfig, extra_spec: dict | None = None
                    ) -> Callable:
    """Builds the pure train_step; caller jits with in/out shardings."""

    def grad_fn(params, inputs, targets, extra):
        return jax.value_and_grad(
            lambda p: loss_fn(p, cfg, inputs, targets,
                              z_loss=tc.z_loss, aux_weight=tc.aux_weight,
                              remat=tc.remat, block_q=tc.block_q,
                              block_kv=tc.block_kv, extra=extra),
            has_aux=True)(params)

    def train_step(params, opt_state: AdamWState, inputs, targets,
                   *extra_args):
        extra = None
        if extra_spec:
            extra = dict(zip(extra_spec, extra_args))
        M = tc.accum
        if M <= 1:
            (loss, parts), grads = grad_fn(params, inputs, targets, extra)
        else:
            B = inputs.shape[0]
            assert B % M == 0, (B, M)

            def split(x):
                return x.reshape(M, B // M, *x.shape[1:])

            mb_in, mb_tg = split(inputs), split(targets)
            mb_extra = (jax.tree.map(split, extra) if extra else None)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                from repro.distributed.sharding import lshard
                g_acc, loss_acc, parts_acc = carry
                xin, tgt, ex = mb
                # keep microbatch slices batch-sharded (the reshape
                # confuses GSPMD into involuntary full remat otherwise)
                xin = lshard(xin, "batch", None)
                tgt = lshard(tgt, "batch", None)
                (loss, parts), g = grad_fn(params, xin, tgt, ex)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                parts_acc = jax.tree.map(jnp.add, parts_acc, parts)
                return (g_acc, loss_acc + loss, parts_acc), None

            zero_parts = {"ce": jnp.zeros((), jnp.float32),
                          "z": jnp.zeros((), jnp.float32),
                          "aux": jnp.zeros((), jnp.float32)}
            (grads, loss, parts), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32), zero_parts),
                (mb_in, mb_tg, mb_extra) if mb_extra is not None
                else (mb_in, mb_tg, None))
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
            parts = jax.tree.map(lambda x: x / M, parts)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def train(cfg: ModelConfig, *, pipeline: DataPipeline,
          opt_cfg: AdamWConfig, tc: TrainConfig,
          ckpt: CheckpointManager | None = None,
          params=None, opt_state=None,
          jit_fn: Callable | None = None,
          on_step: Callable[[int, dict], None] | None = None) -> dict:
    """Run the loop; resumes from ``ckpt``'s branch head when present."""
    key = jax.random.PRNGKey(tc.seed)
    if params is None:
        params = MDL.init_params(key, cfg)
    if opt_state is None:
        opt_state = adamw_init(params)

    start_step = 0
    if ckpt is not None:
        restored = ckpt.restore(params, opt_state)
        if restored is not None:
            params, opt_state, data_state, _ = restored
            start_step = int(data_state["step"])
            pipeline.state = type(pipeline.state).from_json(
                {k: data_state[k] for k in
                 ("shard_order_seed", "epoch", "step")})

    step_fn = jit_fn or jax.jit(make_train_step(cfg, opt_cfg, tc))
    contracts = batch_contract(cfg, pipeline.batch, pipeline.seq_len)

    history = []
    for step in range(start_step, tc.steps):
        inputs, targets = pipeline.next_batch()
        # worker-moment contract check on the physical batch
        contracts["inputs"].validate_concrete(inputs, "inputs")
        contracts["targets"].validate_concrete(targets, "targets")
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(
            params, opt_state, jnp.asarray(inputs), jnp.asarray(targets))
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = time.perf_counter() - t0
        history.append({"step": step, **metrics})
        if on_step:
            on_step(step, metrics)
        if ckpt is not None and (step + 1) % tc.ckpt_every == 0:
            ckpt.save(step=step + 1, params=params, opt_state=opt_state,
                      data_state=pipeline.state.to_json(),
                      metrics=metrics, code=f"{cfg.name}@{step + 1}")
    return {"params": params, "opt_state": opt_state, "history": history}
