"""AdamW + LR schedules + global-norm clipping, implemented in-repo.

Optimizer states are plain pytrees (shardable alongside params by the
same rules), updates are pure functions — pjit-friendly and trivially
checkpointable via the versioned store.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # ()
    mu: Any                  # pytree like params
    nu: Any                  # pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"       # "cosine" | "linear" | "constant"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = jnp.ones_like(t)
    decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=zeros(params), nu=zeros(params))


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> tuple[Any, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        p2, m2, v2 = upd(g, m, v, p)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = AdamWState(step=step,
                           mu=jax.tree.unflatten(treedef, new_m),
                           nu=jax.tree.unflatten(treedef, new_v))
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
