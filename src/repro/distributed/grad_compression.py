"""Gradient compression for the slow (inter-pod) all-reduce.

On a multi-pod mesh the ``pod`` axis crosses DCN/optical links an order
of magnitude slower than intra-pod ICI. We therefore do the intra-pod
gradient reduction at full precision (implicit, via pjit), and compress
only the cross-pod stage: int8 block-quantized all-reduce with **error
feedback** (the quantization residual is added to the next step's
gradient), which keeps SGD convergence guarantees (Karimireddy et al.,
error-feedback SGD).

Implemented with ``shard_map`` over the ``pod`` axis. The wire payload is
the int8 tensor + one fp32 scale per 256-block ⇒ ~4x fewer bytes than a
bf16 all-reduce with an fp32 accumulator, on the slowest links. (The
reference implementation below psums the *dequantized* payload so it
runs on any backend; a production TPU build would register an int8
all-reduce — the roofline collective-bytes accounting in
`repro.roofline` models the int8 wire format.)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map


def quantize_int8(x: jax.Array, block: int = 256
                  ) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-block int8 quantization of the flattened tensor."""
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, size: int,
                    shape) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return out.reshape(shape)


def _leaf_compressed_psum(g: jax.Array, e: jax.Array, npod: int,
                          block: int) -> tuple[jax.Array, jax.Array]:
    """One leaf inside shard_map: quantize(+error feedback), psum, deq."""
    gf = g.astype(jnp.float32) + e
    q, scale = quantize_int8(gf, block)
    local_deq = dequantize_int8(q.astype(jnp.int32), scale,
                                gf.size, gf.shape)
    new_e = gf - local_deq            # residual kept for next step
    qsum = jax.lax.psum(q.astype(jnp.float32) * scale, "pod")
    deq = qsum.reshape(-1)[:gf.size].reshape(gf.shape) / npod
    return deq.astype(g.dtype), new_e


def compressed_psum_pod(grads: Any, mesh: Mesh, *,
                        error: Any | None = None,
                        block: int = 256) -> tuple[Any, Any]:
    """All-reduce ``grads`` over the ``pod`` axis with int8 compression
    + error feedback. Returns (reduced_grads, new_error).

    ``grads`` leaves must be replicated over `pod` from the intra-pod
    reduction (the pure-DP boundary); other axes are untouched.
    """
    if "pod" not in mesh.axis_names:
        return grads, (error if error is not None else
                       jax.tree.map(lambda g: jnp.zeros(g.shape,
                                                        jnp.float32), grads))

    npod = mesh.shape["pod"]
    flat, treedef = jax.tree.flatten(grads)
    if error is None:
        err_flat = [jnp.zeros(g.shape, jnp.float32) for g in flat]
    else:
        err_flat = treedef.flatten_up_to(error)

    def mapped(*leaves):
        n = len(leaves) // 2
        outs = [_leaf_compressed_psum(g, e, npod, block)
                for g, e in zip(leaves[:n], leaves[n:])]
        return tuple(x for pair in outs for x in pair)

    specs = tuple(P() for _ in flat)
    out = shard_map(mapped, mesh=mesh, in_specs=specs * 2,
                    out_specs=specs * 2, check_vma=False)(
        *flat, *err_flat)
    red = jax.tree.unflatten(treedef, list(out[0::2]))
    new_err = jax.tree.unflatten(treedef, list(out[1::2]))
    return red, new_err
