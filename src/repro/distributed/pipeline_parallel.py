"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Optional at the assigned scales (TP×FSDP fits every arch on the v5e
mesh), but required posture for 1000+ nodes: stages are mapped onto the
``pipe`` axis with ``shard_map``; microbatches stream through stages via
``jax.lax.ppermute`` (neighbor ICI transfers only — no all-gathers), with
the standard (S−1+M)/M bubble.

The stage function is any ``x -> x`` block stack; weights for stage i
live only on pipe rank i (stacked leading `pipe` dim, sharded).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x: jax.Array, *, mesh: Mesh,
                     num_microbatches: int) -> jax.Array:
    """Run x (B, ...) through S pipeline stages with M microbatches.

    ``stage_params`` leaves have leading dim S sharded over ``pipe``.
    Returns the final-stage output for the full batch.
    """
    S = mesh.shape["pipe"]
    M = num_microbatches
    B = x.shape[0]
    assert B % M == 0

    def body(params, xin):
        # params: this stage's slice (leading dim 1); xin: (B, ...)
        rank = jax.lax.axis_index("pipe")
        p = jax.tree.map(lambda a: a[0], params)
        mb = xin.reshape(M, B // M, *xin.shape[1:])

        steps = M + S - 1
        buf = jnp.zeros_like(mb[0])
        out = jnp.zeros_like(mb)

        def step(carry, t):
            buf, out = carry
            # stage 0 injects microbatch t (if any); others use received
            inject = jax.lax.dynamic_index_in_dim(
                mb, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            cur = jnp.where(rank == 0, inject, buf)
            live = (t - rank >= 0) & (t - rank < M)
            y = stage_fn(p, cur)
            y = jnp.where(live, y, buf)
            # last stage collects its finished microbatch
            done_idx = jnp.clip(t - (S - 1), 0, M - 1)
            collect = (rank == S - 1) & (t - (S - 1) >= 0) & \
                (t - (S - 1) < M)
            out = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, done_idx, 0),
                lambda o: o, out)
            # shift activations to the next stage
            nxt = jax.lax.ppermute(
                y, "pipe", [(i, (i + 1) % S) for i in range(S)])
            return (nxt, out), None

        (buf, out), _ = jax.lax.scan(step, (buf, out),
                                     jnp.arange(steps))
        # broadcast final outputs from the last stage to all ranks
        out = jax.lax.psum(
            jnp.where(rank == S - 1, out, jnp.zeros_like(out)), "pipe")
        return out.reshape(B, *x.shape[1:])

    other = tuple(a for a in mesh.axis_names if a != "pipe")
    pspec = jax.tree.map(lambda _: P("pipe"), stage_params)
    return shard_map(
        body, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_vma=False,
    )(stage_params, x)
