"""Fault tolerance: crash-consistent restart + failure simulation.

The guarantees come from composition with the paper's machinery:

1. **Crash consistency** — checkpoints are transactional commits
   (CheckpointManager), so a worker dying mid-save can never publish a
   torn {params, opt_state, cursor} triple; the branch head always names
   a complete checkpoint.
2. **Restart** — `resilient_train` wraps the training loop, catches
   (simulated or real) worker failures, and restarts from the branch
   head. The committed pipeline cursor makes the re-run bitwise identical.
3. **Straggler mitigation** — data-plane shard leases
   (`repro.data.pipeline.ShardLeaseQueue`); slow readers lose leases,
   work is reassigned, and transactional publication deduplicates.
4. **Elastic downscale** — on repeated failure of the same pod, the
   caller can pass a smaller mesh; `repro.distributed.elastic.reshard`
   replaces any device placement.

`FailureInjector` deterministically kills the "worker" at chosen steps so
tests can assert all of the above without real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


class WorkerDied(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Kills the worker at each step listed in ``fail_at`` (once each)."""

    fail_at: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def on_step(self, step: int, metrics: dict) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise WorkerDied(f"injected node failure at step {step}")


def resilient_train(cfg: ModelConfig, *, pipeline_factory: Callable[[], DataPipeline],
                    opt_cfg: AdamWConfig, tc: TrainConfig,
                    ckpt: CheckpointManager,
                    injector: FailureInjector | None = None,
                    max_restarts: int = 10,
                    jit_fn: Callable | None = None) -> dict:
    """Training with automatic restart-from-last-commit on worker death."""
    restarts = 0
    while True:
        pipeline = pipeline_factory()
        try:
            return train(cfg, pipeline=pipeline, opt_cfg=opt_cfg, tc=tc,
                         ckpt=ckpt, jit_fn=jit_fn,
                         on_step=injector.on_step if injector else None)
        except WorkerDied:
            restarts += 1
            if restarts > max_restarts:
                raise
            # loop: train() restores from the branch head (atomic commit)
            continue
