"""Elastic rescaling: restore any checkpoint onto any mesh shape.

Because checkpoints are stored as *logical* (unsharded, host-side) pytree
snapshots in the versioned store, rescaling is purely a placement change:
``reshard`` device_puts every leaf with the sharding derived from the new
mesh + axis rules. Growing or shrinking the data axis changes only the
per-device batch; TP degree changes re-slice parameter matrices — all
handled by NamedSharding, no tensor surgery needed.

The global batch contract is preserved across rescales (the pipeline
cursor is part of the checkpoint), so a 512-chip run can continue on 256
chips after losing a pod — slow but *correct*, the paper's partial-vs-
total-failure upgrade applied to cluster capacity.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules


def param_spec(path: tuple, leaf, rules: AxisRules) -> P:
    """Heuristic logical spec for a parameter leaf by name/rank."""
    name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)
    nd = leaf.ndim
    if "embed" in name and nd == 2:          # (V, d)
        return rules.resolve("p_embed_vocab", "p_embed")
    if "lm_head" in name and nd == 2:        # (d, V)
        return rules.resolve("p_embed", "p_embed_vocab")
    if "experts" in name and nd >= 3:        # (E, d, f) / stacked (n,E,d,f)
        # expert dim over `model` (EP) when divisible; otherwise fall
        # back to TP *within* experts (granite's 40 experts on a 16-way
        # model axis): shard the f dim — column-parallel for up/gate
        # (…, d, f), row-parallel for w_down (…, f, d).
        ep_ok = True
        ent = rules.rules.get("p_experts")
        if rules.mesh is not None and ent is not None:
            for ax in (ent if isinstance(ent, tuple) else (ent,)):
                if ax in rules.mesh.shape:
                    ep_ok &= leaf.shape[nd - 3] % rules.mesh.shape[ax] == 0
        pad = [None] * (nd - 3)
        if ep_ok:
            return rules.resolve(*pad, "p_experts", "p_moe_inner", None)
        if "w_down" in name:
            return rules.resolve(*pad, None, "p_ff", "p_moe_inner")
        return rules.resolve(*pad, None, "p_moe_inner", "p_ff")
    if nd >= 2 and any(s in name for s in
                       ("wq", "wk", "wv", "w_gate", "w_up", "proj_gate",
                        "proj_rec", "w_in", "w_a", "w_x")):
        pad = [None] * (nd - 2)
        return rules.resolve(*pad, "p_embed", "p_ff")   # column-parallel
    if nd >= 2 and any(s in name for s in
                       ("wo", "w_down", "proj_out", "w_out")):
        pad = [None] * (nd - 2)
        return rules.resolve(*pad, "p_ff", "p_embed")   # row-parallel
    if "conv_w" in name and nd >= 2:         # (k, w): width over model
        pad = [None] * (nd - 2)
        return rules.resolve(*pad, None, "p_ff")
    if "lam" in name and nd >= 1:            # (w,)
        pad = [None] * (nd - 1)
        return rules.resolve(*pad, "p_ff")
    return P(*([None] * nd))


def params_sharding(params: Any, mesh: Mesh, rules: AxisRules
                    ) -> Any:
    import dataclasses
    rules = dataclasses.replace(rules, mesh=mesh)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf,
                                                          rules)),
        params)


def reshard(tree: Any, mesh: Mesh, rules: AxisRules) -> Any:
    """Place a host-side pytree onto ``mesh`` with per-leaf shardings."""
    sh = params_sharding(tree, mesh, rules)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh)
