"""Logical-axis sharding rules (MaxText-style) for all architectures.

Model code never names physical mesh axes. It tags tensors with *logical*
axis names (``"batch"``, ``"heads"``, ``"ff"`` …) via :func:`lshard`;
a :class:`AxisRules` mapping — per arch × shape, chosen by the launcher —
resolves logical names to physical mesh axes. This is what makes the same
model definition runnable on the single-pod (data, model) mesh, the
multi-pod (pod, data, model) mesh, or a laptop (no mesh: rules inactive).

Physical axes:
  pod    — slow inter-pod links: pure DP (+ compressed grad all-reduce)
  data   — intra-pod DP / FSDP axis; batch dim; decode: also KV-seq shards
  model  — TP axis: heads / ff / vocab / experts; decode: KV-seq shards

Non-divisible dims (e.g. 40 heads over a 16-way model axis) rely on
GSPMD's implicit padding — legal, costs padding waste that the roofline
report surfaces (see EXPERIMENTS.md §Roofline notes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AxisRules", "use_rules", "lshard", "logical_spec",
           "named_sharding", "TRAIN_RULES", "DECODE_RULES", "FSDP_RULES",
           "current_rules", "shard_map"]

AxisEntry = str | tuple[str, ...] | None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-portable ``shard_map``.

    ``jax.shard_map`` (with ``check_vma``) only exists on newer jax;
    older releases ship it as ``jax.experimental.shard_map.shard_map``
    with the flag spelled ``check_rep``. All in-repo callers go through
    this wrapper so the distributed stack runs on both.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name -> physical mesh axis (or axes, or None)."""

    rules: Mapping[str, AxisEntry]
    mesh: Mesh | None = None

    def resolve(self, *names: str | None) -> P:
        out = []
        used: set[str] = set()
        for n in names:
            if n is None:
                out.append(None)
                continue
            entry = self.rules.get(n)
            # drop axes the mesh doesn't have (single-pod vs multi-pod)
            if entry is not None and self.mesh is not None:
                have = set(self.mesh.axis_names)
                if isinstance(entry, tuple):
                    entry = tuple(a for a in entry if a in have) or None
                elif entry not in have:
                    entry = None
            # a mesh axis may appear at most once per spec: first logical
            # name wins (e.g. under sequence parallelism `heads` takes
            # `model`; `seq` then resolves to None inside attention)
            if entry is not None:
                if isinstance(entry, tuple):
                    entry = tuple(a for a in entry if a not in used) or None
                    if entry:
                        used.update(entry)
                elif entry in used:
                    entry = None
                else:
                    used.add(entry)
            out.append(entry)
        return P(*out)


_local = threading.local()


def current_rules() -> AxisRules | None:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: AxisRules | None):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def logical_spec(*names: str | None) -> P:
    r = current_rules()
    if r is None:
        return P(*([None] * len(names)))
    return r.resolve(*names)


def lshard(x: jax.Array, *names: str | None) -> jax.Array:
    """Apply a logical sharding constraint (no-op outside rules/mesh)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.resolve(*names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec))


def safe_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on dims the mesh cannot divide evenly.

    Explicit jit argument shardings require divisibility (unlike
    intermediate constraints, which GSPMD pads); replication of the
    offending dim is always correct — e.g. whisper's 1500 encoder
    frames on a 16-way axis.
    """
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for ax in axes:
            n *= mesh.shape.get(ax, 1)
        out.append(entry if shape[i] % n == 0 else None)
    return P(*out)


def named_sharding(mesh: Mesh, *names: str | None,
                   rules: AxisRules | None = None) -> NamedSharding:
    r = rules or current_rules() or AxisRules({}, mesh)
    r = dataclasses.replace(r, mesh=mesh)
    return NamedSharding(mesh, r.resolve(*names))


# ---------------------------------------------------------------------------
# Standard rule sets
# ---------------------------------------------------------------------------

# Megatron-style TP + DP for training / prefill. Activations keep d_model
# unsharded; heads/ff/vocab split over `model`; batch over (pod, data).
TRAIN_RULES: dict[str, AxisEntry] = {
    "batch": ("pod", "data"),
    "seq": None,              # sequence stays local in training
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "kv_seq": None,
    # parameter axes
    "p_embed_vocab": "model",
    "p_heads": "model",
    "p_kv_heads": "model",
    "p_ff": "model",
    "p_embed": None,          # FSDP_RULES overrides to ("data",)
    "p_experts": "model",
    "p_moe_inner": None,      # FSDP_RULES overrides to ("data",)
    "layers": None,
}

# FSDP: parameters additionally sharded over `data` on their d_model axis
# (all-gathered on use). Required to fit the ≥100B archs.
FSDP_RULES: dict[str, AxisEntry] = dict(
    TRAIN_RULES,
    p_embed=("data",),
    p_moe_inner=("data",),
)

# Megatron-style sequence parallelism: the residual stream between blocks
# is sharded over `model` along seq (the norm/elementwise regions), and
# GSPMD converts the TP all-reduces into all-gather + reduce-scatter
# pairs around attention/FFN. Mandatory at train_4k/prefill_32k on v5e:
# an unsharded per-layer residual (B_loc·S·d·2B, e.g. 1.6 GB for
# command-r) × L rematerialization carries would not fit HBM.
SP_SUFFIX: dict[str, AxisEntry] = {"seq": "model"}

# Decode: KV cache sequence-sharded over `model` (flash-decode partial
# softmax: works for ANY head count — no divisibility constraint), batch
# over (pod, data). Weights stay TP-sharded.
DECODE_RULES: dict[str, AxisEntry] = dict(
    TRAIN_RULES,
    batch=("pod", "data"),
    kv_seq="model",
    heads=None,            # activations: 1-token q, replicate heads
    kv_heads=None,
)


# Pure data parallelism: batch spans EVERY mesh axis; parameters are
# replicated. The right strategy for small models (xlstm-350m: d=1024)
# where 16-way TP makes every activation collective ~40× the compute
# (measured: EXPERIMENTS.md §Perf C1). Grad all-reduce is the only
# collective left.
DP_ONLY_RULES: dict[str, AxisEntry] = {
    **{k: None for k in TRAIN_RULES},
    "batch": ("pod", "data", "model"),
}


def make_rules(kind: str, mesh: Mesh | None, *, fsdp: bool = False,
               seq_parallel: bool = False,
               dp_only: bool = False) -> AxisRules:
    # NOTE: prefill returns the KV cache in the decode layout — its seq
    # axis shards over `model` (resolve() dedups against SP's use).
    if dp_only and kind in ("train", "prefill"):
        base = dict(DP_ONLY_RULES)
        if fsdp:
            # ZeRO-style: params/opt sharded over `data`, gathered on
            # use — lets 3–9B models run pure-DP (granite: experts stay
            # LOCAL per token, no dispatch collectives at all)
            base["p_embed"] = ("data",)
            base["p_moe_inner"] = ("data",)
        return AxisRules(base, mesh)
    if kind in ("train", "prefill"):
        base = dict(FSDP_RULES if fsdp else TRAIN_RULES)
        if seq_parallel:
            base.update(SP_SUFFIX)
        if kind == "prefill":
            base["kv_seq"] = "model"
    elif kind == "decode":
        base = dict(DECODE_RULES)
        if fsdp:
            base["p_embed"] = ("data",)
            base["p_moe_inner"] = ("data",)
    else:
        raise ValueError(kind)
    return AxisRules(base, mesh)
