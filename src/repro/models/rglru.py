"""RecurrentGemma's recurrent block: causal conv + RG-LRU (arXiv:2402.19427).

The RG-LRU is an element-wise gated linear recurrence

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(c * softplus(Λ) * r_t * log a_base)   — here parameterized as
    a_t = exp(-c * softplus(Λ) * r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

TPU adaptation (DESIGN.md §7): training uses a *blocked associative scan*
(`jax.lax.associative_scan` — log-depth, MXU-free but VPU-friendly)
instead of the GPU per-thread sequential recurrence; decode carries h as
O(d) state. The full recurrent block is:

    x ──ln──┬── proj_gate ── gelu ──────────────┐
            └── proj_rec ── conv1d ── RG-LRU ──⊙── proj_out ── (+residual)
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models.layers import Params, _dense_init, split_keys

_C = 8.0  # recurrence sharpness constant from the paper


def rglru_init(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    w = d  # lru width = d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    return {
        "proj_gate": _dense_init(ks[0], (d, w), dt),
        "proj_rec": _dense_init(ks[1], (d, w), dt),
        "proj_out": _dense_init(ks[2], (w, d), dt),
        "conv_w": _dense_init(ks[3], (cfg.conv_kernel, w), dt, scale=0.1),
        "w_a": _dense_init(ks[4], (w, w), jnp.float32, scale=0.01),
        "w_x": _dense_init(ks[5], (w, w), jnp.float32, scale=0.01),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 (paper's init range)
        "lam": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,w), w: (k,w). Returns (out, tail)."""
    k = w.shape[0]
    if state is None:
        x_pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        x_pad = jnp.concatenate([state, x], axis=1)
    out = sum(x_pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    tail = x_pad[:, -(k - 1):, :] if k > 1 else None
    return out.astype(x.dtype), tail


def _lru_scan(a: jax.Array, b: jax.Array,
              h0: jax.Array | None = None) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1 (S)."""
    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_gates(p: Params, xr: jax.Array):
    """Compute (a, b) for the recurrence, in float32."""
    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * xf)
    return a, b


def rglru_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  state: dict | None = None,
                  ) -> tuple[jax.Array, dict | None]:
    """x: (B,S,d). state: {"conv": (B,k-1,w), "h": (B,w)} for decode."""
    gate = jax.nn.gelu(x @ p["proj_gate"])
    xr = x @ p["proj_rec"]
    conv_state = state["conv"] if state is not None else None
    xr, conv_tail = _causal_conv(xr, p["conv_w"], conv_state)
    xr = lshard(xr, "batch", "seq", "ff")
    a, b = rglru_gates(p, xr)
    h0 = state["h"] if state is not None else None
    if x.shape[1] == 1 and state is not None:
        # decode: one sequential step, no scan
        h = (a[:, 0] * state["h"] + b[:, 0])[:, None, :]
    else:
        h = _lru_scan(a, b, h0)
    out = (gate.astype(jnp.float32) * h).astype(x.dtype) @ p["proj_out"]
    out = lshard(out, "batch", "seq", "embed")
    new_state = None
    if state is not None:
        new_state = {"conv": conv_tail, "h": h[:, -1, :]}
    return out, new_state


def rglru_state_init(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, w), jnp.bfloat16),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
