"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

From arXiv:2405.04517. TPU adaptation (DESIGN.md §7):

- **mLSTM** uses the *chunkwise-parallel* formulation: within a chunk the
  contribution is a (masked, gated) attention-like matmul on the MXU;
  across chunks the matrix memory C (B,H,hd,hd) and normalizer n (B,H,hd)
  are carried by a `lax.scan`. Decode is the O(1) recurrent update. This
  replaces the CUDA per-warp recurrence with MXU-shaped tiles.
- **sLSTM** has hidden-to-hidden recurrence (block-diagonal per head), so
  it is inherently sequential: a `lax.scan` over time with exponential
  gating and the (m, n) stabilizer state. Heads are block-diagonal, so
  the per-step matmul is (B, H, hd) x (H, hd, hd).

Both use exponential gating with the max-state stabilizer from the paper.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models.layers import Params, _dense_init, split_keys


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig) -> Params:
    d, hd, H = cfg.d_model, cfg.head_dim, cfg.num_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, H * hd), dt),
        "wk": _dense_init(ks[1], (d, H * hd), dt),
        "wv": _dense_init(ks[2], (d, H * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, d), dt),
        "w_if": _dense_init(ks[4], (d, 2 * H), jnp.float32, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((H,), jnp.float32),
                                 jnp.full((H,), 3.0, jnp.float32)]),
    }


def _mlstm_chunk(q, k, v, log_i, log_f, C0, n0, m0):
    """One chunk, parallel-within / recurrent-across.

    q,k,v: (B,H,L,hd); log_i/log_f: (B,H,L); state C0 (B,H,hd,hd),
    n0 (B,H,hd), m0 (B,H). Returns (out, C1, n1, m1).
    """
    B, H, L, hd = q.shape
    # cumulative log forget within the chunk: F_t = sum_{s<=t} log f_s
    F = jnp.cumsum(log_f, axis=-1)                       # (B,H,L)
    # decay from chunk start to t (inclusive of f_t):
    #   state contribution uses  exp(F_t)
    # intra-chunk (j -> t, j<=t): exp(F_t - F_j) * i_j
    m_intra = F[..., :, None] - F[..., None, :] + log_i[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    m_intra = jnp.where(causal, m_intra, -jnp.inf)       # (B,H,L,L)
    m_state = F + m0[..., None]                          # (B,H,L)
    # stabilizer: per-step max over both sources
    m_new = jnp.maximum(jnp.max(m_intra, axis=-1), m_state)  # (B,H,L)
    m_new = jnp.maximum(m_new, -1e30)
    d_intra = jnp.exp(m_intra - m_new[..., None])        # (B,H,L,L)
    d_state = jnp.exp(m_state - m_new)                   # (B,H,L)

    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhld,bhjd->bhlj", q, k,
                   preferred_element_type=jnp.float32) * scale
    intra = jnp.einsum("bhlj,bhjd->bhld", s * d_intra,
                       v.astype(jnp.float32))
    inter = jnp.einsum("bhld,bhde->bhle", q.astype(jnp.float32) * scale,
                       C0) * d_state[..., None]
    num = intra + inter
    # normalizer
    n_intra = jnp.einsum("bhlj,bhjd->bhld", s * d_intra,
                         jnp.ones_like(v, jnp.float32))
    qn = jnp.einsum("bhld,bhd->bhl", q.astype(jnp.float32) * scale, n0)
    denom = jnp.abs(jnp.sum(s * d_intra, axis=-1) + qn * d_state)
    denom = jnp.maximum(denom, jnp.exp(-m_new))          # lower bound
    out = num / denom[..., None]

    # ---- state update to end of chunk ----
    F_tot = F[..., -1]                                   # (B,H)
    m1 = jnp.maximum(F_tot + m0, jnp.max(F_tot[..., None] - F + log_i,
                                         axis=-1))
    w_state = jnp.exp(F_tot + m0 - m1)                   # (B,H)
    w_in = jnp.exp(F_tot[..., None] - F + log_i - m1[..., None])  # (B,H,L)
    C1 = C0 * w_state[..., None, None] + jnp.einsum(
        "bhld,bhle,bhl->bhde", k.astype(jnp.float32),
        v.astype(jnp.float32), w_in)
    n1 = n0 * w_state[..., None] + jnp.einsum(
        "bhld,bhl->bhd", k.astype(jnp.float32), w_in)
    return out, C1, n1, m1


def mlstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  state: dict | None = None, chunk: int = 256,
                  ) -> tuple[jax.Array, dict | None]:
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q = lshard(q, "batch", "heads", "seq", "head_dim")
    k = lshard(k, "batch", "heads", "seq", "head_dim")
    v = lshard(v, "batch", "heads", "seq", "head_dim")
    gates = jnp.einsum("bsd,dg->bsg", x.astype(jnp.float32), p["w_if"]) \
        + p["b_if"]
    log_i = gates[..., :H].transpose(0, 2, 1)            # (B,H,S) pre-act
    log_f = jax.nn.log_sigmoid(gates[..., H:]).transpose(0, 2, 1)

    if state is not None and S == 1:
        # O(1) decode step
        C0, n0, m0 = state["C"], state["n"], state["m"]
        out, C1, n1, m1 = _mlstm_chunk(q, k, v, log_i, log_f, C0, n0, m0)
        out = out[:, :, 0, :].reshape(B, 1, H * hd).astype(x.dtype)
        y = out @ p["wo"]
        return y, {"C": C1, "n": n1, "m": m1}

    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    def to_chunks(t):
        return t.reshape(B, H, nc, chunk, -1).transpose(2, 0, 1, 3, 4)
    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    gic = log_i.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)
    gfc = log_f.reshape(B, H, nc, chunk).transpose(2, 0, 1, 3)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    if state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def body(carry, inp):
        C, n, m = carry
        qi, ki, vi, gi, gf = inp
        out, C, n, m = _mlstm_chunk(qi, ki, vi, gi, gf, C, n, m)
        return (C, n, m), out

    (C1, n1, m1), outs = jax.lax.scan(body, (C0, n0, m0),
                                      (qc, kc, vc, gic, gfc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, hd)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd).astype(x.dtype)
    y = out @ p["wo"]
    y = lshard(y, "batch", "seq", "embed")
    new_state = ({"C": C1, "n": n1, "m": m1}
                 if state is not None else None)
    return y, new_state


def mlstm_state_init(cfg: ModelConfig, batch: int) -> dict:
    H, hd = cfg.num_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> Params:
    d, H = cfg.d_model, cfg.num_heads
    hd = d // H
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    return {
        # input projections for gates i, f, z, o: (d, 4d)
        "w_in": _dense_init(ks[0], (d, 4 * d), dt),
        # block-diagonal recurrent weights per head: (4, H, hd, hd)
        "r": _dense_init(ks[1], (4, H, hd, hd), jnp.float32, scale=0.05),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_out": _dense_init(ks[2], (d, d), dt),
    }


def slstm_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  state: dict | None = None,
                  ) -> tuple[jax.Array, dict | None]:
    B, S, d = x.shape
    H = cfg.num_heads
    hd = d // H
    zx = (x @ p["w_in"]).astype(jnp.float32) + p["b"]    # (B,S,4d)
    zx = zx.reshape(B, S, 4, H, hd)

    if state is None:
        st = slstm_state_init(cfg, B)
    else:
        st = state

    # Batch-broadcast the recurrent weights BEFORE the scan: R used
    # directly inside the step makes its scan-transposed cotangent a
    # batch-CONTRACTED tensor, which GSPMD all-reduces over the DP axes
    # at every timestep (measured 206 GB/chip/step — 4.2 MB × S × L,
    # EXPERIMENTS.md §Perf C2). With a per-batch copy the dR carry stays
    # batch-sharded through the scan and the broadcast's transpose sums
    # it ONCE at the end (a single small all-reduce).
    r_b = lshard(jnp.broadcast_to(p["r"][None], (B,) + p["r"].shape),
                 "batch", None, None, None, None)

    def step(carry, z_t):
        c, n, m, h = carry                                # (B,H,hd) each
        rec = jnp.einsum("bhd,bghde->bghe", h, r_b)       # (B,4,H,hd)
        z = z_t + rec
        i_t, f_t, z_in, o_t = (z[:, 0], z[:, 1], z[:, 2], z[:, 3])
        m_new = jnp.maximum(jax.nn.log_sigmoid(f_t) + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(jax.nn.log_sigmoid(f_t) + m - m_new)
        c_new = f_p * c + i_p * jnp.tanh(z_in)
        n_new = f_p * n + i_p
        h_new = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new, h_new), h_new

    zx_t = zx.transpose(1, 0, 2, 3, 4)                    # (S,B,4,H,hd)
    carry0 = (st["c"], st["n"], st["m"], st["h"])
    (c1, n1, m1, h1), hs = jax.lax.scan(step, carry0, zx_t)
    hs = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = hs @ p["w_out"]
    y = lshard(y, "batch", "seq", "embed")
    new_state = ({"c": c1, "n": n1, "m": m1, "h": h1}
                 if state is not None else None)
    return y, new_state


def slstm_state_init(cfg: ModelConfig, batch: int) -> dict:
    H = cfg.num_heads
    hd = cfg.d_model // H
    z = lambda: jnp.zeros((batch, H, hd), jnp.float32)
    return {"c": z(), "n": z(), "m": z(), "h": z()}
