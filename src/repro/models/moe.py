"""Mixture-of-Experts layer: GShard-style grouped top-k routing.

TPU-native design notes (DESIGN.md §7):

- Experts are sharded over the ``model`` mesh axis (EP); tokens stay
  sharded over ``data``. Dispatch/combine are dense einsums against a
  one-hot (group, expert, capacity) tensor — deterministic, jit-friendly
  (no ragged ops) and GSPMD-shardable.
- Tokens are processed in fixed-size *groups* (``cfg.moe.group_size``):
  the dispatch tensor is O(g · E · c) per group instead of O(T · E · C),
  and the group loop is a ``lax.scan`` so live memory is bounded.
- Capacity per group c = ceil(g · top_k / E · capacity_factor); tokens
  overflowing an expert's capacity are dropped (standard GShard
  semantics), gates renormalized over surviving experts.
- Router runs in float32 (numerics), includes the load-balancing
  auxiliary loss of Shazeer et al.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current_rules, lshard
from repro.models.layers import Params, _dense_init, mlp_forward, mlp_init, split_keys


def _expert_axis_tag(E: int) -> str | None:
    """EP activation tag only when the expert count divides the mesh's
    expert axis; otherwise the weights fall back to intra-expert TP
    (see elastic.param_spec) and the activations must stay E-local —
    mismatched layouts make GSPMD reshard the dispatch/combine gathers
    every group (measured +70% collective on granite, EXPERIMENTS.md)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return "experts"
    ent = r.rules.get("experts")
    size = 1
    for ax in (ent if isinstance(ent, tuple) else (ent,)):
        if ax in r.mesh.shape:
            size *= r.mesh.shape[ax]
    return "experts" if size and E % size == 0 else None


def moe_init(key, cfg: ModelConfig) -> Params:
    m = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    E = m.num_experts
    # stacked expert weights: (E, d, f) / (E, f, d)
    if cfg.act == "swiglu":
        expert = {
            "w_gate": _dense_init(ks[0], (E, d, f), dt, scale=1 / math.sqrt(d)),
            "w_up": _dense_init(ks[1], (E, d, f), dt, scale=1 / math.sqrt(d)),
            "w_down": _dense_init(ks[2], (E, f, d), dt, scale=1 / math.sqrt(f)),
        }
    else:
        expert = {
            "w_up": _dense_init(ks[0], (E, d, f), dt, scale=1 / math.sqrt(d)),
            "w_down": _dense_init(ks[1], (E, f, d), dt, scale=1 / math.sqrt(f)),
        }
    p: Params = {
        "router": _dense_init(jax.random.fold_in(key, 7), (d, E),
                              jnp.float32, scale=0.02),
        "experts": expert,
    }
    if m.shared_expert:
        p["shared"] = mlp_init(jax.random.fold_in(key, 11), cfg)
    return p


def _capacity(cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(math.ceil(m.group_size * m.experts_per_token / m.num_experts
                      * m.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _route_group(p: Params, xg: jax.Array, cfg: ModelConfig):
    """Route one token group per batch row.

    xg: (B, g, d) -> (out (B, g, d), aux_loss). B is sharded over `data`,
    experts over `model`; the dispatch einsum is the point where GSPMD
    inserts the token-to-expert reshard (all-to-all equivalent).
    """
    m = cfg.moe
    B, g, d = xg.shape
    E, k, c = m.num_experts, m.experts_per_token, _capacity(cfg)
    logits = jnp.einsum("bgd,de->bge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (B, g, k)
    # position of each (token, slot) within its expert's capacity:
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (B, g, k, E)
    flat = onehot.reshape(B, g * k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat)          # (B, g*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(B, g, k)
    keep = pos < c
    gate_vals = gate_vals * keep
    # renormalize surviving gates
    denom = jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    gate_vals = gate_vals / denom
    # ---- gather-based dispatch (TPU adaptation, EXPERIMENTS.md §Perf) --
    # The GShard dense dispatch einsum (bgke,bgkc->bgec then bgec,bgd->
    # becd) costs B·g·E·c·d MACs of pure bookkeeping — for granite
    # (E=40, c=128) that is ~10× the EXPERT compute and shows up as
    # useful_ratio≈0.1 in the roofline. Instead scatter the token index
    # of each surviving (expert, slot) pair and GATHER activations:
    # zero matmul FLOPs, same drop semantics, vjp = scatter-add.
    b_ix = jnp.arange(B, dtype=jnp.int32)[:, None, None]
    g_ix = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[None, :, None],
                            (B, g, k))
    slot = jnp.where(keep, pos, c)            # c = out-of-bounds → drop
    src = jnp.full((B, E, c), g, jnp.int32)   # g = "empty slot" sentinel
    src = src.at[b_ix, expert_idx, slot].set(g_ix, mode="drop")
    # gather tokens (append a zero row as the empty-slot source)
    xg_pad = jnp.concatenate(
        [xg.astype(jnp.bfloat16),
         jnp.zeros((B, 1, d), jnp.bfloat16)], axis=1)
    xin = jnp.take_along_axis(xg_pad[:, :, None, :],
                              src.reshape(B, E * c)[:, :, None, None],
                              axis=1).reshape(B, E, c, d)
    etag = _expert_axis_tag(E)
    xin = lshard(xin, "batch", etag, "expert_cap", "embed")
    # expert FFN (batched over B, E)
    ew = p["experts"]
    if "w_gate" in ew:
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, ew["w_gate"])) \
            * jnp.einsum("becd,edf->becf", xin, ew["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xin, ew["w_up"]))
    h = lshard(h, "batch", etag, "expert_cap", "ff")
    eout = jnp.einsum("becf,efd->becd", h, ew["w_down"])
    eout = lshard(eout, "batch", etag, "expert_cap", "embed")
    # combine: gather each token's k expert outputs and gate-sum them
    # (B·g·k·d FLOPs instead of B·g·E·c·d)
    flat_idx = (expert_idx * c + jnp.minimum(slot, c - 1)
                ).reshape(B, g * k)            # (B, g*k) into (E*c)
    eflat = eout.reshape(B, E * c, d).astype(jnp.float32)
    picked = jnp.take_along_axis(
        eflat, flat_idx[:, :, None], axis=1).reshape(B, g, k, d)
    picked = picked * keep[..., None]          # dropped slots contribute 0
    out = jnp.einsum("bgkd,bgk->bgd", picked, gate_vals)
    # load-balance aux loss (Shazeer): E * sum_e f_e * P_e
    f_e = jnp.mean(jnp.sum(onehot, axis=2).astype(jnp.float32), axis=(0, 1))
    P_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * P_e) / k
    return out.astype(xg.dtype), aux


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Groups = contiguous token chunks.

    The group loop scans over the *sequence* chunks (unsharded axis) and
    vmaps over batch (sharded over ``data``), so each scan step is a
    fully data-parallel (B, g, d) routing problem and live dispatch
    memory is O(B_local · g · E · c).
    """
    m = cfg.moe
    B, S, d = x.shape
    # group size: the largest divisor of S not exceeding the configured
    # size (a perf knob, not semantics — routing is per-token).
    g = min(m.group_size, S)
    while S % g != 0:
        g -= 1
    n = S // g
    xg = x.reshape(B, n, g, d).transpose(1, 0, 2, 3)   # (n, B, g, d)

    def body(_, xgi):
        out, aux = _route_group(p, xgi, cfg)
        return None, (out, aux)

    _, (out, aux) = jax.lax.scan(body, None, xg)       # out: (n, B, g, d)
    out = out.transpose(1, 0, 2, 3).reshape(B, S, d)
    if "shared" in p:
        out = out + mlp_forward(p["shared"], x, cfg)
    return lshard(out, "batch", "seq", "embed"), jnp.mean(aux)
