"""Transformer building blocks: norms, RoPE, GQA attention, MLPs.

Everything is a pure function over explicit parameter pytrees (no flax),
tagged with *logical* sharding constraints (:func:`repro.distributed.
sharding.lshard`) so one definition serves laptop smoke tests, the
single-pod mesh and the multi-pod mesh.

Attention is **blockwise** (flash-style online softmax, implemented with
`lax.scan` over a *static pair list* of (q-block, kv-block) tiles):

- memory is O(block²) instead of O(S²) — mandatory for the 32k shapes;
- causal / sliding-window patterns skip masked tiles *at trace time*, so
  compiled FLOPs are exact (no 2× masked-tile waste);
- the tile loop is the same structure the Pallas kernel uses, so the
  kernel's ref oracle and this path share test vectors.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(cfg: ModelConfig) -> Params:
    return {"scale": jnp.ones((cfg.d_model,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., :, None, :]   # (...,S,1,half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blockwise flash attention (pure XLA reference path)
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _attend_tile(q, k, v, mask, scale):
    """One flash tile. q: (B,H,bq,hd) k/v: (B,H,bkv,hd) mask: (bq,bkv)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, _NEG_INF)
    m = jnp.max(s, axis=-1)                        # (B,H,bq)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                        # (B,H,bq)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def _tile_pairs(n_q: int, n_kv: int, *, causal: bool,
                window_blocks: int | None, block_q: int, block_kv: int):
    """Static (q_block, kv_block) pair list — masked tiles skipped at trace
    time so compiled FLOPs are exact."""
    pairs = []
    for qi in range(n_q):
        for ki in range(n_kv):
            if causal and ki * block_kv > (qi + 1) * block_q - 1:
                continue  # tile entirely in the future
            if window_blocks is not None and \
                    ki * block_kv + block_kv - 1 < qi * block_q - \
                    window_blocks * block_kv:
                continue  # tile entirely outside the window
            pairs.append((qi, ki))
    return pairs


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        block_q: int = 512, block_kv: int = 512,
                        q_offset: int = 0) -> jax.Array:
    """Flash attention over (B,H,S,hd) with online softmax.

    ``window``: sliding-window size (local attention); None = global.
    ``q_offset``: absolute position of q[0] relative to k[0] (cross-chunk
    prefill). k/v may have fewer heads than q (GQA): they are broadcast.
    Differentiation goes through the flash custom-VJP (tile
    recomputation), NOT through naive scan transposition.
    """
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    if K != H:  # GQA: broadcast kv heads (vjp of repeat sums per group)
        rep = H // K
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    return flash_mha(q, k, v, causal, window, block_q, block_kv, q_offset)


def _blockwise_core(q, k, v, *, causal: bool, window: int | None,
                    block_q: int, block_kv: int, q_offset: int):
    """The tile loop. Returns (out (B,H,Sq,hd), lse (B,H,Sq))."""
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    n_q = q.shape[2] // block_q
    n_kv = k.shape[2] // block_kv
    scale = 1.0 / math.sqrt(hd)

    wb = None if window is None else max(1, -(-window // block_kv))
    pairs = _tile_pairs(n_q, n_kv, causal=causal, window_blocks=wb,
                        block_q=block_q, block_kv=block_kv)
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    qb = q.reshape(B, H, n_q, block_q, hd).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(B, H, n_kv, block_kv, hd).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, n_kv, block_kv, hd).transpose(2, 0, 1, 3, 4)

    q_pos_base = jnp.arange(block_q, dtype=jnp.int32) + q_offset
    k_pos_base = jnp.arange(block_kv, dtype=jnp.int32)

    o_acc = jnp.zeros((n_q, B, H, block_q, hd), jnp.float32)
    m_acc = jnp.full((n_q, B, H, block_q), _NEG_INF, jnp.float32)
    l_acc = jnp.zeros((n_q, B, H, block_q), jnp.float32)

    def body(carry, idx):
        o_acc, m_acc, l_acc = carry
        qi, ki = qi_arr[idx], ki_arr[idx]
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        kt = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        qpos = q_pos_base + qi * block_q
        kpos = k_pos_base + ki * block_kv
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        if pad_kv:
            mask &= (kpos < Skv)[None, :]
        o_t, m_t, l_t = _attend_tile(qt, kt, vt, mask, scale)
        m_old = jax.lax.dynamic_index_in_dim(m_acc, qi, 0, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l_acc, qi, 0, keepdims=False)
        o_old = jax.lax.dynamic_index_in_dim(o_acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(m_old, m_t)
        a_old = jnp.exp(m_old - m_new)
        a_t = jnp.exp(m_t - m_new)
        l_new = l_old * a_old + l_t * a_t
        o_new = o_old * a_old[..., None] + o_t * a_t[..., None]
        o_acc = jax.lax.dynamic_update_index_in_dim(o_acc, o_new, qi, 0)
        m_acc = jax.lax.dynamic_update_index_in_dim(m_acc, m_new, qi, 0)
        l_acc = jax.lax.dynamic_update_index_in_dim(l_acc, l_new, qi, 0)
        return (o_acc, m_acc, l_acc), None

    (o_acc, m_acc, l_acc), _ = jax.lax.scan(
        body, (o_acc, m_acc, l_acc), jnp.arange(len(pairs)))
    out = o_acc / jnp.maximum(l_acc[..., None], 1e-30)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, n_q * block_q, hd)
    lse = m_acc + jnp.log(jnp.maximum(l_acc, 1e-30))
    lse = lse.transpose(1, 2, 0, 3).reshape(B, H, n_q * block_q)
    return out[:, :, :Sq].astype(q.dtype), lse[:, :, :Sq]


# ---------------------------------------------------------------------------
# custom-VJP flash attention (training path)
#
# The naive differentiation of the tile scan saves every tile's (s, p)
# probability block for the backward pass: n_tiles × (B,H,bq,bkv) f32 —
# for command-r train_4k that is ~3.6 GB/layer/chip (measured: the
# 327 GiB/dev dry-run baseline, EXPERIMENTS.md §Perf iteration A1).
# The flash backward instead saves only (q,k,v,out,lse) and RECOMPUTES
# each tile's probabilities: +~30% attention FLOPs for ~36× less saved
# memory. Same tile pair list as the forward, so masked-tile skipping
# carries over to the backward.
# ---------------------------------------------------------------------------

def _blockwise_fwd_lse(q, k, v, *, causal, window, block_q, block_kv,
                       q_offset):
    """Forward identical to blockwise_attention but also returns the
    log-sum-exp per query position (needed by the flash backward)."""
    out, lse = _blockwise_core(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               q_offset=q_offset)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_mha(q, k, v, causal=True, window=None, block_q=512,
              block_kv=512, q_offset=0):
    out, _ = _blockwise_fwd_lse(q, k, v, causal=causal, window=window,
                                block_q=block_q, block_kv=block_kv,
                                q_offset=q_offset)
    return out


def _flash_mha_fwd(q, k, v, causal, window, block_q, block_kv, q_offset):
    out, lse = _blockwise_fwd_lse(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_kv=block_kv,
                                  q_offset=q_offset)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(causal, window, block_q, block_kv, q_offset,
                   res, dout):
    q, k, v, out, lse = res
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    scale = 1.0 / math.sqrt(hd)

    def padq(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q \
            else x

    def padkv(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad_kv), (0, 0))) \
            if pad_kv else x

    qp, op, dop = padq(q), padq(out.astype(jnp.float32)), \
        padq(dout.astype(jnp.float32))
    kp, vp = padkv(k), padkv(v)
    # pad lse with +BIG so recomputed p = exp(s - BIG) = 0 on pad rows
    lsep = (jnp.pad(lse, ((0, 0), (0, 0), (0, pad_q)),
                    constant_values=1e30) if pad_q else lse)
    n_q = qp.shape[2] // block_q
    n_kv = kp.shape[2] // block_kv

    # delta_i = rowsum(dout * out) — the softmax-jacobian correction
    delta = jnp.sum(dop * op, axis=-1)                    # (B,H,Sq')

    wb = None if window is None else max(1, -(-window // block_kv))
    pairs = _tile_pairs(n_q, n_kv, causal=causal, window_blocks=wb,
                        block_q=block_q, block_kv=block_kv)
    qi_arr = jnp.array([p[0] for p in pairs], jnp.int32)
    ki_arr = jnp.array([p[1] for p in pairs], jnp.int32)

    qb = qp.reshape(B, H, n_q, block_q, hd).transpose(2, 0, 1, 3, 4)
    kb = kp.reshape(B, H, n_kv, block_kv, hd).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, H, n_kv, block_kv, hd).transpose(2, 0, 1, 3, 4)
    dob = dop.reshape(B, H, n_q, block_q, hd).transpose(2, 0, 1, 3, 4)
    lseb = lsep.reshape(B, H, n_q, block_q).transpose(2, 0, 1, 3)
    deltab = delta.reshape(B, H, n_q, block_q).transpose(2, 0, 1, 3)

    q_pos_base = jnp.arange(block_q, dtype=jnp.int32) + q_offset
    k_pos_base = jnp.arange(block_kv, dtype=jnp.int32)

    dq_acc = jnp.zeros((n_q, B, H, block_q, hd), jnp.float32)
    dk_acc = jnp.zeros((n_kv, B, H, block_kv, hd), jnp.float32)
    dv_acc = jnp.zeros((n_kv, B, H, block_kv, hd), jnp.float32)

    def body(carry, idx):
        dq_acc, dk_acc, dv_acc = carry
        qi, ki = qi_arr[idx], ki_arr[idx]
        qt = jax.lax.dynamic_index_in_dim(qb, qi, 0, keepdims=False)
        kt = jax.lax.dynamic_index_in_dim(kb, ki, 0, keepdims=False)
        vt = jax.lax.dynamic_index_in_dim(vb, ki, 0, keepdims=False)
        dot_ = jax.lax.dynamic_index_in_dim(dob, qi, 0, keepdims=False)
        lse_t = jax.lax.dynamic_index_in_dim(lseb, qi, 0, keepdims=False)
        dlt_t = jax.lax.dynamic_index_in_dim(deltab, qi, 0, keepdims=False)
        qpos = q_pos_base + qi * block_q
        kpos = k_pos_base + ki * block_kv
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        if pad_kv:
            mask &= (kpos < Skv)[None, :]
        # recompute the tile's probabilities from (q,k,lse)
        s = jnp.einsum("bhqd,bhkd->bhqk", qt, kt,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse_t[..., None])                 # (B,H,bq,bkv)
        dv_t = jnp.einsum("bhqk,bhqd->bhkd", p, dot_)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dot_,
                        vt.astype(jnp.float32))
        ds = p * (dp - dlt_t[..., None]) * scale
        dq_t = jnp.einsum("bhqk,bhkd->bhqd", ds,
                          kt.astype(jnp.float32))
        dk_t = jnp.einsum("bhqk,bhqd->bhkd", ds,
                          qt.astype(jnp.float32))
        dq_acc = jax.lax.dynamic_update_index_in_dim(
            dq_acc, jax.lax.dynamic_index_in_dim(
                dq_acc, qi, 0, keepdims=False) + dq_t, qi, 0)
        dk_acc = jax.lax.dynamic_update_index_in_dim(
            dk_acc, jax.lax.dynamic_index_in_dim(
                dk_acc, ki, 0, keepdims=False) + dk_t, ki, 0)
        dv_acc = jax.lax.dynamic_update_index_in_dim(
            dv_acc, jax.lax.dynamic_index_in_dim(
                dv_acc, ki, 0, keepdims=False) + dv_t, ki, 0)
        return (dq_acc, dk_acc, dv_acc), None

    (dq_acc, dk_acc, dv_acc), _ = jax.lax.scan(
        body, (dq_acc, dk_acc, dv_acc), jnp.arange(len(pairs)))

    def unblk_q(x):
        x = x.transpose(1, 2, 0, 3, 4).reshape(B, H, n_q * block_q, hd)
        return x[:, :, :Sq]

    def unblk_kv(x):
        x = x.transpose(1, 2, 0, 3, 4).reshape(B, H, n_kv * block_kv, hd)
        return x[:, :, :Skv]

    return (unblk_q(dq_acc).astype(q.dtype),
            unblk_kv(dk_acc).astype(k.dtype),
            unblk_kv(dv_acc).astype(v.dtype))


flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def full_attention(q, k, v, *, causal: bool = True,
                   window: int | None = None, q_offset: int = 0):
    """Unblocked reference (small shapes / oracles only)."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    if K != H:
        k = jnp.repeat(k, H // K, axis=1)
        v = jnp.repeat(v, H // K, axis=1)
    Skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + blockwise core + decode path)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, *, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd), dt),
        "wk": _dense_init(ks[1], (d, K * hd), dt),
        "wv": _dense_init(ks[2], (d, K * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bo"] = jnp.zeros((d,), dt)
    return p


def _project_qkv(p: Params, x: jax.Array, xkv: jax.Array, cfg: ModelConfig,
                 positions, kv_positions, *, use_rope: bool):
    B, S, d = x.shape
    Skv = xkv.shape[1]
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, Skv, K, hd)
    v = v.reshape(B, Skv, K, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = lshard(q.transpose(0, 2, 1, 3), "batch", "heads", "seq", "head_dim")
    k = lshard(k.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", "head_dim")
    v = lshard(v.transpose(0, 2, 1, 3), "batch", "kv_heads", "seq", "head_dim")
    return q, k, v


def attention_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                      kind: str = "attn", positions=None,
                      encoder_out: jax.Array | None = None,
                      block_q: int = 512, block_kv: int = 512,
                      return_kv: bool = False):
    """Training / prefill attention. kind: attn | local | cross."""
    B, S, d = x.shape
    cross = kind == "cross"
    xkv = encoder_out if cross else x
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    kv_positions = (jnp.arange(xkv.shape[1], dtype=jnp.int32)[None, :]
                    if cross else positions)
    q, k, v = _project_qkv(p, x, xkv, cfg, positions, kv_positions,
                           use_rope=not cross)
    causal = not cross
    window = cfg.local_window if kind == "local" else None
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_kv=block_kv)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = out @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    out = lshard(out, "batch", "seq", "embed")
    if return_kv:
        # the returned prefill cache is seq-sharded ("kv_seq", the
        # flash-decode layout) — kv_heads rarely divide the TP axis, and
        # an unsharded 32k cache is 17 GB/chip on command-r (§Dry-run)
        k = lshard(k, "batch", None, "kv_seq", "head_dim")
        v = lshard(v, "batch", None, "kv_seq", "head_dim")
        return out, (k, v)
    return out


def attention_decode(p: Params, x: jax.Array, cache: dict, cfg: ModelConfig,
                     *, kind: str = "attn") -> tuple[jax.Array, dict]:
    """Single-token decode against a KV cache.

    cache = {"k": (B,K,Smax,hd), "v": ..., "len": (B,) or scalar}.
    The cache sequence axis may be sharded over `model` (flash-decode):
    the partial-softmax reductions below lower to tiny all-reduces.
    """
    B, S1, d = x.shape
    assert S1 == 1
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = cache["len"]  # scalar int32: current length (same for batch)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, positions,
                                   use_rope=kind != "cross")
    Smax = cache["k"].shape[2]
    # ring buffer: local-attention caches are window-sized; slot = pos mod
    # size. RoPE is applied at write time with the ABSOLUTE position, so
    # attention scores stay correct without per-slot position bookkeeping.
    ins = jax.lax.rem(pos, Smax)
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, 0, ins, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, 0, ins, 0))
    ck = lshard(ck, "batch", "kv_heads", "kv_seq", "head_dim")
    cv = lshard(cv, "batch", "kv_heads", "kv_seq", "head_dim")

    # quantized caches (fp8): storage stays narrow, math upcasts to bf16
    ck_m = ck if ck.dtype == jnp.bfloat16 else ck.astype(jnp.bfloat16)
    cv_m = cv if cv.dtype == jnp.bfloat16 else cv.astype(jnp.bfloat16)

    # GQA grouped score: (B, K, G, hd) x (B, K, Smax, hd)
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, ck_m,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    kpos = jnp.arange(Smax, dtype=jnp.int32)
    # ring semantics: once the buffer has wrapped every slot holds a
    # position within the last Smax tokens (all valid); before wrapping
    # only slots <= pos are populated.
    valid = (kpos <= pos) | (pos >= Smax)
    if kind == "local" and cfg.local_window < Smax:
        valid &= kpos > pos - cfg.local_window
    s = jnp.where(valid[None, None, None, :], s, _NEG_INF)
    # partial-softmax friendly reduction over (possibly sharded) Smax
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bksd->bkgd", (e / denom).astype(cv_m.dtype),
                   cv_m, preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    out = o @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out, {"k": ck, "v": cv, "len": pos + 1}


def attention_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                         dtype=jnp.bfloat16) -> dict:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, K, max_len, hd), dtype),
        "v": jnp.zeros((batch, K, max_len, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = split_keys(key, 3)
    if cfg.act == "swiglu":
        return {"w_gate": _dense_init(ks[0], (d, f), dt),
                "w_up": _dense_init(ks[1], (d, f), dt),
                "w_down": _dense_init(ks[2], (f, d), dt)}
    return {"w_up": _dense_init(ks[0], (d, f), dt),
            "w_down": _dense_init(ks[1], (f, d), dt)}


def mlp_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = lshard(h, "batch", "seq", "ff")
    out = h @ p["w_down"]
    return lshard(out, "batch", "seq", "embed")
