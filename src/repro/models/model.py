"""Unified model: one definition covering all 10 assigned architectures.

A model is (embedding) + N decoder blocks + (final norm, LM head), where
each block's *mixing* sublayer is chosen by ``cfg.block_pattern`` (cycled
over layers): full attention, sliding-window attention, RG-LRU, mLSTM or
sLSTM — followed by an (optionally MoE) FFN sublayer when ``d_ff > 0``.
Audio (whisper) adds a bidirectional encoder over stub frame embeddings +
per-block cross-attention; VLM does early fusion of stub patch embeddings.

Layers are executed as a ``lax.scan`` over *super-blocks* (one repeat of
the pattern, parameters stacked) so the HLO stays compact for the 40-cell
dry-run; `L % len(pattern)` remainder layers are unrolled.

Three entry points:
  - :func:`init_params`  (works under ``jax.eval_shape`` — no allocation)
  - :func:`forward`      (train / prefill; optional remat)
  - :func:`decode_step`  (one token against per-layer caches/states)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import lshard
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import xlstm as X

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str) -> Params:
    ks = L.split_keys(key, 4)
    p: Params = {"norm1": L.rmsnorm_init(cfg)}
    if kind in ("attn", "local"):
        p["mix"] = L.attention_init(ks[0], cfg)
    elif kind == "rglru":
        p["mix"] = R.rglru_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mix"] = X.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p["mix"] = X.slstm_init(ks[0], cfg)
    else:  # pragma: no cover
        raise ValueError(kind)
    if cfg.encoder_layers:  # whisper-style decoder: cross-attention
        p["norm_x"] = L.rmsnorm_init(cfg)
        p["cross"] = L.attention_init(ks[1], cfg, cross=True)
    if cfg.d_ff > 0:
        p["norm2"] = L.rmsnorm_init(cfg)
        if cfg.moe is not None:
            p["ffn"] = M.moe_init(ks[2], cfg)
        else:
            p["ffn"] = L.mlp_init(ks[2], cfg)
    return p


def _encoder_layer_init(key, cfg: ModelConfig) -> Params:
    ks = L.split_keys(key, 2)
    return {
        "norm1": L.rmsnorm_init(cfg),
        "attn": L.attention_init(ks[0], cfg),
        "norm2": L.rmsnorm_init(cfg),
        "ffn": L.mlp_init(ks[1], cfg),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    ks = L.split_keys(key, 6 + cfg.num_layers + cfg.encoder_layers)
    dt = jnp.dtype(cfg.param_dtype)
    P_len = cfg.pattern_len
    n_scan = cfg.n_scan_blocks

    params: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": L.rmsnorm_init(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[1], (cfg.d_model, cfg.padded_vocab),
                              jnp.float32) * 0.02).astype(dt)

    # scanned super-blocks: per pattern-slot, stack params over n_scan
    slots = []
    for j, kind in enumerate(cfg.block_pattern):
        per_block = [
            _block_init(ks[6 + b * P_len + j], cfg, kind)
            for b in range(n_scan)
        ]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_block)
                     if n_scan > 1 else
                     jax.tree.map(lambda x: x[None], per_block[0]))
    params["slots"] = slots

    # unrolled tail layers
    tail = []
    for t in range(cfg.n_tail_layers):
        kind = cfg.block_pattern[t % P_len]
        tail.append(_block_init(ks[6 + n_scan * P_len + t], cfg, kind))
    params["tail"] = tail

    if cfg.encoder_layers:
        enc = [_encoder_layer_init(ks[2 + i], cfg)
               for i in range(cfg.encoder_layers)]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
            "final_norm": L.rmsnorm_init(cfg),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _apply_block(p: Params, x, cfg: ModelConfig, kind: str, *,
                 enc_out=None, cache=None, decode: bool = False,
                 block_q: int = 512, block_kv: int = 512,
                 collect_kv: bool = False):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local"):
        if decode:
            mixed, cache_attn = L.attention_decode(
                p["mix"], h, cache["attn"], cfg, kind=kind)
            new_cache = dict(cache, attn=cache_attn)
        else:
            out = L.attention_forward(p["mix"], h, cfg, kind=kind,
                                      block_q=block_q, block_kv=block_kv,
                                      return_kv=collect_kv)
            if collect_kv:
                mixed, (k_new, v_new) = out
                new_cache = {"k": k_new, "v": v_new}
            else:
                mixed = out
                new_cache = cache
    elif kind == "rglru":
        mixed, st = R.rglru_forward(p["mix"], h, cfg,
                                    cache["rec"] if decode else None)
        new_cache = dict(cache, rec=st) if decode else cache
    elif kind == "mlstm":
        mixed, st = X.mlstm_forward(p["mix"], h, cfg,
                                    cache["rec"] if decode else None)
        new_cache = dict(cache, rec=st) if decode else cache
    elif kind == "slstm":
        mixed, st = X.slstm_forward(p["mix"], h, cfg,
                                    cache["rec"] if decode else None)
        new_cache = dict(cache, rec=st) if decode else cache
    else:  # pragma: no cover
        raise ValueError(kind)
    x = x + mixed

    if "cross" in p:
        h = L.rmsnorm(p["norm_x"], x, cfg.norm_eps)
        if decode:
            # cross K/V are static during decode: cached once at prefill
            mixed, _ = _cross_decode(p["cross"], h, cache["cross"], cfg)
        else:
            mixed = L.attention_forward(p["cross"], h, cfg, kind="cross",
                                        encoder_out=enc_out,
                                        block_q=block_q, block_kv=block_kv)
        x = x + mixed

    if "ffn" in p:
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, aux = M.moe_forward(p["ffn"], h, cfg)
        else:
            f = L.mlp_forward(p["ffn"], h, cfg)
        x = x + f
    return lshard(x, "batch", "seq", "embed"), new_cache, aux


def _cross_decode(p, x, kv, cfg: ModelConfig):
    """Decode-time cross attention against precomputed encoder K/V."""
    B, _, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
    out = L.full_attention(q, kv["k"], kv["v"], causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H * hd)
    return out @ p["wo"], kv


# ---------------------------------------------------------------------------
# encoder (whisper stub frontend -> bidirectional stack)
# ---------------------------------------------------------------------------

def encode(params: Params, cfg: ModelConfig, audio_embeds: jax.Array,
           block_q: int = 512, block_kv: int = 512) -> jax.Array:
    enc = params["encoder"]
    x = audio_embeds

    def body(x, p):
        h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
        h = L.attention_forward(p["attn"], h, cfg, kind="cross",
                                encoder_out=h,  # self, bidirectional
                                block_q=block_q, block_kv=block_kv)
        x = x + h
        h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        x = x + L.mlp_forward(p["ffn"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rmsnorm(enc["final_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            vision_embeds=None, audio_embeds=None,
            remat: str | None = None,
            block_q: int = 512, block_kv: int = 512,
            mode: str = "logits",        # logits | last_logits | hidden
            return_kv: bool = False):
    """tokens: (B, S) int32 -> (output, aux[, kv_caches]).

    ``mode="last_logits"`` returns only the final position's logits (the
    serving prefill shape); ``return_kv=True`` additionally returns the
    per-layer K/V tensors produced by attention blocks (the prefill
    cache output — recurrent blocks contribute None slots here; their
    decode state is built by the serving loop's teacher-forced steps).
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    if vision_embeds is not None:  # VLM early fusion
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    x = lshard(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.encoder_layers:
        assert audio_embeds is not None
        enc_out = encode(params, cfg, audio_embeds, block_q, block_kv)

    aux_total = jnp.zeros((), jnp.float32)

    def superblock(x, slot_params):
        aux_sb = jnp.zeros((), jnp.float32)
        kvs = []
        for j, kind in enumerate(cfg.block_pattern):
            x, kv, aux = _apply_block(slot_params[j], x, cfg, kind,
                                      enc_out=enc_out,
                                      block_q=block_q, block_kv=block_kv,
                                      collect_kv=return_kv)
            aux_sb = aux_sb + aux
            kvs.append(kv if (return_kv and kind in ("attn", "local"))
                       else jnp.zeros((), jnp.float32))
        return x, aux_sb, kvs

    if remat == "full":
        superblock = jax.checkpoint(superblock)
    elif remat == "dots":
        superblock = jax.checkpoint(
            superblock,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def scan_body(x, slot_params):
        # Pin the carry's sharding INSIDE the loop body: without this
        # GSPMD legalizes the carry to seq-unsharded (dropping the
        # sequence-parallel reduce-scatter), and remat then saves a
        # full-seq residual per layer — 192 GiB/dev on command-r
        # train_4k (EXPERIMENTS.md §Perf A2).
        x = lshard(x, "batch", "seq", "embed")
        x, aux, kvs = superblock(x, slot_params)
        return x, (aux, kvs)

    x, (auxs, kv_scan) = jax.lax.scan(scan_body, x, params["slots"])
    aux_total = aux_total + jnp.sum(auxs)

    kv_tail = []
    for t, p in enumerate(params["tail"]):
        kind = cfg.block_pattern[t % cfg.pattern_len]
        x, kv, aux = _apply_block(p, x, cfg, kind, enc_out=enc_out,
                                  block_q=block_q, block_kv=block_kv,
                                  collect_kv=return_kv)
        aux_total = aux_total + aux
        if return_kv and kind in ("attn", "local"):
            kv_tail.append(kv)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if mode == "hidden":
        out = x
    else:
        if mode == "last_logits":
            x = x[:, -1:, :]
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, head,
                            preferred_element_type=jnp.float32)
        # vocab FIRST: under SP the seq dim (often 1 for last_logits)
        # would consume the `model` axis and force an 11.7 GiB f32
        # all-gather of the LM head (measured, EXPERIMENTS.md §Perf B4)
        out = lshard(logits, "batch", None, "vocab")
    if return_kv:
        return out, aux_total, (kv_scan, kv_tail)
    return out, aux_total


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_out: jax.Array | None = None) -> list:
    """Per-slot stacked caches for scan + tail caches (appended flat)."""
    def one(kind):
        c: dict[str, Any] = {}
        if kind in ("attn", "local"):
            size = (min(max_len, cfg.local_window) if kind == "local"
                    else max_len)
            c["attn"] = L.attention_cache_init(
                cfg, batch, size, dtype=jnp.dtype(cfg.kv_dtype))
        elif kind == "rglru":
            c["rec"] = R.rglru_state_init(cfg, batch)
        elif kind == "mlstm":
            c["rec"] = X.mlstm_state_init(cfg, batch)
        elif kind == "slstm":
            c["rec"] = X.slstm_state_init(cfg, batch)
        if cfg.encoder_layers and enc_out is not None:
            K, hd = cfg.num_kv_heads, cfg.head_dim
            # precomputed cross K/V placeholder (filled at prefill)
            Senc = enc_out.shape[1]
            c["cross"] = {
                "k": jnp.zeros((batch, K, Senc, hd), jnp.bfloat16),
                "v": jnp.zeros((batch, K, Senc, hd), jnp.bfloat16),
            }
        return c

    slots = []
    for j, kind in enumerate(cfg.block_pattern):
        per = [one(kind) for _ in range(cfg.n_scan_blocks)]
        slots.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per)
                     if cfg.n_scan_blocks > 1
                     else jax.tree.map(lambda x: x[None], per[0]))
    tail = [one(cfg.block_pattern[t % cfg.pattern_len])
            for t in range(cfg.n_tail_layers)]
    return [slots, tail]


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                caches: list):
    """One decode step. tokens: (B, 1) -> (logits (B,1,V), new caches)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        jnp.dtype(cfg.dtype))
    x = lshard(x, "batch", "seq", "embed")
    slot_caches, tail_caches = caches

    def scan_body(x, inp):
        slot_params, slot_cache = inp
        new_cache = []
        for j, kind in enumerate(cfg.block_pattern):
            x, nc, _ = _apply_block(slot_params[j], x, cfg, kind,
                                    cache=slot_cache[j], decode=True)
            new_cache.append(nc)
        return x, new_cache

    x, new_slot_caches = jax.lax.scan(
        scan_body, x, (params["slots"], slot_caches))

    new_tail = []
    for t, p in enumerate(params["tail"]):
        kind = cfg.block_pattern[t % cfg.pattern_len]
        x, nc, _ = _apply_block(p, x, cfg, kind,
                                cache=tail_caches[t], decode=True)
        new_tail.append(nc)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    logits = lshard(logits, "batch", None, "vocab")
    return logits, [new_slot_caches, new_tail]
