"""Serving driver: batched requests against a *pinned commit*.

``python -m repro.launch.serve --arch xlstm_350m --requests 8``

Demonstrates the paper's snapshot-read guarantee at the serving
boundary: the replica loads params from an immutable tag, then a
concurrent "training run" publishes a new checkpoint to ``main`` — the
replica's params are unaffected (no torn reads), and promotion is an
explicit catalog operation.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs import ARCHS, get_smoke_config
from repro.core.catalog import Catalog
from repro.models import model as MDL
from repro.serving.serve_loop import Request, ServeLoop
from repro.training.optimizer import adamw_init


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="xlstm_350m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    if cfg.encoder_layers:
        print(f"[serve] {args.arch}: enc-dec serving needs per-request "
              "encoder features; use examples/transactional_training.py")
        return 0

    key = jax.random.PRNGKey(args.seed)
    params = MDL.init_params(key, cfg)

    # publish params to the catalog and PIN the serving replica to a tag
    catalog = Catalog()
    ckpt = CheckpointManager(catalog, branch="main")
    ckpt.save(step=0, params=params, opt_state=adamw_init(params),
              data_state={"step": 0, "epoch": 0, "shard_order_seed": 0},
              metrics={}, code=f"{cfg.name}@serve")
    tag = catalog.tag("serving/v0", "main")
    print(f"[serve] pinned replica to tag serving/v0 -> {tag[:12]}")

    loop = ServeLoop(cfg, params, batch_slots=args.slots,
                     max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 12)).astype(np.int32)
        loop.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    loop.run()
    done = sum(r is None for r in loop.active)
    print(f"[serve] completed {args.requests} requests "
          f"({args.slots} continuous-batching slots)")
    for rid in range(min(3, args.requests)):
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
