import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh — (16,16) single-pod or (2,16,16) multi-pod;
  2. builds the cell program (train/prefill/serve step) with abstract
     ``ShapeDtypeStruct`` inputs and explicit NamedShardings;
  3. ``jax.jit(...).lower(*args).compile()`` — a sharding mismatch, an
     unsupported collective or a compile-time OOM is a *bug in the
     framework* and fails the cell;
  4. prints ``compiled.memory_analysis()`` / ``cost_analysis()`` and runs
     the loop-aware HLO analyzer for the roofline terms;
  5. writes one JSON row per cell under ``results/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch xlstm_350m --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (DryrunKnobs, arch_dryrun_defaults,
                                build_cell, skip_reason)
from repro.roofline import hw
from repro.roofline.analysis import analyze_hlo, roofline_terms


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             knobs: DryrunKnobs | None = None,
             save_hlo: str | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    knobs = knobs or arch_dryrun_defaults(cfg)
    t0 = time.perf_counter()
    plan = build_cell(cfg, shape, mesh, knobs)

    with mesh:
        jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                         donate_argnums=plan.donate_argnums)
        lowered = jitted.lower(*plan.args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    mem_row = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_row[k] = int(v)
    # proves-it-fits: arguments + temp per device (donation dedups aliases)
    bytes_per_device = (mem_row.get("argument_size_in_bytes", 0)
                        + mem_row.get("temp_size_in_bytes", 0)
                        - mem_row.get("alias_size_in_bytes", 0))

    hlo = compiled.as_text()
    if save_hlo:
        pathlib.Path(save_hlo).write_text(hlo)
    hc = analyze_hlo(hlo)  # per-partition (per-chip) figures

    rl = roofline_terms(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops=hc.flops * chips, model_flops=plan.model_flops,
        hbm_bytes=hc.hbm_bytes * chips,
        collective_bytes=hc.collective_bytes * chips,
        bytes_per_device=bytes_per_device)

    dom_s = {"compute": rl.compute_s, "memory": rl.memory_s,
             "collective": rl.collective_s}[rl.bottleneck]
    step_s = max(rl.compute_s, rl.memory_s, rl.collective_s)
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips,
        "knobs": dataclasses.asdict(knobs),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_row,
        "bytes_per_device": bytes_per_device,
        "hbm_ok": bytes_per_device < hw.HBM_BYTES,
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")
                              if k in cost},
        "hlo_flops": rl.hlo_flops,
        "model_flops": rl.model_flops,
        "useful_ratio": round(rl.useful_ratio, 4),
        "hbm_bytes": rl.hbm_bytes,
        "collective_bytes": rl.collective_bytes,
        "collective_ops": hc.collective_ops,
        "compute_s": rl.compute_s, "memory_s": rl.memory_s,
        "collective_s": rl.collective_s,
        "bottleneck": rl.bottleneck,
        "roofline_fraction": (rl.compute_s / step_s) if step_s else 0.0,
        "while_trips": hc.while_trips,
    }
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--fsdp", action="store_true", default=None)
    ap.add_argument("--no-seq-parallel", dest="seq_parallel",
                    action="store_false", default=None)
    ap.add_argument("--remat", default=None,
                    choices=["full", "dots", "none"])
    ap.add_argument("--block-q", type=int, default=None)
    ap.add_argument("--block-kv", type=int, default=None)
    ap.add_argument("--accum", type=int, default=None)
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = ARCHS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else (
        [args.shape] if args.shape else list(SHAPES))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not archs[0]:
        ap.error("need --arch or --all")

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                knobs = arch_dryrun_defaults(get_config(arch))
                over = {}
                if args.fsdp is not None:
                    over["fsdp"] = args.fsdp
                if args.seq_parallel is not None:
                    over["seq_parallel"] = args.seq_parallel
                if args.remat is not None:
                    over["remat"] = (None if args.remat == "none"
                                     else args.remat)
                if args.block_q is not None:
                    over["block_q"] = args.block_q
                if args.block_kv is not None:
                    over["block_kv"] = args.block_kv
                if args.accum is not None:
                    over["accum"] = args.accum
                if over:
                    knobs = dataclasses.replace(knobs, **over)
                tag = f"{arch}.{shape}.{mesh_kind}"
                try:
                    row = run_cell(arch, shape, mesh_kind, knobs=knobs,
                                   save_hlo=args.save_hlo)
                except Exception as e:  # a failed cell is a framework bug
                    traceback.print_exc()
                    row = {"arch": arch, "shape": shape,
                           "mesh": mesh_kind, "status": "failed",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                (outdir / f"{tag}.json").write_text(json.dumps(row,
                                                               indent=1))
                if row["status"] == "ok":
                    print(f"[dryrun] {tag}: OK  "
                          f"compile={row['compile_s']:.1f}s  "
                          f"bytes/dev={row['bytes_per_device']/2**30:.2f}GiB"
                          f"  bottleneck={row['bottleneck']}  "
                          f"roofline={row['roofline_fraction']:.2f}")
                elif row["status"] == "skipped":
                    print(f"[dryrun] {tag}: SKIP ({row['reason']})")
                else:
                    print(f"[dryrun] {tag}: FAILED {row['error']}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
