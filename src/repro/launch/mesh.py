"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
must set XLA_FLAGS *before* the first jax device query, while smoke
tests and benchmarks must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) single-pod (256 chips) or (2, 16, 16) two-pod (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
