"""Per-cell (arch × shape × mesh) program builders for the dry-run.

Everything here works on ``jax.ShapeDtypeStruct`` stand-ins: no device
allocation ever happens on this path (the control-plane "moment 2" of
the paper — we must be able to reject a plan before any worker spends a
byte of HBM).

For each shape kind we build:
  train_4k      -> ``train_step``   (fwd + bwd + AdamW update)
  prefill_32k   -> ``prefill_step`` (fwd, last-position logits + KV out)
  decode_32k    -> ``serve_step``   (1 token against a seq_len KV cache)
  long_500k     -> ``serve_step``   (sub-quadratic archs only)

plus the matching input avals and NamedShardings (via the logical axis
rules in :mod:`repro.distributed.sharding`).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.elastic import params_sharding
from repro.distributed.sharding import (AxisRules, make_rules, safe_spec,
                                         use_rules)
from repro.models import model as MDL
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainConfig, make_train_step

__all__ = ["CellPlan", "build_cell", "cell_is_skipped", "skip_reason",
           "arch_dryrun_defaults"]


# ---------------------------------------------------------------------------
# skips (documented in DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------

def cell_is_skipped(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    return shape.name == "long_500k" and not cfg.sub_quadratic


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if cell_is_skipped(cfg, shape):
        return (f"{cfg.name}: pure full-attention stack — 512k-token decode "
                "needs sub-quadratic mixing (run for ssm/hybrid only)")
    return None


# ---------------------------------------------------------------------------
# per-arch dry-run defaults (chosen by napkin math; see EXPERIMENTS.md)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DryrunKnobs:
    fsdp: bool = False
    seq_parallel: bool = True
    remat: str | None = "full"
    block_q: int = 512
    block_kv: int = 512
    loss_chunk: int = 512
    accum: int = 1
    dp_only: bool = False      # pure DP (small archs): batch on all axes
    kv_dtype: str = "float8_e4m3fn"   # decode cache storage (§Perf E)


_BIG = {"recurrentgemma_9b", "llama4_scout_17b", "minitron_8b",
        "phi3_medium_14b", "command_r_plus_104b"}


# microbatch counts chosen by napkin math: live activations must fit
# 16 GiB HBM next to params+opt (see EXPERIMENTS.md §Perf A3).
_ACCUM = {"command_r_plus_104b": 16, "llama4_scout_17b": 8,
          "phi3_medium_14b": 4, "minitron_8b": 4, "granite_moe_3b": 4,
          "phi4_mini_3b": 4, "phi3_vision_4b": 4, "whisper_medium": 4,
          "recurrentgemma_9b": 4}


# small archs where 16-way TP is pure overhead: replicate params, DP the
# batch across all 256/512 chips (params+opt fit trivially).
_DP_ONLY = {"xlstm_350m", "whisper_medium"}


def arch_dryrun_defaults(cfg: ModelConfig) -> DryrunKnobs:
    from repro.configs import _ALIASES
    # config .name carries the published id ("granite-moe-3b-a800m");
    # resolve to the registry arch id the knob tables are keyed by.
    name = _ALIASES.get(cfg.name, cfg.name.replace("-", "_"))
    return DryrunKnobs(fsdp=name in _BIG, accum=_ACCUM.get(name, 1),
                       dp_only=name in _DP_ONLY)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: MDL.init_params(k, cfg), jax.random.PRNGKey(0))


def extra_inputs(cfg: ModelConfig, batch: int) -> dict[str, Any]:
    """Frontend STUB inputs (precomputed frame/patch embeddings)."""
    extra: dict[str, Any] = {}
    if cfg.encoder_layers:                       # audio (whisper)
        extra["audio_embeds"] = _sds(
            (batch, cfg.num_source_positions, cfg.d_model), cfg.dtype)
    elif cfg.family == "vlm":                    # early-fusion patches
        extra["vision_embeds"] = _sds(
            (batch, cfg.num_source_positions, cfg.d_model), cfg.dtype)
    return extra


# ---------------------------------------------------------------------------
# cache / state sharding heuristics
# ---------------------------------------------------------------------------

def _path_name(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _cache_spec(path, leaf, rules: AxisRules) -> P:
    name = _path_name(path)
    nd = leaf.ndim
    if name.endswith("/k") or name.endswith("/v"):
        # (B,K,S,hd) or stacked (n,B,K,S,hd): seq over `model` (flash-
        # decode partial softmax), batch over (pod,data)
        base = ["batch", "kv_heads", "kv_seq", "head_dim"]
        pad = [None] * (nd - 4)
        return rules.resolve(*pad, *base)
    if "rec" in name and nd >= 2:
        # recurrent state: batch-major, feature dims local
        pad = [None] * (nd - 2) if nd > 2 else []
        if nd == 1:
            return rules.resolve(None)
        # stacked layer dim first when present (heuristic: >2 dims)
        if nd >= 3:
            return rules.resolve(None, "batch", *([None] * (nd - 2)))
        return rules.resolve("batch", None)
    return rules.resolve(*([None] * nd))


def cache_sharding(caches, mesh: Mesh, rules: AxisRules):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(
            mesh, safe_spec(_cache_spec(p, l, rules), l.shape, mesh)),
        caches)


def safe_params_sharding(params, mesh: Mesh, rules: AxisRules):
    sh = params_sharding(params, mesh, rules)
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, safe_spec(s.spec, l.shape, mesh)),
        sh, params)


def _batched_spec(leaf, rules: AxisRules) -> P:
    """batch-leading activations: (B, ...)."""
    return rules.resolve("batch", *([None] * (leaf.ndim - 1)))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, knobs: DryrunKnobs,
                      extra_spec: tuple[str, ...]) -> Callable:
    def prefill_step(params, inputs, *extra_args):
        extra = dict(zip(extra_spec, extra_args))
        out, _aux, kv = MDL.forward(
            params, cfg, inputs, mode="last_logits", return_kv=True,
            remat=None, block_q=knobs.block_q, block_kv=knobs.block_kv,
            **extra)
        return out, kv
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, tokens, caches):
        return MDL.decode_step(params, cfg, tokens, caches)
    return serve_step


# ---------------------------------------------------------------------------
# the cell plan
# ---------------------------------------------------------------------------

def _with_rules(fn, rules):
    """Activate the logical-axis rules DURING TRACING: the model's
    internal ``lshard`` constraints resolve against the thread-local
    rules, so they must be live when jit traces the function (not just
    while specs are built) — otherwise every internal
    with_sharding_constraint silently becomes a no-op and GSPMD invents
    its own (usually seq-unsharded) layouts."""
    import functools as _ft

    @_ft.wraps(fn)
    def wrapped(*args, **kw):
        with use_rules(rules):
            return fn(*args, **kw)
    return wrapped


@dataclasses.dataclass
class CellPlan:
    """Everything jit needs: fn, abstract args, in/out shardings."""
    arch: str
    shape: str
    kind: str
    fn: Callable
    args: tuple           # ShapeDtypeStructs (pytrees)
    in_shardings: tuple
    donate_argnums: tuple[int, ...]
    rules: AxisRules
    model_flops: float    # 6·N·D train / 2·N_active·tokens prefill/decode


def _model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_act = cfg.num_active_params()
    if shape.kind == "train":
        return 6.0 * n_act * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.seq_len * shape.global_batch
    return 2.0 * n_act * shape.global_batch          # decode: 1 tok/seq


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               knobs: DryrunKnobs | None = None) -> CellPlan:
    knobs = knobs or arch_dryrun_defaults(cfg)
    kind = shape.kind
    B, S = shape.global_batch, shape.seq_len
    # pure DP only when the batch divides the whole mesh (train_4k);
    # otherwise fall back to the standard TP(+SP) rules.
    dp_only = knobs.dp_only and B % mesh.devices.size == 0
    rules = make_rules("train" if kind == "train" else
                       ("prefill" if kind == "prefill" else "decode"),
                       mesh, fsdp=knobs.fsdp,
                       seq_parallel=knobs.seq_parallel and kind != "decode",
                       dp_only=dp_only)

    # long_500k runs a single sequence: batch cannot shard over the DP
    # axes — replicate batch, parallelism comes from TP + kv_seq shards.
    dp = 1
    entry = rules.rules.get("batch")
    for ax in (entry if isinstance(entry, tuple) else (entry,)):
        if ax in mesh.shape:
            dp *= mesh.shape[ax]
    if B % dp != 0:
        rules = AxisRules(dict(rules.rules, batch=None), mesh)

    with use_rules(rules):
        params = abstract_params(cfg)
        p_shard = safe_params_sharding(params, mesh, rules)
        extra = extra_inputs(cfg, B)
        extra_names = tuple(extra)
        extra_avals = tuple(extra.values())
        extra_shard = tuple(
            NamedSharding(mesh, _batched_spec(a, rules))
            for a in extra_avals)
        tok_shard = NamedSharding(mesh, rules.resolve("batch", None))

        if kind == "train":
            tc = TrainConfig(remat=knobs.remat, block_q=knobs.block_q,
                             block_kv=knobs.block_kv, accum=knobs.accum)
            fn = make_train_step(cfg, AdamWConfig(), tc,
                                 extra_spec=dict.fromkeys(extra_names)
                                 if extra_names else None)
            opt = jax.eval_shape(adamw_init, params)
            o_shard = safe_params_sharding(opt, mesh, rules)
            args = (params, opt,
                    _sds((B, S), "int32"), _sds((B, S), "int32"),
                    *extra_avals)
            in_sh = (p_shard, o_shard, tok_shard, tok_shard, *extra_shard)
            return CellPlan(cfg.name, shape.name, kind,
                            _with_rules(fn, rules), args, in_sh,
                            donate_argnums=(0, 1), rules=rules,
                            model_flops=_model_flops(cfg, shape))

        if kind == "prefill":
            fn = make_prefill_step(cfg, knobs, extra_names)
            args = (params, _sds((B, S), "int32"), *extra_avals)
            in_sh = (p_shard, tok_shard, *extra_shard)
            return CellPlan(cfg.name, shape.name, kind,
                            _with_rules(fn, rules), args, in_sh,
                            donate_argnums=(), rules=rules,
                            model_flops=_model_flops(cfg, shape))

        # decode: 1 new token against a seq_len cache (fp8 storage)
        cfg = dataclasses.replace(cfg, kv_dtype=knobs.kv_dtype)
        fn_cfg = cfg
        enc_aval = None
        if cfg.encoder_layers:
            enc_aval = _sds((B, cfg.num_source_positions, cfg.d_model),
                            cfg.dtype)
        caches = jax.eval_shape(
            functools.partial(MDL.init_cache, cfg, B, S), enc_out=enc_aval)
        c_shard = cache_sharding(caches, mesh, rules)
        fn = make_serve_step(cfg)
        args = (params, _sds((B, 1), "int32"), caches)
        in_sh = (p_shard, tok_shard, c_shard)
        return CellPlan(cfg.name, shape.name, kind,
                        _with_rules(fn, rules), args, in_sh,
                        donate_argnums=(2,), rules=rules,
                        model_flops=_model_flops(cfg, shape))
