"""End-to-end training driver: ``python -m repro.launch.train --arch …``.

Runs the full stack on whatever devices exist: synthetic corpus → data
pipeline → contracts → jit'd train step → transactional checkpoints on a
versioned branch (the paper's run protocol applied to training). With
``--smoke`` (default on CPU) the arch's reduced config is used so a few
hundred steps finish in minutes.

Fault-tolerance drill: ``--kill-at N`` raises a simulated worker death at
step N; the driver restarts from the branch head and proves the resumed
stream is bitwise identical (the paper's reproducible-run claim).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.checkpoints.checkpointing import CheckpointManager
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core.catalog import Catalog
from repro.data.pipeline import DataPipeline, TokenDataset
from repro.data.synthetic import markov_corpus
from repro.distributed.fault_tolerance import FailureInjector, resilient_train
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, default="xlstm_350m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--kill-at", type=int, default=None,
                    help="simulate a worker death at this step")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    print(f"[train] {cfg.name} ({cfg.family}) "
          f"{cfg.num_params()/1e6:.1f}M params on "
          f"{len(jax.devices())} device(s)")

    tokens = markov_corpus(args.batch * args.seq_len * 64, cfg.vocab_size,
                           seed=args.seed)
    ds = TokenDataset(tokens, shard_tokens=args.batch * args.seq_len * 4)

    def pipeline_factory():
        return DataPipeline(ds, batch=args.batch, seq_len=args.seq_len,
                            seed=args.seed)

    catalog = Catalog()
    ckpt = CheckpointManager(catalog, branch="main",
                             registry=None)
    tc = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                     seed=args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 4),
                          total_steps=args.steps)

    if args.kill_at is not None:
        inj = FailureInjector(fail_at=(args.kill_at,))
        result = resilient_train(
            cfg, pipeline_factory=pipeline_factory, opt_cfg=opt_cfg,
            tc=tc, ckpt=ckpt, injector=inj)
        print(f"[train] survived {len(inj._fired)} injected failure(s); "
              f"restarts resumed from committed branch head")
    else:
        result = train(cfg, pipeline=pipeline_factory(), opt_cfg=opt_cfg,
                       tc=tc, ckpt=ckpt)

    hist = result["history"]
    first, last = hist[0], hist[-1]
    print(f"[train] step {first['step']}: loss={first['loss']:.4f}  ->  "
          f"step {last['step']}: loss={last['loss']:.4f}")
    assert np.isfinite(last["loss"]), "non-finite loss"
    assert last["loss"] < first["loss"], "loss did not decrease"
    log = catalog.log("main", limit=5)
    print(f"[train] branch main head={log[0].id[:12]} "
          f"({len(log)} recent commits, all transactional)")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
