"""whisper-medium [audio]: enc-dec, conv frontend STUBBED.

24L (encoder) + 24L (decoder), d_model=1024, 16H (kv=16), d_ff=4096,
vocab=51865 [arXiv:2212.04356]. The audio conv frontend is a stub:
``input_specs`` provides precomputed 1500-frame embeddings; the decoder
backbone handles the assigned LM shapes with cross-attention to them.
GELU MLP (whisper uses GELU, not SwiGLU); biases on attention.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    encoder_layers=24,
    num_source_positions=1500,
    act="gelu",
    use_bias=True,
    tie_embeddings=True,
)

SMOKE_CONFIG = reduced(CONFIG)
