"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern.

38L, d_model=4096, 16H (GQA kv=1 i.e. MQA for the local-attn layers),
d_ff=12288, vocab=256000 [arXiv:2402.19427]. Pattern
(rglru, rglru, local): 12 scanned super-blocks + 2 unrolled tail layers.
Sub-quadratic (local window 2048) => runs long_500k.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    tie_embeddings=True,
)

SMOKE_CONFIG = reduced(CONFIG, num_kv_heads=1)
