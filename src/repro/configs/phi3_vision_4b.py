"""phi-3-vision-4.2b [vlm]: phi3-mini backbone + CLIP stub.

32L, d_model=3072, 32H (kv=32), d_ff=8192, vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]. CLIP frontend is a stub:
``input_specs`` provides precomputed patch embeddings (576 patches),
early-fused over the first token positions.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    num_source_positions=576,
)

SMOKE_CONFIG = reduced(CONFIG)
