"""The paper's running example (Listings 1–5) as a reusable config.

``build_pipeline()`` returns the raw_table → parent → child → grand_child
DAG with the exact schemas of Listing 3; ``seed_lake(client)`` writes the
Listing-1 source table. Used by examples/quickstart.py and as the
canonical fixture for catalog/transaction demos.

NOTE: no ``from __future__ import annotations`` here — Schema class
bodies use live annotation objects (the paper's Listing-3 syntax).
"""
import datetime

import numpy as np

from repro.core import schema as S
from repro.core.contracts import CastDecl
from repro.core.dag import Pipeline
from repro.data.tables import Table, arrow_cast, col, lit, str_lit


class RawSchema(S.Schema):
    col1: str
    col2: datetime.datetime
    col3: int


class ParentSchema(S.Schema):          # "Node 1"
    col1: str
    col2: datetime.datetime
    _S: int


class ChildSchema(S.Schema):           # "Node 2"
    col2: datetime.datetime            # inherited type
    col4: float                        # fresh type
    col5: S.Nullable[str]              # fresh type (UNION(str, None))


class Grand(S.Schema):                 # "Node 3"
    col2: datetime.datetime            # inherited type
    col4: int                          # inherited type, narrowed


class FriendSchema(S.Schema):          # Appendix A, "Node 4"
    col2 = ChildSchema.col2
    col4 = Grand.col4
    col5 = ChildSchema.col5[S.NotNull]


def build_pipeline(*, with_friend: bool = False) -> Pipeline:
    p = Pipeline("paper_pipeline")
    p.source("raw_table", RawSchema)

    @p.node()   # parent_table: ParentSchema <- raw_table (Listing 4)
    def parent_table(df: RawSchema = "raw_table") -> ParentSchema:
        return df.group_by_sum(["col1", "col2"], "col3", out="_S")

    @p.node()   # "Node 1" -> "Node 2" (Listing 5)
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        return df.select([
            col("col2"),
            lit(0.25).alias("col4"),
            lit(None).alias("col5"),
        ])

    @p.node(casts=[CastDecl("col4", S.INT)])   # "Node 2" -> "Node 3"
    def grand_child(df: ChildSchema = child_table) -> Grand:
        return df.select([
            col("col2"),
            arrow_cast(col("col4"), str_lit("Int64")).alias("col4"),
        ])

    if with_friend:   # Appendix A binary node
        @p.node()
        def family_friend(df_child: ChildSchema = child_table,
                          df_grand: Grand = grand_child) -> FriendSchema:
            # Appendix A Listing 11: grand's col4 renamed before the join
            # so the joined table carries the INT version under "col4"
            dg = df_grand.select([col("col2"),
                                  col("col4").alias("4_grand")])
            j = df_child.filter(col("col5").is_not_null()) \
                .join(dg, on=["col2"])
            return j.select([col("col2"),
                             col("4_grand").alias("col4"),
                             col("col5")])

    return p


def seed_lake(client, rows: int = 5) -> None:
    """Write the Listing-1 ``raw_table`` source."""
    rng = np.random.default_rng(0)
    client.write_source_table("main", "raw_table", Table({
        "col1": np.array(list("ab" * rows)[:rows], dtype=object),
        "col2": np.array(["2026-07-01"] * rows, dtype="datetime64[ns]"),
        "col3": rng.integers(1, 10, rows).astype(np.int64),
    }))
