"""command-r-plus-104b [dense]: GQA, no biases. The largest assigned arch.

64L, d_model=12288, 96H (GQA kv=8), d_ff=33792, vocab=256000
[hf:CohereForAI/c4ai-command-r-plus]. Requires TP+FSDP to fit.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    num_layers=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
)

SMOKE_CONFIG = reduced(CONFIG)
