"""Unified model/run configuration system.

One :class:`ModelConfig` describes every assigned architecture; per-arch
modules (``repro/configs/<id>.py``) export ``CONFIG`` plus a reduced
``SMOKE_CONFIG`` for CPU tests. Shapes (``train_4k`` etc.) are
:class:`ShapeConfig` instances shared across LM-family archs.

Layer heterogeneity (hybrid/ssm archs) is expressed as a ``block_pattern``
cycled over layers; the model stacks parameters per *super-block* so the
forward pass can ``lax.scan`` over repeats of the pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Mapping

BlockKind = Literal["attn", "local", "rglru", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    shared_expert: bool = False
    capacity_factor: float = 1.25
    group_size: int = 512          # GShard-style routing group
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    block_pattern: tuple[BlockKind, ...] = ("attn",)
    local_window: int = 2048
    moe: MoEConfig | None = None
    # encoder-decoder (audio) / early-fusion (vlm) frontends are STUBS:
    # input_specs() provides precomputed frame/patch embeddings.
    encoder_layers: int = 0
    num_source_positions: int = 0   # encoder frames (audio) / patches (vlm)
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: Literal["swiglu", "gelu"] = "swiglu"
    use_bias: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # xlstm-specific
    conv_kernel: int = 4
    # decode KV-cache storage dtype. "float8_e4m3fn" halves the cache —
    # decode is HBM-bound on cache streaming, so this ~doubles decode
    # throughput headroom (scores/math stay bf16/f32; see EXPERIMENTS.md
    # §Perf E). "bfloat16" is the lossless default.
    kv_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"{self.name}: num_heads {self.num_heads} not a multiple of "
            f"kv heads {self.num_kv_heads}")

    # ---- derived ------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_scan_blocks(self) -> int:
        """Super-blocks executed under lax.scan."""
        return self.num_layers // self.pattern_len

    @property
    def n_tail_layers(self) -> int:
        """Remainder layers (unrolled) when L % pattern_len != 0."""
        return self.num_layers - self.n_scan_blocks * self.pattern_len

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding /
        LM-head vocab axis shards evenly over any mesh axis ≤256
        (whisper's 51865 and granite's 49155 are not 16-divisible).
        Pad logits are masked out of the loss and of serving argmax."""
        return -(-self.vocab_size // 256) * 256

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally (long-context capable)."""
        return all(k != "attn" for k in self.block_pattern)

    def num_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        H, K = self.num_heads, self.num_kv_heads
        per_layer = {}
        attn = d * (H * hd) + 2 * d * (K * hd) + (H * hd) * d
        if self.act == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        norms = 2 * d
        total = 0
        for i in range(self.num_layers):
            kind = self.block_pattern[i % self.pattern_len]
            if kind in ("attn", "local"):
                blk = attn + norms
            elif kind == "rglru":
                # lru: in/out proj (2*d*d) + gates (2*d*d) + conv
                blk = 4 * d * d + self.conv_kernel * d + norms
            elif kind == "mlstm":
                blk = d * (H * hd) * 3 + (H * hd) * d + 3 * H * hd + norms
            elif kind == "slstm":
                blk = 4 * d * d + 4 * d + norms
            else:  # pragma: no cover
                raise ValueError(kind)
            if self.moe is not None and f > 0:
                n_ffn = self.moe.num_experts + int(self.moe.shared_expert)
                blk += n_ffn * mlp + d * self.moe.num_experts  # + router
            elif f > 0:
                blk += mlp
            total += blk
        total += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + 2 * norms)
            # decoder cross-attention
            total += self.num_layers * (attn + norms)
        return total

    def num_active_params(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.num_params()
        full = self.num_params()
        f = self.d_ff
        mlp = (3 if self.act == "swiglu" else 2) * self.d_model * f
        inactive = (self.moe.num_experts - self.moe.experts_per_token)
        return full - self.num_layers * inactive * mlp


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# The four LM-family shapes assigned to every architecture.
SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = cfg.block_pattern
    small = dict(
        num_layers=max(len(pat), 2 if len(pat) == 1 else len(pat)),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, 4 // cfg.q_per_kv) if cfg.q_per_kv <= 4 else 1,
        d_ff=128 if cfg.d_ff > 0 else 0,
        vocab_size=256,
        head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_source_positions=8 if cfg.num_source_positions else 0,
        local_window=16,
        moe=(dataclasses.replace(cfg.moe, num_experts=4,
                                 experts_per_token=min(
                                     cfg.moe.experts_per_token, 2),
                                 group_size=16)
             if cfg.moe else None),
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
