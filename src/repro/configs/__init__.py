"""Architecture registry: ``get_config(arch_id)`` / ``--arch`` support."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced

ARCHS = [
    "whisper_medium",
    "phi3_vision_4b",
    "recurrentgemma_9b",
    "llama4_scout_17b",
    "granite_moe_3b",
    "minitron_8b",
    "phi3_medium_14b",
    "command_r_plus_104b",
    "phi4_mini_3b",
    "xlstm_350m",
]

_ALIASES = {
    "whisper-medium": "whisper_medium",
    "phi-3-vision-4.2b": "phi3_vision_4b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "minitron-8b": "minitron_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "command-r-plus-104b": "command_r_plus_104b",
    "phi4-mini-3.8b": "phi4_mini_3b",
    "xlstm-350m": "xlstm_350m",
}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG
