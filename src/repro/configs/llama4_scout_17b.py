"""llama4-scout-17b-a16e [moe]: 16 experts top-1 + shared expert.

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048,
early-fusion vision stub [hf:meta-llama/Llama-4-Scout-17B-16E].
"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    moe=MoEConfig(num_experts=16, experts_per_token=1,
                  shared_expert=True, group_size=512),
    num_source_positions=576,   # early-fusion vision stub
)

SMOKE_CONFIG = reduced(CONFIG)
