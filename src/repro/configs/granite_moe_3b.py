"""granite-moe-3b-a800m [moe]: 40 experts top-8, narrow experts.

32L, d_model=1536, 24H (GQA kv=8), expert d_ff=512, vocab=49155
[hf:ibm-granite/granite-3.0 family].
"""
from repro.configs.base import ModelConfig, MoEConfig, reduced

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=40, experts_per_token=8, group_size=512),
    tie_embeddings=True,
)

SMOKE_CONFIG = reduced(CONFIG)
