"""xlstm-350m [ssm]: alternating mLSTM / sLSTM blocks.

24L, d_model=1024, 4H, d_ff=0 (no separate FFN sublayer; the xLSTM blocks
carry the capacity), vocab=50304 [arXiv:2405.04517]. Pattern
(mlstm, slstm) x 12. Fully recurrent => O(1) decode state, runs long_500k.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
)

SMOKE_CONFIG = reduced(CONFIG, num_heads=2, num_kv_heads=2, head_dim=32,
                       d_model=64, d_ff=0)
