"""Batched serving against a *pinned commit* of the model catalog.

Serving reads params from an immutable commit/tag — never a moving
branch — so a training run publishing a new checkpoint can never tear a
serving replica (the paper's snapshot-read guarantee at the serving
boundary). Promotion is a catalog operation (tag / merge), not a file
copy.

The loop is continuous batching over request slots: each slot holds one
sequence + its per-layer cache entry; finished slots are refilled from
the queue. For simplicity slots share a step boundary (no paged KV);
per-slot cache state is batched into the stacked cache pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as MDL


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (P,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeLoop:
    def __init__(self, cfg: ModelConfig, params: Any, *, batch_slots: int,
                 max_len: int, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.queue: list[Request] = []
        self.active: list[Request | None] = [None] * batch_slots
        self.caches = MDL.init_cache(cfg, batch_slots, max_len)
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self._step = jax.jit(
            lambda p, t, c: MDL.decode_step(p, cfg, t, c))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.B):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # prefill by teacher-forcing the prompt through decode
                # steps (batched serving simplification)
                tok = jnp.asarray(req.prompt[:1])[None, :]
                self.tokens = self.tokens.at[i].set(tok[0])
                req._pos = 0  # type: ignore[attr-defined]

    def step(self) -> int:
        """One decode step for all active slots; returns #finished."""
        self._fill_slots()
        if not any(self.active):
            return 0
        logits, self.caches = self._step(self.params, self.tokens,
                                         self.caches)
        # restrict argmax to the real vocab (embedding may be padded)
        nxt = jnp.argmax(logits[:, -1, :self.cfg.vocab_size],
                         axis=-1).astype(jnp.int32)
        finished = 0
        new_tokens = np.asarray(self.tokens).copy()
        nxt_np = np.asarray(nxt)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            pos = req._pos + 1  # type: ignore[attr-defined]
            if pos < len(req.prompt):
                new_tokens[i, 0] = req.prompt[pos]   # still prefilling
            else:
                req.out.append(int(nxt_np[i]))
                new_tokens[i, 0] = int(nxt_np[i])
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.active[i] = None
                    finished += 1
            req._pos = pos  # type: ignore[attr-defined]
        self.tokens = jnp.asarray(new_tokens)
        return finished

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and not any(self.active):
                break
            self.step()


def load_params_at(client, ref: str, like: Any):
    """Materialize params from a pinned commit/tag (serving read path)."""
    from repro.core.store import get_pytree
    snap = client.catalog.read_table(ref, "params")
    return get_pytree(client.store, snap, like)
