"""Injectable clocks: chaos schedules must run deterministically fast.

:class:`TransactionalRun` takes ``clock=`` (anything with ``sleep``).
The default is the wall clock; under chaos a shared :class:`FakeClock`
absorbs every backoff sleep into virtual time, so a 256-agent swarm
with thousands of publication retries finishes in milliseconds while
the *schedule* of retries (which attempt slept how long, from the
seeded jitter) is fully preserved and replayable.
"""
from __future__ import annotations

import threading
import time

__all__ = ["FakeClock"]


class FakeClock:
    """Virtual time: ``sleep`` advances a counter instead of blocking.

    Each sleep still yields the GIL once (``time.sleep(0)``) so the
    call remains a real thread-scheduling point — backoff keeps its
    role as a schedule perturbation, it just stops costing wall time.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.now_s = 0.0            # total virtual time slept
        self.sleep_count = 0

    def sleep(self, seconds: float) -> None:
        with self._lock:
            self.now_s += seconds
            self.sleep_count += 1
        time.sleep(0)   # preserve the scheduling point, not the wait

    def time(self) -> float:
        with self._lock:
            return self.now_s
