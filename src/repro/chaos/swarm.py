"""Agent-swarm stress harness (DESIGN.md §15).

Drives many concurrent :class:`~repro.core.transactions.TransactionalRun`
agents against ONE catalog under an adversarial, seeded schedule:
contended hot-table publications (forcing mid-run rebases),
contract-violating writes, abandoned transactional branches, simulated
crashes at publication seams (via an active :class:`~repro.chaos.faults.
FaultPlan`), quarantine-reuse of aborted branches, and a janitor
running :meth:`Catalog.gc` concurrently with live publications.

Everything an agent *intends* is decided by ``random.Random`` streams
keyed on ``(seed, agent, run)`` — replaying a seed replays the same
mix of behaviors, tables, and fault decisions; thread interleaving
varies, but the invariants :func:`repro.chaos.check.check_swarm`
asserts are schedule-independent, so a red seed is a deterministic
reproduction of a real protocol bug, not of one lucky schedule.

Liveness protocol (GC soundness): an agent registers its run id in the
shared live set BEFORE ``begin()`` creates the TXN branch, and
``Catalog.gc`` snapshots the live view under the catalog lock — so the
janitor can run with ``grace_s=0`` and still never observe a live
run's branch without its owner. An agent that crashes or abandons
deregisters (its heartbeat stops), which is exactly what makes its
debris collectable.
"""
from __future__ import annotations

import dataclasses
import random
import threading
from typing import Any, Sequence

from repro.chaos.clock import FakeClock
from repro.chaos.faults import FaultPlan, FaultRule, FaultyStore, \
    fault_injection
from repro.core.catalog import Catalog, GCReport
from repro.core.errors import (BranchNotFound, CatalogError, MergeConflict,
                               RefConflict, TransactionAborted,
                               VisibilityError)
from repro.core.hooks import InjectedCrash, InjectedFault
from repro.core.store import MemoryStore, ObjectStore
from repro.core.transactions import RunRegistry, TransactionalRun

__all__ = ["SwarmConfig", "AgentRecord", "SwarmResult", "run_swarm"]


@dataclasses.dataclass(frozen=True)
class SwarmConfig:
    """One reproducible swarm experiment. Everything derives from
    ``seed``; behavior probabilities are cumulative draws per run."""

    n_agents: int = 8
    runs_per_agent: int = 3
    seed: int | str = 0
    hot_tables: int = 2          # shared table pool driving contention
    p_contended: float = 0.35    # write a hot table (rebase pressure)
    p_multi: float = 0.2         # multi-table atomic run (2-3 tables)
    p_violate: float = 0.1       # contract-violating write -> abort
    p_abandon: float = 0.08      # walk away mid-run (orphan TXN branch)
    p_reuse: float = 0.12        # quarantine-reuse an aborted branch
    gc_every: int = 0            # janitor gc per N completions (0 = off)
    gc_grace_s: float = 0.0      # grace for the mid-run janitor
    use_store: bool = False      # route payloads through (Faulty)Store
    fault_rules: tuple[FaultRule, ...] = ()
    fault_budget: int | None = None
    max_publish_attempts: int = 12
    publish_backoff_s: float = 0.001
    target: str = "main"


@dataclasses.dataclass
class AgentRecord:
    """What one agent attempted and how it ended."""

    agent: int
    idx: int
    run_id: str
    intent: str                       # behavior drawn for this run
    outcome: str = "pending"          # committed|aborted|abandoned|crashed
                                      # |released|skipped|branch_lost
    tables: dict[str, str] = dataclasses.field(default_factory=dict)
    branch: str | None = None
    final_commit: str | None = None
    verified_head: str | None = None
    released_head: str | None = None  # quarantine release: verified commit
    illegal_merge: bool = False       # unverified quarantine merge WORKED
    error: str = ""


@dataclasses.dataclass
class SwarmResult:
    config: SwarmConfig
    catalog: Catalog
    store: ObjectStore
    registry: RunRegistry
    plan: FaultPlan
    clock: FakeClock
    records: list[AgentRecord]
    gc_reports: list[GCReport]
    final_gc: GCReport | None = None

    @property
    def released_heads(self) -> tuple[str, ...]:
        """Commit ids re-verified by quarantine release — snapshots from
        aborted runs that these merges *legitimately* republished."""
        return tuple(r.released_head for r in self.records
                     if r.released_head is not None)

    def outcomes(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            out[r.outcome] = out.get(r.outcome, 0) + 1
        return out


class _LiveSet:
    """Thread-safe run-liveness view; iterating snapshots atomically
    (``Catalog.gc`` does ``frozenset(live)`` under the catalog lock)."""

    def __init__(self):
        self._s: set[str] = set()
        self._lock = threading.Lock()

    def add(self, rid: str) -> None:
        with self._lock:
            self._s.add(rid)

    def discard(self, rid: str) -> None:
        with self._lock:
            self._s.discard(rid)

    def __iter__(self):
        with self._lock:
            return iter(list(self._s))


def _choose_intent(rng: random.Random, cfg: SwarmConfig,
                   pool_nonempty: bool) -> str:
    x = rng.random()
    for p, intent in ((cfg.p_violate, "violate"),
                      (cfg.p_abandon, "abandon"),
                      (cfg.p_reuse, "reuse"),
                      (cfg.p_contended, "contended"),
                      (cfg.p_multi, "multi")):
        if x < p:
            if intent == "reuse" and not pool_nonempty:
                return "disjoint"  # nothing aborted yet to reuse
            return intent
        x -= p
    return "disjoint"


def _table_set(intent: str, rng: random.Random, cfg: SwarmConfig,
               agent: int) -> list[str]:
    if intent == "contended":
        return [f"hot{rng.randrange(cfg.hot_tables)}"]
    if intent == "multi":
        names = [f"a{agent}_t{j}" for j in range(2 + rng.randrange(2))]
        if rng.random() < 0.5:   # multi-table runs may span a hot table
            names[0] = f"hot{rng.randrange(cfg.hot_tables)}"
        return names
    return [f"a{agent}"]         # disjoint / violate / abandon


def run_swarm(config: SwarmConfig, *,
              store: ObjectStore | None = None) -> SwarmResult:
    """Run the swarm to completion; returns everything the
    linearizability checker needs. The final-sweep GC (all agents
    joined, empty live set, zero grace) is always performed so the
    result's catalog reflects post-recovery steady state."""
    cfg = config
    inner = store if store is not None else MemoryStore()
    faulty = FaultyStore(inner)
    plan = FaultPlan(cfg.seed, cfg.fault_rules, budget=cfg.fault_budget)
    clock = FakeClock()
    catalog = Catalog(faulty)
    registry = RunRegistry()
    live = _LiveSet()
    records: list[AgentRecord] = []
    gc_reports: list[GCReport] = []
    aborted_pool: list[str] = []   # branch names available for reuse
    state_lock = threading.Lock()
    completions = [0]

    def one_run(agent: int, k: int) -> None:
        rng = random.Random(f"{cfg.seed}:agent{agent}:run{k}")
        with state_lock:
            pool_nonempty = bool(aborted_pool)
        intent = _choose_intent(rng, cfg, pool_nonempty)
        rid = f"sw{cfg.seed}-a{agent}r{k}"
        rec = AgentRecord(agent=agent, idx=k, run_id=rid, intent=intent)
        try:
            if intent == "reuse":
                _do_reuse(rec, rng, agent)
            else:
                _do_run(rec, rng, agent, k, intent)
        except InjectedCrash as e:
            rec.outcome = "crashed"
            rec.error = str(e)
        except TransactionAborted as e:
            rec.outcome = "aborted"
            rec.error = str(e)
            if rec.branch is not None:
                with state_lock:
                    aborted_pool.append(rec.branch)
        except BranchNotFound as e:
            # a normal run losing its branch mid-flight would mean GC
            # collected live state — the checker flags branch_lost;
            # reuse losing its *source* to GC is a benign race.
            rec.outcome = "skipped" if intent == "reuse" else "branch_lost"
            rec.error = str(e)
        except (VisibilityError, MergeConflict, RefConflict,
                CatalogError) as e:
            rec.outcome = "skipped"
            rec.error = str(e)
        finally:
            with state_lock:
                records.append(rec)
                completions[0] += 1
                n = completions[0]
            if cfg.gc_every and n % cfg.gc_every == 0:
                report = catalog.gc(live_runs=live,
                                    grace_s=cfg.gc_grace_s)
                with state_lock:
                    gc_reports.append(report)

    def _do_run(rec: AgentRecord, rng: random.Random, agent: int,
                k: int, intent: str) -> None:
        txn = TransactionalRun(
            catalog, cfg.target, run_id=rec.run_id, registry=registry,
            code=rec.run_id,
            max_publish_attempts=cfg.max_publish_attempts,
            publish_backoff_s=cfg.publish_backoff_s, clock=clock,
            backoff_seed=f"{cfg.seed}:{rec.run_id}")
        live.add(rec.run_id)    # heartbeat BEFORE the branch exists
        try:
            txn.begin()
            rec.branch = txn.branch
            tables: dict[str, str] = {}
            for i, t in enumerate(_table_set(intent, rng, cfg, agent)):
                payload = f"{t}@{rec.run_id}#{i}"   # unique per run
                try:
                    snap = (faulty.put(payload.encode())
                            if cfg.use_store else payload)
                except InjectedFault as e:
                    txn.abort(e)    # a failed physical write aborts cleanly
                    raise TransactionAborted(
                        f"store write failed: {e}", branch=txn.branch,
                        cause=e) from e
                tables[t] = snap
            rec.tables = dict(tables)
            txn.write_tables(tables, message=f"swarm {rec.run_id}")
            if intent == "violate":
                def bad(read):
                    raise ValueError("contract violation (injected)")
                txn.verify(bad)     # -> TransactionAborted
            expect = dict(tables)

            def check(read):
                for t, s in expect.items():
                    if read(t) != s:
                        raise ValueError(f"snapshot of {t!r} drifted")
            txn.verify(check)
            if intent == "abandon":
                rec.outcome = "abandoned"   # walk away: no commit/abort
                return
            merged = txn.commit()
            rec.outcome = "committed"
            rec.final_commit = merged.id
            rec.verified_head = registry.get_run(rec.run_id).verified_head
        finally:
            live.discard(rec.run_id)        # heartbeat stops, dead or done

    def _do_reuse(rec: AgentRecord, rng: random.Random,
                  agent: int) -> None:
        with state_lock:
            if not aborted_pool:
                rec.outcome = "skipped"
                rec.error = "no aborted branch to reuse"
                return
            src = aborted_pool[rng.randrange(len(aborted_pool))]
        qb = f"q/{rec.run_id}"
        catalog.create_branch(qb, src, allow_reuse=True)  # -> QUARANTINED
        rec.branch = qb
        t = f"requal_a{agent}"
        snap = f"{t}@{rec.run_id}#q"
        catalog.write_table(qb, t, snap)
        rec.tables = {t: snap}
        try:
            catalog.merge(qb, into=cfg.target,
                          message=f"illegal unverified merge {rec.run_id}")
            rec.illegal_merge = True    # Fig. 4 guardrail FAILED
            rec.outcome = "released"
            return
        except VisibilityError:
            pass                        # guardrail held, as it must

        def reverify(read):
            if read(t) != snap:
                raise ValueError("requalified snapshot drifted")
        head = catalog.release_quarantined(qb, reverify)
        rec.released_head = head.id
        merged = catalog.merge(qb, into=cfg.target,
                               message=f"release {rec.run_id}")
        rec.outcome = "released"
        rec.final_commit = merged.id

    def agent_main(agent: int) -> None:
        for k in range(cfg.runs_per_agent):
            one_run(agent, k)

    with fault_injection(plan):
        threads = [threading.Thread(target=agent_main, args=(a,),
                                    name=f"swarm-agent-{a}")
                   for a in range(cfg.n_agents)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        # recovery sweep: every agent is gone, so all remaining TXN and
        # ABORTED debris (crashes, abandons, un-reused aborts) goes.
        final_gc = catalog.gc(live_runs=(), grace_s=0.0)

    return SwarmResult(config=cfg, catalog=catalog, store=faulty,
                       registry=registry, plan=plan, clock=clock,
                       records=records, gc_reports=gc_reports,
                       final_gc=final_gc)
