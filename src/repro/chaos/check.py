"""Linearizability / atomicity checker for swarm histories.

Generalizes the invariants of ``tests/test_concurrent_runs.py`` to the
full adversarial vocabulary of the swarm: crashes, abandons,
quarantine releases, and concurrent GC. All checks are on the *final*
catalog state plus the per-agent records — schedule-independent, so a
failing seed reproduces deterministically.

Invariants (DESIGN.md §15):

1.  **Readable catalog.** Every branch resolves; the target's
    first-parent history walks to the root; every commit's tables read.
2.  **Published = verified.** A committed run's ``final_commit`` is on
    the target's first-parent chain, appears there EXACTLY once, and
    equals the branch head its full verifier set validated.
3.  **All-or-nothing.** At its publication commit, ALL of a run's
    table snapshots are present; before it, NONE are — a reader at any
    commit sees either the whole run or none of it.
4.  **Aborted/abandoned runs are invisible.** No snapshot written by a
    run that did not publish appears anywhere on the chain — except
    snapshots re-legitimized by a quarantine release, which must be
    covered by a recorded re-verified release head.
5.  **Lost-ack crashes are still atomic.** A crashed run whose commit
    IS on the chain (died after merge, before acknowledging) is held
    to the committed-run rules; one that is not is held to invisible.
6.  **No mystery publications.** Every chain commit carrying a run_id
    belongs to a known record.
7.  **The Fig. 4 guardrail held.** No unverified quarantine merge
    succeeded, and no live branch was lost to GC mid-run.
"""
from __future__ import annotations

from typing import Iterable, Sequence

from repro.core.catalog import Catalog, Commit

__all__ = ["check_history", "check_swarm"]


def _chain(catalog: Catalog, target: str) -> list[Commit]:
    """Target's first-parent history, root -> head."""
    log = catalog.log(target, limit=1_000_000)
    return list(reversed(log))


def check_history(catalog: Catalog, records: Sequence, *,
                  target: str = "main",
                  released_heads: Iterable[str] = ()) -> list[str]:
    """Return human-readable violations (empty list == history linearizable)."""
    v: list[str] = []

    # 1. catalog readable after everything (crashes, GC included)
    try:
        chain = _chain(catalog, target)
        if not chain or chain[0].parents:
            v.append(f"target {target!r} history does not reach the root")
    except Exception as e:   # noqa: BLE001 - any failure is the finding
        return [f"catalog unreadable: walking {target!r} raised {e!r}"]
    for b in catalog.branches():
        try:
            catalog.branch_info(b)
            catalog.tables(b)
        except Exception as e:   # noqa: BLE001
            v.append(f"branch {b!r} unreadable: {e!r}")

    by_run: dict[str, list[Commit]] = {}
    for c in chain:
        if c.run_id is not None:
            by_run.setdefault(c.run_id, []).append(c)

    # Quarantine releases re-legitimize the RE-VERIFIED branch state —
    # which includes its commit lineage: a released merge may
    # fast-forward the target onto commits originally authored by the
    # aborted run (the sanctioned Fig. 4 reuse path, DESIGN.md §6).
    # Everything reachable from a released head — commits and the
    # snapshots they expose — is therefore exempt from the
    # aborted-state-leak rules; aborted runs whose branches were NOT
    # released stay fully checked.
    released_ancestry: set[str] = set()
    stack = list(released_heads)
    while stack:
        cid = stack.pop()
        if cid in released_ancestry:
            continue
        released_ancestry.add(cid)
        stack.extend(catalog.commit(cid).parents)
    legit: set[tuple[str, str]] = set()
    for cid in released_ancestry:
        for t, s in catalog.commit(cid).tables.items():
            legit.add((t, s))

    index_of = {c.id: i for i, c in enumerate(chain)}
    known_runs = set()

    for r in records:
        rid = r.run_id
        known_runs.add(rid)
        on_chain = by_run.get(rid, [])
        published = r.outcome == "committed" or (
            r.outcome == "crashed" and on_chain)     # lost-ack
        if r.outcome == "committed" and not on_chain:
            v.append(f"{rid}: committed but no commit on {target!r}")
            continue
        if published:
            if len(on_chain) != 1:
                v.append(f"{rid}: {len(on_chain)} chain commits carry its "
                         f"run_id; publication must be exactly one")
                continue
            pub = on_chain[0]
            if r.final_commit is not None and r.final_commit != pub.id:
                v.append(f"{rid}: final_commit {r.final_commit[:8]} is not "
                         f"the chain commit {pub.id[:8]}")
            if r.outcome == "committed" and r.verified_head != pub.id:
                v.append(f"{rid}: published {pub.id[:8]} but verifiers "
                         f"validated {str(r.verified_head)[:8]} — "
                         f"unverified state reached {target!r}")
            missing = [t for t, s in r.tables.items()
                       if pub.tables.get(t) != s]
            if missing:
                v.append(f"{rid}: partial publication — {missing} absent "
                         f"from its own commit {pub.id[:8]}")
            horizon = index_of[pub.id]
            for c in chain[:horizon]:
                early = [t for t, s in r.tables.items()
                         if c.tables.get(t) == s]
                if early:
                    v.append(f"{rid}: snapshots {early} visible at "
                             f"{c.id[:8]} BEFORE publication "
                             f"{pub.id[:8]} (torn run)")
                    break
        else:
            # aborted / abandoned / crashed-unpublished / skipped:
            # nothing this run wrote may be visible, ever — unless a
            # quarantine release re-verified and republished it.
            rogue = [c for c in on_chain
                     if c.id not in released_ancestry]
            if rogue:
                v.append(f"{rid}: outcome {r.outcome!r} but commit(s) "
                         f"{[c.id[:8] for c in rogue]} are on "
                         f"{target!r}")
            for c in chain:
                leaked = [(t, s) for t, s in r.tables.items()
                          if c.tables.get(t) == s
                          and (t, s) not in legit]
                if leaked:
                    v.append(f"{rid}: outcome {r.outcome!r} but wrote "
                             f"{leaked} visible at {c.id[:8]} "
                             f"(aborted state leaked)")
                    break
        if getattr(r, "illegal_merge", False):
            v.append(f"{rid}: UNVERIFIED quarantined branch merged into "
                     f"{target!r} (paper Fig. 4 guardrail failed)")
        if r.outcome == "branch_lost":
            v.append(f"{rid}: live branch vanished mid-run ({r.error}) — "
                     f"GC collected live state")

    for c in chain:
        if c.run_id is not None and c.run_id not in known_runs:
            v.append(f"chain commit {c.id[:8]} carries unknown run_id "
                     f"{c.run_id!r} (mystery publication)")
    return v


def check_swarm(result) -> list[str]:
    """Check a :class:`~repro.chaos.swarm.SwarmResult` end to end."""
    return check_history(result.catalog, result.records,
                         target=result.config.target,
                         released_heads=result.released_heads)
