"""Chaos tier (DESIGN.md §15): seeded fault injection, agent-swarm
stress, and the linearizability checker that audits what survived.

The layering contract: core code never imports this package — it only
announces named :func:`repro.core.hooks.fault_point` seams, and
:func:`fault_injection` installs a :class:`FaultPlan` to act on them.
"""
from repro.chaos.check import check_history, check_swarm
from repro.chaos.clock import FakeClock
from repro.chaos.faults import (FaultPlan, FaultRule, FaultyStore,
                                fault_injection)
from repro.chaos.swarm import (AgentRecord, SwarmConfig, SwarmResult,
                               run_swarm)
from repro.core.hooks import (InjectedCrash, InjectedFault,
                              install_fault_hook)

__all__ = [
    "AgentRecord", "FakeClock", "FaultPlan", "FaultRule", "FaultyStore",
    "InjectedCrash", "InjectedFault", "SwarmConfig", "SwarmResult",
    "check_history", "check_swarm", "fault_injection",
    "install_fault_hook", "run_swarm",
]
