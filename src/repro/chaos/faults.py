"""Deterministic seeded fault injection (DESIGN.md §15).

A :class:`FaultPlan` is the single decision engine: given a seed and a
set of :class:`FaultRule`\\ s, it decides — *deterministically from the
seed* — what happens each time execution passes a named
:func:`~repro.core.hooks.fault_point`. The n-th visit to point ``p``
under seed ``s`` always gets the same decision, because the decision
RNG is keyed ``f"{s}:{p}:{n}"`` with a per-point visit counter; thread
interleaving changes *which thread* draws visit ``n``, never what
visit ``n`` does. Replaying a failing seed therefore replays the same
fault budget at the same points.

Rules match points by dotted-name prefix, so ``FaultRule("txn.commit",
"fail", 0.2)`` covers every seam in the publication loop while
``FaultRule("filestore.put_ref.pre_replace", "crash", 1.0)`` targets
exactly the ref torn-write window. Kinds:

- ``"fail"``  → raise :class:`~repro.core.hooks.InjectedFault`
  (recoverable: the op errors, normal abort paths run);
- ``"crash"`` → raise :class:`~repro.core.hooks.InjectedCrash`
  (simulated process death: ``except Exception`` cleanup is skipped);
- ``"torn"``  → like ``"crash"``, but first truncate the in-flight
  temp file (``ctx["tmp"]``) to a seeded byte length — the
  torn-write adversary for :meth:`FileStore.put_ref`;
- ``"delay"`` → sleep a seeded ``U[0, delay_s]`` (real wall time by
  default: delays exist to perturb thread schedules).

``budget`` caps the total number of fail/crash/torn injections — the
fixed fault budget the contended-publication benchmark's success-rate
gate runs under. Delays don't consume budget.

:class:`FaultyStore` wraps any :class:`~repro.core.store.ObjectStore`
and announces a fault point before each operation, putting the
physical layer under the same plan as the publication loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import random
import threading
import time
from typing import Any, Callable, Iterator, Sequence

from repro.core.hooks import (InjectedCrash, InjectedFault, fault_point,
                              install_fault_hook)
from repro.core.store import ObjectStore
from repro.obs import get_recorder

__all__ = ["FaultRule", "FaultPlan", "FaultyStore", "fault_injection"]

_FAULT_KINDS = ("fail", "crash", "torn", "delay")


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection rule: at points matching ``match`` (dotted-name
    prefix), act with probability ``rate`` per visit."""

    match: str
    kind: str              # "fail" | "crash" | "torn" | "delay"
    rate: float = 1.0
    delay_s: float = 0.002  # max sleep for kind="delay"

    def __post_init__(self):
        if self.kind not in _FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_FAULT_KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")


class FaultPlan:
    """Seed-deterministic fault decisions over named points.

    Thread-safe; one plan is shared by every thread of a swarm. The
    ``injected`` log records ``(point, visit_n, kind)`` for every
    injection actually fired — the replay/debug trail a failing seed
    ships with.
    """

    def __init__(self, seed: int | str, rules: Sequence[FaultRule] = (),
                 *, budget: int | None = None,
                 sleep: Callable[[float], None] = time.sleep):
        self.seed = seed
        self.rules = tuple(rules)
        self.budget = budget
        self._sleep = sleep
        self._lock = threading.Lock()
        self._visits: dict[str, int] = {}
        self._spent = 0
        self.injected: list[tuple[str, int, str]] = []

    @property
    def faults_injected(self) -> int:
        with self._lock:
            return self._spent

    def _decide(self, point: str) -> tuple[FaultRule | None, int,
                                           random.Random]:
        """Pick the rule (if any) firing at this visit. The visit
        counter is the only shared state consulted, so the mapping
        visit-number → decision is pure in (seed, point, n)."""
        with self._lock:
            n = self._visits.get(point, 0)
            self._visits[point] = n + 1
        rng = random.Random(f"{self.seed}:{point}:{n}")
        for rule in self.rules:
            if point.startswith(rule.match) and rng.random() < rule.rate:
                return rule, n, rng
        return None, n, rng

    def __call__(self, point: str, ctx: dict[str, Any]) -> None:
        """The installed hook: act on ``fault_point(point, **ctx)``."""
        rule, n, rng = self._decide(point)
        if rule is None:
            return
        if rule.kind == "delay":
            self._record(point, n, "delay")
            self._sleep(rng.uniform(0.0, rule.delay_s))
            return
        # fail/crash/torn consume the fault budget atomically.
        with self._lock:
            if self.budget is not None and self._spent >= self.budget:
                return
            self._spent += 1
        self._record(point, n, rule.kind)
        if rule.kind == "fail":
            raise InjectedFault(point)
        if rule.kind == "torn":
            tmp = ctx.get("tmp")
            if tmp is not None and os.path.exists(tmp):
                size = os.path.getsize(tmp)
                with open(tmp, "r+b") as f:
                    f.truncate(rng.randrange(size) if size else 0)
        raise InjectedCrash(point)

    def _record(self, point: str, n: int, kind: str) -> None:
        with self._lock:
            self.injected.append((point, n, kind))
        rec = get_recorder()
        if rec.enabled:
            rec.event("injected_fault", point=point, visit=n, kind=kind)
            rec.metrics.counter(f"chaos.injected.{kind}").inc()


@contextlib.contextmanager
def fault_injection(plan: FaultPlan) -> Iterator[FaultPlan]:
    """Scope within which ``plan`` drives every ``fault_point``.

    Restores the previously installed hook on exit, so chaos scopes
    nest and tests cannot leak a hook into each other.
    """
    prev = install_fault_hook(plan)
    try:
        yield plan
    finally:
        install_fault_hook(prev)


class FaultyStore(ObjectStore):
    """Wrap a store so every operation passes a ``store.*`` fault point.

    The wrapper holds no policy: with no hook installed it is a pure
    passthrough, and under :func:`fault_injection` the plan decides.
    Structured helpers (``put_json``/``put_array``/pytrees) inherit the
    faults because they bottom out in :meth:`put`/:meth:`get`.
    """

    def __init__(self, inner: ObjectStore):
        self.inner = inner

    def put(self, data: bytes) -> str:
        fault_point("store.put", n_bytes=len(data))
        return self.inner.put(data)

    def get(self, key: str) -> bytes:
        fault_point("store.get", key=key)
        return self.inner.get(key)

    def __contains__(self, key: str) -> bool:
        return key in self.inner

    def keys(self) -> Iterator[str]:
        return self.inner.keys()

    def put_ref(self, name: str, key: str) -> None:
        fault_point("store.put_ref", name=name, key=key)
        self.inner.put_ref(name, key)

    def get_ref(self, name: str) -> str | None:
        fault_point("store.get_ref", name=name)
        return self.inner.get_ref(name)

    def refs(self, prefix: str = "") -> Iterator[str]:
        return self.inner.refs(prefix)

    def delete_ref(self, name: str) -> bool:
        fault_point("store.delete_ref", name=name)
        return self.inner.delete_ref(name)

    def __getattr__(self, name: str) -> Any:
        # sweep_tmp and any backend-specific surface delegate; hasattr
        # answers match the wrapped backend's.
        return getattr(self.inner, name)
