"""Counters/histograms registry: the aggregate view of trace events.

Spans answer "what happened in *this* run"; the registry answers "how
often / how long across everything the recorder saw" — cache hit rate,
nodes re-executed per rebase, per-kernel wall time — without walking
span trees. The same instrumentation sites feed both (one event, one
``inc``/``observe``), and :meth:`MetricsRegistry.snapshot` serializes
into run manifests and BENCH documents.

A :class:`Histogram` keeps O(1) state (count/sum/min/max), not samples:
manifests must stay small no matter how many nodes a run executes.

``NULL_METRICS`` is the disabled path — a registry whose instruments
drop every update with no allocation, shared by every NullRecorder.
"""
from __future__ import annotations

import threading
from typing import Any

__all__ = ["Counter", "Histogram", "MetricsRegistry", "NULL_METRICS"]


class Counter:
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n


class Histogram:
    """O(1) summary of observations (count/sum/min/max; mean derived)."""

    __slots__ = ("_lock", "count", "sum", "min", "max")

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if self.min is None or v < self.min:
                self.min = v
            if self.max is None or v > self.max:
                self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max, "mean": self.mean}


class _NullCounter:
    __slots__ = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    count, sum, min, max, mean = 0, 0.0, None, None, 0.0

    def observe(self, v: float) -> None:
        pass

    def to_dict(self) -> dict[str, Any]:   # pragma: no cover - not hit
        return {"count": 0, "sum": 0.0, "min": None, "max": None,
                "mean": 0.0}


_NULL_COUNTER = _NullCounter()
_NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Name -> instrument, created on first use; thread-safe."""

    def __init__(self, *, null: bool = False):
        self._null = null
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if self._null:
            return _NULL_COUNTER          # type: ignore[return-value]
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def histogram(self, name: str) -> Histogram:
        if self._null:
            return _NULL_HISTOGRAM        # type: ignore[return-value]
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            return h

    def snapshot(self) -> dict[str, Any]:
        """JSON-serializable state: {"counters": {...}, "histograms":
        {...}} plus derived rates the manifests/benchmarks read."""
        with self._lock:
            counters = {n: c.value for n, c in self._counters.items()}
            hists = {n: h.to_dict() for n, h in self._histograms.items()}
        out: dict[str, Any] = {"counters": counters, "histograms": hists}
        hits = counters.get("engine.cache.hits", 0)
        misses = counters.get("engine.cache.misses", 0)
        if hits + misses:
            out["derived"] = {
                "cache_hit_rate": hits / (hits + misses)}
        return out


NULL_METRICS = MetricsRegistry(null=True)
