"""Commit-anchored audit manifests (DESIGN.md §14).

A *run manifest* is the finished span tree of one transactional run,
serialized to a JSON document and stored in the same content-addressed
``ObjectStore`` the catalog commits live in. The anchoring rule: the
manifest is written under the named ref ``runmanifest/<commit_id>``
*after* the commit ref moves, keyed by the **published** commit id —
so any state an agent can observe in the catalog can be audited
post-hoc via :meth:`Catalog.run_manifest`, and an aborted run leaves
no manifest (there is no commit to anchor it to).

Manifests are observational, never load-bearing: nothing in commit
resolution, cache keys, or contract validation reads them back. A
missing manifest (run executed with tracing disabled) is a normal
state, reported as ``None``.
"""
from __future__ import annotations

from typing import Any

MANIFEST_REF_PREFIX = "runmanifest/"
MANIFEST_FORMAT = "repro.run-manifest/1"

__all__ = ["MANIFEST_REF_PREFIX", "MANIFEST_FORMAT", "build_manifest",
           "store_manifest", "load_manifest"]


def build_manifest(run_span, spans, *, commit_id: str, run_id: str,
                   metrics: dict[str, Any] | None = None,
                   orphan_events: list[dict[str, Any]] | None = None,
                   ) -> dict[str, Any]:
    """Assemble the manifest document for one run.

    ``spans`` is the run's finished subtree (``recorder.subtree``), so
    concurrent runs sharing one recorder each serialize only their own
    spans — parent ids partition the forest.
    """
    return {
        "format": MANIFEST_FORMAT,
        "commit_id": commit_id,
        "run_id": run_id,
        "root_span_id": run_span.span_id,
        "spans": [s.to_dict() for s in spans],
        "metrics": metrics or {},
        "orphan_events": list(orphan_events or ()),
    }


def store_manifest(store, commit_id: str, doc: dict[str, Any]) -> str:
    """Persist ``doc`` content-addressed and anchor it to ``commit_id``.
    Returns the object key."""
    key = store.put_json(doc)
    store.put_ref(MANIFEST_REF_PREFIX + commit_id, key)
    return key


def load_manifest(store, commit_id: str) -> dict[str, Any] | None:
    """The manifest anchored to ``commit_id``, or None if the run was
    not traced (or the id is unknown)."""
    key = store.get_ref(MANIFEST_REF_PREFIX + commit_id)
    if key is None:
        return None
    return store.get_json(key)
