"""Flight recorder: structured tracing, metrics, and audit manifests.

See DESIGN.md §14. Public surface:

- :func:`get_recorder` / :func:`install` / :class:`tracing` — the
  process-ambient recorder and the ``with tracing() as rec:`` entry
  point.
- :class:`TraceRecorder` / :class:`NullRecorder` / :class:`Span` — the
  recorder protocol.
- :class:`MetricsRegistry` — counters/histograms fed by the same
  instrumentation sites.
- ``manifest`` helpers — commit-anchored run manifests
  (``Catalog.run_manifest`` reads these back).
- ``export`` helpers — JSON and Chrome trace-event (Perfetto) output.

Invariant (test-gated): nothing in this package is consulted by
``engine.cache_key`` or any backend ``cache_token`` — tracing observes
execution, it never changes what executes or what a result hashes to.
"""
from repro.obs.export import (
    to_chrome_trace,
    to_json,
    write_chrome_trace,
)
from repro.obs.manifest import (
    MANIFEST_FORMAT,
    MANIFEST_REF_PREFIX,
    build_manifest,
    load_manifest,
    store_manifest,
)
from repro.obs.metrics import NULL_METRICS, Counter, Histogram, MetricsRegistry
from repro.obs.trace import (
    NullRecorder,
    Recorder,
    Span,
    TraceRecorder,
    get_recorder,
    install,
    tracing,
)

__all__ = [
    "Span", "Recorder", "NullRecorder", "TraceRecorder",
    "get_recorder", "install", "tracing",
    "Counter", "Histogram", "MetricsRegistry", "NULL_METRICS",
    "MANIFEST_REF_PREFIX", "MANIFEST_FORMAT",
    "build_manifest", "store_manifest", "load_manifest",
    "to_json", "to_chrome_trace", "write_chrome_trace",
]
