"""Hierarchical structured tracing: the flight recorder (DESIGN.md §14).

A *span* is one timed region of a run — ``run`` → ``publication
attempt`` → ``rebase``/``revalidate`` → ``plan wave`` → ``node
execution`` → ``backend kernel call`` on the transactional path,
``sql`` → ``parse`` → ``compile`` → ``infer`` on the query path —
carrying typed attributes (rows in/out, cache verdict + key, the
``auto`` backend's decision and *why*, optimizer pass provenance,
bytes moved by the sharded exchange, rebase conflict details). Spans
form a tree via parent ids; *events* are point-in-time records attached
to the innermost open span (degradations, backend decisions, conflict
details).

Two recorders implement one protocol:

- :class:`NullRecorder` — the default. ``enabled`` is False, ``span()``
  returns a shared no-op context manager, ``event()`` returns
  immediately, and the metrics registry drops everything. Call sites
  follow the discipline *no string formatting and no dict building
  unless* ``rec.enabled`` *(or the values are already at hand)*, so the
  disabled path costs two attribute loads and a truth test per op —
  gated ≤2% by ``benchmarks/tracing_overhead.py``.
- :class:`TraceRecorder` — appends finished spans to a thread-safe
  list. Span parentage propagates through a :mod:`contextvars`
  variable, so nesting is correct across the engine's wave thread pool
  (the executor copies the submitting context per task) and across
  concurrent transactional runs in different threads (a fresh thread
  starts with an empty context, so runs never adopt each other's
  spans).

**The cache-key non-interference invariant** (test-gated): nothing in
this module is ever consulted by ``repro.core.engine.cache_key`` or by
any backend ``cache_token`` — tracing on/off, or two different
recorders, share cache entries bit for bit. Tracing observes execution;
it must never *be* execution state.
"""
from __future__ import annotations

import contextvars
import itertools
import threading
import time
from typing import Any

from repro.obs.metrics import NULL_METRICS, MetricsRegistry

__all__ = ["Span", "Recorder", "NullRecorder", "TraceRecorder",
           "get_recorder", "install", "tracing"]


class Span:
    """One timed region. Mutable while open (attributes are set as the
    instrumented code learns them); treated as immutable once ``t1``
    is stamped. ``attrs`` values must be JSON-serializable."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "attrs",
                 "events", "thread_id")

    def __init__(self, name: str, span_id: int, parent_id: int | None,
                 attrs: dict[str, Any]):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.time()
        self.t1: float | None = None
        self.attrs = attrs
        self.events: list[dict[str, Any]] = []
        self.thread_id = threading.get_ident()

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else time.time()) - self.t0

    def to_dict(self) -> dict[str, Any]:
        return {"name": self.name, "span_id": self.span_id,
                "parent_id": self.parent_id, "t0": self.t0,
                "t1": self.t1, "thread_id": self.thread_id,
                "attrs": dict(self.attrs), "events": list(self.events)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"<Span {self.name!r} id={self.span_id} "
                f"parent={self.parent_id} attrs={self.attrs}>")


class _NullSpan:
    """Shared no-op span/context-manager: the whole disabled path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class Recorder:
    """The tracing protocol. ``enabled`` gates every call site."""

    enabled: bool = False
    metrics: MetricsRegistry = NULL_METRICS

    def span(self, name: str, /, **attrs: Any):
        """Context manager for one span; yields the span so the body
        can ``.set(...)`` attributes discovered during execution."""
        raise NotImplementedError

    def start_span(self, name: str, /, **attrs: Any):
        """Non-context-managed open (for begin()/commit() pairs split
        across calls); close with :meth:`end_span`."""
        raise NotImplementedError

    def end_span(self, span) -> None:
        raise NotImplementedError

    def event(self, name: str, /, **attrs: Any) -> None:
        """Attach a point-in-time event to the innermost open span."""
        raise NotImplementedError


class NullRecorder(Recorder):
    enabled = False
    metrics = NULL_METRICS

    def span(self, name: str, /, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def start_span(self, name: str, /, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def end_span(self, span) -> None:
        pass

    def event(self, name: str, /, **attrs: Any) -> None:
        pass


# The ambient parent span. Worker threads start with an empty context
# (parent=None) unless the submitter copies its context in — which is
# exactly what the engine does per task, so node spans nest under the
# wave/run that scheduled them while unrelated threads stay separate.
_current: contextvars.ContextVar["Span | None"] = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class _SpanCtx:
    """Context manager pairing one Span with the ambient-parent var."""

    __slots__ = ("recorder", "span", "_token")

    def __init__(self, recorder: "TraceRecorder", span: Span):
        self.recorder = recorder
        self.span = span
        self._token: contextvars.Token | None = None

    def __enter__(self) -> Span:
        self._token = _current.set(self.span)
        return self.span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self.span.attrs.setdefault("error", repr(exc))
        _current.reset(self._token)
        self.recorder._finish(self.span)
        return False


class TraceRecorder(Recorder):
    """Collects spans and events; one instance per trace sink.

    Thread-safe: span creation/finish and event attachment lock a
    single mutex; span *attribute* writes are single-writer by
    construction (only the code inside the span's scope sets them).
    """

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._spans: list[Span] = []        # finished, finish order
        self._open: dict[int, Span] = {}    # still running
        self._orphan_events: list[dict[str, Any]] = []  # no open span
        self.metrics = MetricsRegistry()

    # -- span lifecycle -------------------------------------------------
    def _new_span(self, name: str, attrs: dict[str, Any],
                  parent: "Span | None") -> Span:
        with self._lock:
            sid = next(self._ids)
            sp = Span(name, sid, parent.span_id if parent else None,
                      attrs)
            self._open[sid] = sp
        return sp

    def _finish(self, span: Span) -> None:
        span.t1 = time.time()
        with self._lock:
            self._open.pop(span.span_id, None)
            self._spans.append(span)

    def span(self, name: str, /, **attrs: Any) -> _SpanCtx:
        return _SpanCtx(self, self._new_span(name, attrs,
                                             _current.get()))

    def start_span(self, name: str, /, **attrs: Any) -> Span:
        sp = self._new_span(name, attrs, _current.get())
        # begin()/commit() run in the opening thread: make the open
        # span the ambient parent there (threads the run span under
        # nothing but over everything the run does in this thread).
        _current.set(sp)
        return sp

    def end_span(self, span: Span) -> None:
        if isinstance(span, _NullSpan) or span.t1 is not None:
            return
        if _current.get() is span:
            _current.set(self._parent_of(span))
        self._finish(span)

    def _parent_of(self, span: Span) -> "Span | None":
        if span.parent_id is None:
            return None
        with self._lock:
            if span.parent_id in self._open:
                return self._open[span.parent_id]
            for s in self._spans:
                if s.span_id == span.parent_id:
                    return s
        return None

    # -- events ---------------------------------------------------------
    def event(self, name: str, /, **attrs: Any) -> None:
        cur = _current.get()
        ev = {"name": name, "t": time.time(), **attrs}
        if cur is not None:
            with self._lock:
                cur.events.append(ev)
        else:
            with self._lock:
                self._orphan_events.append(ev)

    def orphan_events(self) -> list[dict[str, Any]]:
        """Events recorded with no open span (top-level context)."""
        with self._lock:
            return list(self._orphan_events)

    # -- introspection ---------------------------------------------------
    def spans(self, name: str | None = None) -> list[Span]:
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def find(self, name: str) -> "Span | None":
        for s in self.spans(name):
            return s
        return None

    def subtree(self, root: Span) -> list[Span]:
        """All finished spans under ``root`` (inclusive), in start
        order — the serialization unit of a run manifest."""
        with self._lock:
            spans = list(self._spans)
        children: dict[int | None, list[Span]] = {}
        for s in spans:
            children.setdefault(s.parent_id, []).append(s)
        out: list[Span] = []
        stack = [root]
        while stack:
            s = stack.pop()
            out.append(s)
            stack.extend(children.get(s.span_id, ()))
        out.sort(key=lambda s: (s.t0, s.span_id))
        return out


# ---------------------------------------------------------------------------
# the ambient recorder
# ---------------------------------------------------------------------------

_recorder: Recorder = NullRecorder()
_install_lock = threading.Lock()


def get_recorder() -> Recorder:
    """The process-ambient recorder (a NullRecorder unless tracing is
    on). Instrumentation sites call this once per operation — never per
    row — and gate any work beyond the no-op calls on ``.enabled``."""
    return _recorder


def install(recorder: Recorder) -> Recorder:
    """Install ``recorder`` process-wide; returns the previous one."""
    global _recorder
    with _install_lock:
        prev = _recorder
        _recorder = recorder
    return prev


class tracing:
    """``with tracing() as rec:`` — install a fresh TraceRecorder for
    the block, restore the previous recorder after. Also usable as
    ``tracing(rec)`` to install a caller-built recorder."""

    def __init__(self, recorder: "TraceRecorder | None" = None):
        self.recorder = recorder if recorder is not None \
            else TraceRecorder()
        self._prev: Recorder | None = None

    def __enter__(self) -> TraceRecorder:
        self._prev = install(self.recorder)
        return self.recorder

    def __exit__(self, *exc) -> bool:
        install(self._prev)
        return False
