"""Trace exporters: plain JSON and Chrome trace-event format.

The Chrome format (one ``{"traceEvents": [...]}`` document of complete
``"ph": "X"`` events with microsecond timestamps) loads directly into
``chrome://tracing`` / Perfetto, which is the cheapest possible
flame-graph UI for a run: each span becomes a slice on its thread's
track, attributes ride in ``args``, and point events become ``"ph":
"i"`` instants. Works from either live :class:`~repro.obs.trace.Span`
objects or the span dicts stored in a run manifest.
"""
from __future__ import annotations

import json
from typing import Any

__all__ = ["spans_to_dicts", "to_json", "to_chrome_trace",
           "write_chrome_trace"]


def spans_to_dicts(spans) -> list[dict[str, Any]]:
    """Normalize live Spans or already-serialized dicts to dicts."""
    return [s if isinstance(s, dict) else s.to_dict() for s in spans]


def to_json(spans, *, indent: int | None = 2) -> str:
    return json.dumps({"spans": spans_to_dicts(spans)}, indent=indent,
                      sort_keys=True)


def _category(name: str) -> str:
    # First path segment groups related spans onto one color in the UI.
    return name.split(".", 1)[0]


def to_chrome_trace(spans, *, pid: int = 1) -> dict[str, Any]:
    """Chrome trace-event document for ``spans`` (Spans or dicts)."""
    events: list[dict[str, Any]] = []
    for s in spans_to_dicts(spans):
        t0 = s["t0"]
        t1 = s["t1"] if s["t1"] is not None else t0
        ts_us = t0 * 1e6
        events.append({
            "name": s["name"],
            "cat": _category(s["name"]),
            "ph": "X",
            "ts": ts_us,
            "dur": max(0.0, (t1 - t0) * 1e6),
            "pid": pid,
            "tid": s["thread_id"],
            "args": dict(s["attrs"]),
        })
        for ev in s["events"]:
            ev = dict(ev)
            events.append({
                "name": ev.pop("name"),
                "cat": "event",
                "ph": "i",
                "ts": ev.pop("t") * 1e6,
                "pid": pid,
                "tid": s["thread_id"],
                "s": "t",
                "args": ev,
            })
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path, spans, *, pid: int = 1) -> None:
    with open(path, "w") as fh:
        json.dump(to_chrome_trace(spans, pid=pid), fh)
