"""Named fault-injection points (the chaos tier's seam, DESIGN.md §15).

The chaos layer (:mod:`repro.chaos`) needs to fail, delay, or crash the
system at *named* points — between the atomic metadata operations of the
publication loop, and around the physical store's ref writes — without
the core ever importing chaos code. This module is that seam: core call
sites invoke :func:`fault_point` with a dotted point name; in production
the hook is ``None`` and the whole call costs one global load and a
truth test (the same discipline as ``obs.get_recorder().enabled``).

The fault model this encodes (DESIGN.md §15): catalog metadata
operations are atomic (the paper's substrate guarantees them via a
relational database; here a lock), so faults are injected at the
*seams between* atomic ops — exactly where a real process dies — never
inside one. A hook may:

- return normally           (no fault);
- sleep / yield             (adversarial schedule perturbation);
- raise :class:`InjectedFault`  (an ``Exception``: the operation fails,
  normal error handling runs — the run aborts cleanly);
- raise :class:`InjectedCrash`  (a ``BaseException``: simulated process
  death — ``except Exception`` cleanup handlers must NOT run, just as
  they would not for a killed process).
"""
from __future__ import annotations

from typing import Any, Callable

__all__ = ["InjectedFault", "InjectedCrash", "fault_point",
           "install_fault_hook"]


class InjectedFault(Exception):
    """A chaos-injected *recoverable* failure of one operation."""

    def __init__(self, point: str, detail: str = ""):
        super().__init__(f"injected fault at {point!r}"
                         + (f": {detail}" if detail else ""))
        self.point = point


class InjectedCrash(BaseException):
    """Simulated process death at a named point.

    Deliberately a ``BaseException``: the run's ``except Exception``
    cleanup (abort, branch marking) must not fire — a dead process
    cleans up nothing. Whatever state the crash leaves behind is what
    recovery (GC + the catalog's atomic refs) must cope with.
    """

    def __init__(self, point: str):
        super().__init__(f"injected crash at {point!r}")
        self.point = point


FaultHook = Callable[[str, dict[str, Any]], None]

_hook: FaultHook | None = None


def fault_point(name: str, /, **ctx: Any) -> None:
    """Announce a named injection point. No-op unless a hook is
    installed; the hook decides (deterministically, from its seed)
    whether to fault, delay, or crash here."""
    hook = _hook
    if hook is not None:
        hook(name, ctx)


def install_fault_hook(hook: FaultHook | None) -> FaultHook | None:
    """Install (or clear, with ``None``) the process-wide hook;
    returns the previous one so scopes can nest."""
    global _hook
    prev = _hook
    _hook = hook
    return prev
