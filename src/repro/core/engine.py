"""Wave-parallel, content-addressed incremental execution engine.

DESIGN.md §8. Two orthogonal accelerations over the sequential
node-at-a-time worker the paper describes:

- **Wave scheduling**: :func:`repro.core.planner.plan` assigns every
  step a dependency level (*wave*); :class:`PlanExecutor` runs each
  wave's nodes concurrently on a thread pool. A wave only starts after
  the previous wave fully drained, so every node sees exactly the
  snapshots its inputs published — the §3.3 read-isolation story is
  unchanged, just wider.

- **Content-addressed function cache** (:class:`NodeCache`): each node
  evaluation is keyed by ``hash(node source + output-schema fingerprint
  + declared casts, input snapshot keys)``. On a hit the engine skips
  execution and reuses the stored output snapshot — but still runs
  :func:`validate_table` against the declared contract (minus the
  checks Appendix A statically discharged), so a cache hit can never
  launder data past the worker moment. Entries persist as named refs in
  the :class:`~repro.core.store.ObjectStore`, so a file-backed cache
  survives restarts and is shared by every client of the store.

Failure semantics (the abort path of §3.3): when a node fails, its
in-flight wave siblings are *drained, not cancelled*; every output that
passed validation — earlier waves plus validated siblings, in plan
order — is reported via :class:`~repro.core.errors.ExecutionError`
``.partial`` so the runner can flush exactly the validated outputs to
the ABORTED branch, deterministically.
"""
from __future__ import annotations

import contextvars
import dataclasses
import hashlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Mapping

from repro import exec as exec_backends
from repro.core.contracts import validate_table
from repro.core.errors import ExecutionError
from repro.core.planner import Plan, PlanStep
from repro.core.store import ObjectStore
from repro.data.tables import Table
from repro.obs import get_recorder

__all__ = ["cache_key", "NodeCache", "ExecutionOutcome", "PlanExecutor"]


def cache_key(step: PlanStep,
              input_snapshots: Mapping[str, str]) -> str | None:
    """Content address of one node evaluation.

    Static half: the node's transformation source, output-schema
    fingerprint, and declared casts (``Node.cache_material``). Dynamic
    half: the snapshot key of every input, keyed by *parameter* name —
    not merely the sorted key set, because a binary node applied to
    ``(A, B)`` and ``(B, A)`` is a different evaluation — plus the
    *cache token* of the active execution backend (DESIGN.md §9/§10):
    all backends are *supposed* to agree bit-for-bit, but a cache hit
    must never be the mechanism that launders a divergent backend's
    output past that claim, so switching backends moves every key. The
    token extends the bare name with ambient execution state the
    backend depends on — device-mesh shape / shard count for the
    ``jax``/``sharded``/``auto`` backends — because a mesh change
    regroups float SUM summation order under the documented carve-out
    and must never serve a stale cross-mesh hit. ``None`` if the node
    is not content-addressable (e.g. it captures state that cannot be
    fingerprinted stably): such nodes always execute.

    Optimizer state is key material too, same discipline: the active
    pass list and the step's rewrite provenance are folded in, so
    flipping a pass (or a pass rewriting a tree differently) can never
    serve a stale cross-plan hit. An unoptimized plan (empty pass
    list) keys exactly as before. The rewritten logical tree itself is
    already the static half (``PlanStep.cache_material`` describes the
    tree the step will actually execute, not the authored node body).

    Non-key material, by invariant (DESIGN.md §14, test-gated): nothing
    from ``repro.obs`` — tracing on or off, and any trace contents,
    share cache entries bit for bit.
    """
    material = step.cache_material()
    if material is None:
        return None
    h = hashlib.sha256()
    h.update(material.encode())
    h.update(
        f"|backend={exec_backends.active_backend().cache_token()}".encode())
    if step.opt_passes:
        h.update(f"|opt={','.join(step.opt_passes)}".encode())
    for p in step.provenance:
        h.update(f"|rw={p}".encode())
    for param in sorted(input_snapshots):
        h.update(f"|{param}={input_snapshots[param]}".encode())
    return h.hexdigest()[:32]


class NodeCache:
    """``cache_key -> output snapshot key``, persisted as store refs.

    The cache records *function evaluations*, not publications: an entry
    written by a run that later aborts (verifier failure, publication
    conflict) is still sound — the snapshot it names was produced by
    exactly this function over exactly these inputs and passed worker
    validation. Transactional guarantees stay with the run protocol;
    the cache only ever short-circuits recomputation.

    Correctness assumes node functions are deterministic. A
    nondeterministic node degrades to pinning its first observed output
    (reproducible-by-construction, the function-caching stance of
    "Reproducible data science over data lakes").
    """

    REF_PREFIX = "fncache/"

    def __init__(self, store: ObjectStore):
        self.store = store
        self._mem: dict[str, str] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, key: str) -> str | None:
        with self._lock:
            snap = self._mem.get(key)
        if snap is None:
            snap = self.store.get_ref(self.REF_PREFIX + key)
        # the ref is only as good as the blob it points to: a pruned
        # store demotes the entry to a miss instead of a KeyError.
        if snap is not None and snap in self.store:
            with self._lock:
                self._mem[key] = snap
                self.hits += 1
            return snap
        with self._lock:
            self.misses += 1
        return None

    def put(self, key: str, snapshot: str) -> None:
        with self._lock:
            self._mem[key] = snapshot
        self.store.put_ref(self.REF_PREFIX + key, snapshot)


@dataclasses.dataclass(frozen=True)
class ExecutionOutcome:
    """Result of one full plan execution (all waves drained)."""

    snapshots: Mapping[str, str]   # table -> output snapshot key
    executed: tuple[str, ...]      # nodes actually run (cache misses)
    cached: tuple[str, ...]        # nodes satisfied from the cache


class PlanExecutor:
    """Executes a validated :class:`Plan` wave by wave.

    Stateless across :meth:`execute` calls except for the (shared,
    thread-safe) :class:`NodeCache`, so one executor instance serves
    both the initial run and post-rebase re-execution.
    """

    def __init__(self, plan: Plan, store: ObjectStore, *,
                 cache: NodeCache | None = None,
                 max_workers: int | None = None):
        self.plan = plan
        self.store = store
        self.cache = cache
        widest = max((len(w) for w in plan.waves), default=1)
        self.max_workers = max(1, max_workers if max_workers is not None
                               else min(16, widest))

    # ------------------------------------------------------------------
    def execute(self, resolve_source: Callable[[str], str], *,
                fail_after: str | None = None) -> ExecutionOutcome:
        """Run every wave; returns the full table -> snapshot mapping.

        ``resolve_source`` maps a *source* table name to its snapshot
        key (the runner binds it to the transactional branch, so reads
        are pinned). ``fail_after`` injects a failure after the named
        node validates — the deterministic abort-path hook.
        """
        snaps: dict[str, str] = {}      # table -> snapshot (sources too)
        tables: dict[str, Table] = {}   # materialized tables
        mat_lock = threading.Lock()     # guards lazy source loads
        # validated PUBLISHED outputs, plan order — optimizer-
        # materialized auxiliary steps execute and cache like any node
        # but never reach the commit/flush set.
        written: dict[str, str] = {}
        executed: list[str] = []
        cached: list[str] = []

        def materialize(table: str) -> Table:
            # upstream outputs were installed between waves; only source
            # tables are lazily loaded (and memoized) here.
            if table in tables:
                return tables[table]
            with mat_lock:
                if table not in tables:
                    tables[table] = Table.from_blobs(self.store,
                                                     snaps[table])
                return tables[table]

        rec = get_recorder()
        # Per-node runtime profile, collected unconditionally (a few
        # dict writes per NODE, not per row) so `plan.describe(
        # analyze=True)` works with tracing off. Name -> record.
        profile: dict[str, dict] = {}

        def run_step(step: PlanStep):
            """Returns (snapshot|None, table|None, was_cached, error)."""
            if rec.enabled:
                with rec.span("node", node=step.node.name,
                              wave=step.wave) as sp:
                    return step_body(step, sp)
            return step_body(step, None)

        def step_body(step: PlanStep, sp):
            node = step.node
            t_start = time.perf_counter()
            verdict = "uncacheable"
            key = None
            out = None
            try:
                in_snaps = {}
                for param, t in node.inputs.items():
                    if t not in snaps:
                        with mat_lock:
                            if t not in snaps:
                                snaps[t] = resolve_source(t)
                    in_snaps[param] = snaps[t]
                key = (cache_key(step, in_snaps)
                       if self.cache is not None else None)
                if key is not None:
                    verdict = "miss"
                    hit = self.cache.lookup(key)
                    if hit is not None:
                        try:
                            out = Table.from_blobs(self.store, hit)
                        except KeyError:
                            # manifest survived but a column blob was
                            # pruned: demote to a miss and recompute
                            # (never abort on a stale cache entry).
                            out = None
                        if out is not None:
                            # a hit is still physically validated
                            # against the CURRENT plan's contract; only
                            # the checks Appendix A discharged are
                            # skipped.
                            validate_table(out, node.output_schema,
                                           elide=step.elided_null_checks,
                                           name=node.name)
                            verdict = "hit"
                            return hit, out, True, self._inject(
                                step, fail_after)
                ins = {t: materialize(t)
                       for t in set(node.inputs.values())}
                out = step.execute(ins)
                # moment (3): validate physical data BEFORE persisting.
                validate_table(out, node.output_schema,
                               elide=step.elided_null_checks,
                               name=node.name)
                snap = out.to_blobs(self.store)
                if key is not None:
                    self.cache.put(key, snap)
                return snap, out, False, self._inject(step, fail_after)
            except Exception as e:
                verdict = "error"
                return None, None, False, e
            finally:
                wall_s = time.perf_counter() - t_start
                rows_out = out.num_rows if out is not None else None
                record = {"node": node.name, "wave": step.wave,
                          "cache": verdict, "wall_s": wall_s,
                          "rows_out": rows_out}
                with mat_lock:
                    profile[node.name] = record
                if sp is not None:
                    sp.set(cache=verdict, rows_out=rows_out)
                    if key is not None:
                        sp.set(cache_key=key)
                    m = rec.metrics
                    if verdict == "hit":
                        m.counter("engine.cache.hits").inc()
                    elif verdict == "miss":
                        m.counter("engine.cache.misses").inc()
                    m.histogram("engine.node.wall_s").observe(wall_s)

        def submit(pool, step):
            # copy_context(): worker threads inherit the submitting
            # wave span as ambient parent (a fresh Context per task —
            # one Context cannot be entered by two threads at once).
            if rec.enabled:
                return pool.submit(contextvars.copy_context().run,
                                   run_step, step)
            return pool.submit(run_step, step)

        with ThreadPoolExecutor(max_workers=self.max_workers) as pool:
            for wave_idx, wave in enumerate(self.plan.waves):
                wave_span = (rec.span("wave", index=wave_idx,
                                      nodes=len(wave))
                             if rec.enabled else None)
                if wave_span is not None:
                    wave_span.__enter__()
                try:
                    futures = [submit(pool, step) for step in wave]
                    errors: list[tuple[str, BaseException]] = []
                    # drain the WHOLE wave before acting on any
                    # failure: siblings in flight finish, and their
                    # validated outputs are preserved — the flush set
                    # is a deterministic function of the plan, not of
                    # thread timing.
                    for step, fut in zip(wave, futures):
                        snap, table, was_cached, err = fut.result()
                        name = step.node.name
                        if snap is not None:
                            if step.published:
                                written[name] = snap
                            snaps[name] = snap
                            tables[name] = table
                            (cached if was_cached
                             else executed).append(name)
                        if err is not None:
                            errors.append((name, err))
                finally:
                    if wave_span is not None:
                        wave_span.__exit__(None, None, None)
                if errors:
                    name, cause = errors[0]   # first in plan order
                    self._attach_runtime(profile)
                    raise ExecutionError(
                        f"node {name!r} failed: {cause}", cause=cause,
                        partial=written, executed=tuple(executed),
                        cached=tuple(cached))
        self._attach_runtime(profile)
        return ExecutionOutcome(snapshots=dict(written),
                                executed=tuple(executed),
                                cached=tuple(cached))

    def _attach_runtime(self, profile: dict[str, dict]) -> None:
        # Plan is a frozen dataclass; the profile rides as a non-field
        # attribute (observational only — never part of plan identity
        # or cache keys). `describe(analyze=True)` renders it.
        object.__setattr__(self.plan, "_runtime", profile)

    @staticmethod
    def _inject(step: PlanStep, fail_after: str | None):
        if fail_after == step.node.name:
            # testing hook: the node's own output validated (and is
            # preserved); the failure hits while wave siblings may
            # still be in flight.
            return RuntimeError(
                f"injected failure after node {step.node.name!r}")
        return None
