"""Error taxonomy for the correct-by-design pipeline core.

The paper's central design principle (§3) is *fail fast*: "we should never
fail at a later moment if we could have failed at a previous one". Every
error therefore carries the ``Moment`` at which it was raised so tests can
assert the ordering property mechanically.
"""
from __future__ import annotations

import enum


class Moment(enum.IntEnum):
    """The three moments of a run's life-cycle (paper §3, Figure 1).

    Ordered: AUTHORING < CONTROL_PLANE < WORKER. A correct system surfaces
    each class of failure at the *smallest* moment able to detect it.
    """

    AUTHORING = 1      # local code environment, before a run is triggered
    CONTROL_PLANE = 2  # plan validation, before any distributed execution
    WORKER = 3         # runtime, after execution but before persisting data


class ReproError(Exception):
    """Base class for all framework errors."""

    moment: Moment = Moment.WORKER


class ContractError(ReproError):
    """A schema/contract violation (paper §3.1)."""


class ContractCompositionError(ContractError):
    """Adjacent DAG nodes do not compose (control-plane static check)."""

    moment = Moment.CONTROL_PLANE


class ContractAuthoringError(ContractError):
    """A schema is ill-formed at definition time (authoring check)."""

    moment = Moment.AUTHORING


class ContractRuntimeError(ContractError):
    """Physical data does not conform to its declared schema (worker check)."""

    moment = Moment.WORKER


class CatalogError(ReproError):
    """Versioning layer errors (paper §3.2)."""


class BranchNotFound(CatalogError):
    pass


class BranchExists(CatalogError):
    pass


class RefConflict(CatalogError):
    """Optimistic CAS on a branch head failed (concurrent writer)."""


class MergeConflict(CatalogError):
    """Both branches changed the same table since the merge base."""


class VisibilityError(CatalogError):
    """Operation violates branch visibility rules (the Fig. 4 guardrail)."""


class TransactionError(ReproError):
    """Transactional run protocol errors (paper §3.3)."""


class TransactionAborted(TransactionError):
    """The run failed; its transactional branch was preserved for debugging."""

    def __init__(self, msg: str, branch: str | None = None,
                 cause: BaseException | None = None):
        super().__init__(msg)
        self.branch = branch
        self.cause = cause


class PublicationConflict(TransactionAborted):
    """Rebase-and-revalidate publication exhausted its retry budget.

    The target branch kept moving faster than the run could rebase,
    re-verify, and CAS its merge. The run is aborted (branch preserved);
    the caller may retry the whole run against the new head.
    """


class PlanError(ReproError):
    """DAG is structurally invalid (cycle, missing input, duplicate output)."""

    moment = Moment.CONTROL_PLANE


class ExecutionError(ReproError):
    """A node failed during wave execution (DESIGN.md §8).

    Raised by the engine after the failing node's *whole wave* has
    drained: ``partial`` maps every output that validated before the
    failure (earlier waves + validated wave siblings, in plan order) to
    its snapshot key, so the runner can flush exactly the validated
    outputs to the ABORTED branch — deterministically, regardless of
    sibling timing. ``cause`` is the first failure in plan order.
    """

    moment = Moment.WORKER

    def __init__(self, msg: str, cause: BaseException | None = None,
                 partial: dict | None = None,
                 executed: tuple = (), cached: tuple = ()):
        super().__init__(msg)
        self.cause = cause
        self.partial = dict(partial or {})
        self.executed = tuple(executed)
        self.cached = tuple(cached)


class QualityError(ContractRuntimeError):
    """A data-quality verifier (expectation) failed on the worker."""
