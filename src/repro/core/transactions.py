"""Transactional pipeline runs (paper §3.3).

The run protocol, verbatim from the paper — for target branch ``B``:

1. automatically create a new transactional branch ``B'`` from ``B``;
2. write the DAG tables into ``B'`` (each write an atomic commit);
3. run data tests / user-defined verifiers on ``B'``;
4. only if no code or data error is raised, merge ``B'`` back into ``B``
   and delete it.

On failure the transactional branch is marked ABORTED and **preserved**
so the faulty intermediate assets can be queried for triage — but the
catalog's visibility rules guarantee it can never be merged (Fig. 4).

Every run is uniquely identified and pinned to the state of the lake
(start commit) and of the code (a content hash), giving the paper's
reproducibility story: ``registry.get_run(run_id)`` returns everything
needed to replay the run (Listing 6).
"""
from __future__ import annotations

import dataclasses
import time
import uuid
from typing import Any, Callable, Mapping, Sequence

from repro.core.catalog import Catalog, Commit, Visibility
from repro.core.errors import TransactionAborted, TransactionError
from repro.core.store import ObjectStore, content_hash

__all__ = ["RunState", "RunRegistry", "TransactionalRun", "run_transaction"]


@dataclasses.dataclass(frozen=True)
class RunState:
    """Immutable record returned by a run (paper Listing 6)."""

    run_id: str
    ref: str                   # start commit id (the data state)
    code_hash: str             # content hash of the DAG code
    target_branch: str
    txn_branch: str
    status: str                # "running" | "committed" | "aborted"
    final_commit: str | None = None
    error: str | None = None
    started_at: float = 0.0
    finished_at: float | None = None


class RunRegistry:
    """run_id -> RunState bookkeeping (in the paper: control-plane DB)."""

    def __init__(self):
        self._runs: dict[str, RunState] = {}

    def record(self, state: RunState) -> None:
        self._runs[state.run_id] = state

    def get_run(self, run_id: str) -> RunState:
        try:
            return self._runs[run_id]
        except KeyError:
            raise TransactionError(f"unknown run_id {run_id!r}") from None

    def runs(self) -> list[RunState]:
        return list(self._runs.values())


class TransactionalRun:
    """Context-managed implementation of the §3.3 protocol.

    Usage::

        with TransactionalRun(catalog, target="main", code=b"...") as txn:
            txn.write_table("parent", snap_p)
            txn.write_table("child", snap_c)
            txn.verify(lambda read: check_quality(read("child")))
        # exit: atomically merged into `main`; on exception: aborted,
        # branch preserved as `txn.branch` with Visibility.ABORTED.
    """

    def __init__(self, catalog: Catalog, target: str, *,
                 code: bytes | str = b"", registry: RunRegistry | None = None,
                 run_id: str | None = None, author: str = "",
                 keep_branch_on_success: bool = False):
        self.catalog = catalog
        self.target = target
        self.registry = registry
        self.author = author
        self.keep_branch_on_success = keep_branch_on_success
        self.run_id = run_id or f"run_{uuid.uuid4().hex[:12]}"
        code_bytes = code.encode() if isinstance(code, str) else code
        self.code_hash = content_hash(code_bytes)[:16]
        self.branch: str | None = None
        self._start_commit: str | None = None
        self._verifiers: list[Callable[[Callable[[str], str]], Any]] = []
        self._status = "created"
        self._started_at = 0.0

    # ------------------------------------------------------------------
    def begin(self) -> "TransactionalRun":
        if self._status != "created":
            raise TransactionError(f"run {self.run_id} already begun")
        self._started_at = time.time()
        head = self.catalog.head(self.target)
        self._start_commit = head.id
        self.branch = f"txn/{self.run_id}"
        # step 1: system-created transactional branch
        self.catalog.create_branch(
            self.branch, self.target, visibility=Visibility.TXN,
            owner_run=self.run_id)
        self._status = "running"
        self._record()
        return self

    # step 2: writes — sandboxed on the transactional branch
    def write_table(self, table: str, snapshot: str, *,
                    message: str = "") -> Commit:
        self._require_running()
        return self.catalog.write_table(
            self.branch, table, snapshot, message=message,
            author=self.author, run_id=self.run_id, _system=True)

    def read_table(self, table: str) -> str:
        """Read within the transaction (sees own writes, snapshot reads)."""
        self._require_running()
        return self.catalog.read_table(self.branch, table)

    # step 3: verifiers — run on B' before publication
    def verify(self, fn: Callable[[Callable[[str], str]], Any]) -> None:
        """Register (and immediately run) a verifier against B'.

        ``fn`` receives a reader ``read(table) -> snapshot`` bound to the
        transactional branch. Any exception aborts the run.
        """
        self._require_running()
        self._verifiers.append(fn)
        try:
            fn(self.read_table)
        except Exception as e:
            self.abort(e)
            raise TransactionAborted(
                f"verifier failed: {e}", branch=self.branch, cause=e) from e

    # step 4: atomic publication
    def commit(self) -> Commit:
        self._require_running()
        try:
            merged = self.catalog.merge(
                self.branch, into=self.target, run_id=self.run_id,
                message=f"txn commit {self.run_id}", _system=True)
        except Exception as e:
            self.abort(e)
            raise TransactionAborted(
                f"publication failed: {e}", branch=self.branch,
                cause=e) from e
        self._status = "committed"
        if not self.keep_branch_on_success:
            self.catalog.delete_branch(self.branch)
        self._record(final_commit=merged.id)
        return merged

    def abort(self, error: BaseException | str | None = None) -> None:
        """Mark the transactional branch ABORTED; keep it for triage."""
        if self._status != "running":
            return
        self._status = "aborted"
        # the branch stays: "reachable by any user for debugging and
        # inspection" — but Visibility.ABORTED means it can never merge.
        self.catalog.mark(self.branch, Visibility.ABORTED)
        self._record(error=str(error) if error else None)

    # ------------------------------------------------------------------
    def __enter__(self) -> "TransactionalRun":
        return self.begin()

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.commit()
            return False
        if not isinstance(exc, TransactionAborted):
            self.abort(exc)
        return False  # propagate

    # ------------------------------------------------------------------
    def _require_running(self) -> None:
        if self._status != "running":
            raise TransactionError(
                f"run {self.run_id} is {self._status}, not running")

    def _record(self, final_commit: str | None = None,
                error: str | None = None) -> None:
        if self.registry is None:
            return
        self.registry.record(RunState(
            run_id=self.run_id, ref=self._start_commit or "",
            code_hash=self.code_hash, target_branch=self.target,
            txn_branch=self.branch or "", status=self._status,
            final_commit=final_commit, error=error,
            started_at=self._started_at,
            finished_at=(time.time()
                         if self._status in ("committed", "aborted")
                         else None)))


def run_transaction(
    catalog: Catalog,
    target: str,
    writes: Mapping[str, str] | Sequence[tuple[str, str]],
    *,
    verifiers: Sequence[Callable[[Callable[[str], str]], Any]] = (),
    code: bytes | str = b"",
    registry: RunRegistry | None = None,
) -> Commit:
    """One-shot functional form of the protocol."""
    items = writes.items() if isinstance(writes, Mapping) else writes
    with TransactionalRun(catalog, target, code=code,
                          registry=registry) as txn:
        for table, snap in items:
            txn.write_table(table, snap)
        for v in verifiers:
            txn.verify(v)
    head = catalog.head(target)
    return head
