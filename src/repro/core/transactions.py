"""Transactional pipeline runs (paper §3.3).

The run protocol, verbatim from the paper — for target branch ``B``:

1. automatically create a new transactional branch ``B'`` from ``B``;
2. write the DAG tables into ``B'`` (one multi-table atomic commit for a
   whole pipeline via :meth:`TransactionalRun.write_tables`);
3. run data tests / user-defined verifiers on ``B'``;
4. only if no code or data error is raised, merge ``B'`` back into ``B``
   and delete it.

**Publication is concurrency-correct** (DESIGN.md §7): ``begin()``
captures the target head and ``commit()`` merges with an optimistic CAS
(``expected_head``). If the target moved, the silent-three-way-merge
hazard — publishing a combined state *no verifier ever saw*, the exact
counterexample the paper's Alloy model warns about around transactional
branch visibility — is closed by **rebase-and-revalidate**: the
transactional branch is rebased onto the new head, **every registered
verifier re-runs against the rebased state**, and the CAS merge is
retried with bounded backoff. After ``max_publish_attempts`` the run
aborts with :class:`PublicationConflict`. The published commit is
therefore always a fast-forward of a branch head that the full verifier
set validated.

On failure the transactional branch is marked ABORTED and **preserved**
so the faulty intermediate assets can be queried for triage — but the
catalog's visibility rules guarantee it can never be merged (Fig. 4).

Every run is uniquely identified and pinned to the state of the lake
(start commit) and of the code (a content hash), giving the paper's
reproducibility story: ``registry.get_run(run_id)`` returns everything
needed to replay the run (Listing 6).
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
import uuid
from typing import Any, Callable, Mapping, Sequence

from repro.core.catalog import Catalog, Commit, Visibility
from repro.core.errors import (PublicationConflict, RefConflict,
                               TransactionAborted, TransactionError)
from repro.core.hooks import fault_point
from repro.core.store import ObjectStore, content_hash
from repro.obs import build_manifest, get_recorder, store_manifest

__all__ = ["RunState", "RunRegistry", "TransactionalRun", "run_transaction"]

_NOOP_CTX = contextlib.nullcontext()


def _verifier_name(fn) -> str:
    return getattr(fn, "__name__", None) or type(fn).__name__


@dataclasses.dataclass(frozen=True)
class RunState:
    """Immutable record returned by a run (paper Listing 6).

    ``ref`` pins the state the run *read from* (the head at ``begin``);
    ``base_commit`` pins the head the run *published onto* — after a
    rebase these differ, and replaying the DAG at ``ref`` reproduces the
    run's outputs while ``final_commit``'s parent is ``base_commit``.
    """

    run_id: str
    ref: str                   # start commit id (pinned read state)
    code_hash: str             # content hash of the DAG code
    target_branch: str
    txn_branch: str
    status: str                # "running" | "committed" | "aborted"
    final_commit: str | None = None
    error: str | None = None
    started_at: float = 0.0
    finished_at: float | None = None
    verified_head: str | None = None   # branch head the verifiers validated
    publish_attempts: int = 0          # CAS attempts commit() needed
    base_commit: str | None = None     # head the run published onto


class RunRegistry:
    """run_id -> RunState bookkeeping (in the paper: control-plane DB)."""

    def __init__(self):
        self._runs: dict[str, RunState] = {}
        self._lock = threading.Lock()

    def record(self, state: RunState) -> None:
        with self._lock:
            self._runs[state.run_id] = state

    def get_run(self, run_id: str) -> RunState:
        with self._lock:
            try:
                return self._runs[run_id]
            except KeyError:
                raise TransactionError(
                    f"unknown run_id {run_id!r}") from None

    def runs(self) -> list[RunState]:
        with self._lock:
            return list(self._runs.values())


class TransactionalRun:
    """Context-managed implementation of the §3.3 protocol.

    Usage::

        with TransactionalRun(catalog, target="main", code=b"...") as txn:
            txn.write_table("parent", snap_p)
            txn.write_table("child", snap_c)
            txn.verify(lambda read: check_quality(read("child")))
        # exit: atomically merged into `main` (rebase-and-revalidate on
        # concurrent movement); on exception: aborted, branch preserved
        # as `txn.branch` with Visibility.ABORTED.
    """

    def __init__(self, catalog: Catalog, target: str, *,
                 code: bytes | str = b"", registry: RunRegistry | None = None,
                 run_id: str | None = None, author: str = "",
                 keep_branch_on_success: bool = False,
                 max_publish_attempts: int = 8,
                 publish_backoff_s: float = 0.001,
                 publish_backoff_cap_s: float = 0.05,
                 publish_retry_budget_s: float | None = None,
                 backoff: str = "decorrelated",
                 backoff_seed: int | str | None = None,
                 clock: Any | None = None):
        self.catalog = catalog
        self.target = target
        self.registry = registry
        self.author = author
        self.keep_branch_on_success = keep_branch_on_success
        self.max_publish_attempts = max_publish_attempts
        self.publish_backoff_s = publish_backoff_s
        self.publish_backoff_cap_s = publish_backoff_cap_s
        self.publish_retry_budget_s = publish_retry_budget_s
        if backoff not in ("decorrelated", "linear"):
            raise ValueError(
                f"backoff must be 'decorrelated' or 'linear', "
                f"got {backoff!r}")
        self.backoff = backoff
        self.run_id = run_id or f"run_{uuid.uuid4().hex[:12]}"
        # Seeded per run: the retry schedule is replayable (chaos tier)
        # yet decorrelated ACROSS runs — contending runs with distinct
        # run_ids never share a jitter sequence, so a thundering herd
        # of conflicting publishers spreads out instead of re-colliding
        # in lockstep the way the old `base * attempt` schedule did.
        self._backoff_rng = random.Random(
            backoff_seed if backoff_seed is not None else self.run_id)
        self._prev_backoff = 0.0
        self.backoff_spent_s = 0.0   # total injected sleep (fake or real)
        # Injectable clock (chaos: FakeClock) — anything with .sleep().
        self._sleep = clock.sleep if clock is not None else time.sleep
        code_bytes = code.encode() if isinstance(code, str) else code
        self.code_hash = content_hash(code_bytes)[:16]
        self.branch: str | None = None
        self.final_commit: Commit | None = None
        self.publish_attempts = 0
        self._start_commit: str | None = None
        self._target_head: str | None = None   # CAS token for publication
        self._verifiers: list[Callable[[Callable[[str], str]], Any]] = []
        self._verifier_heads: list[str | None] = []  # head each fn last saw
        self._executor: Callable[
            [Callable[[str], str], Callable[..., Any]], Any] | None = None
        self._needs_reexecution = False
        self._status = "created"
        self._started_at = 0.0
        # Flight recorder (DESIGN.md §14): the recorder active at
        # begin() owns this run's span tree; the "run" span stays open
        # across the begin()/commit() pair and its finished subtree is
        # anchored to the published commit as an audit manifest.
        self._rec = None
        self._run_span = None

    # ------------------------------------------------------------------
    def begin(self) -> "TransactionalRun":
        if self._status != "created":
            raise TransactionError(f"run {self.run_id} already begun")
        self._started_at = time.time()
        head = self.catalog.head(self.target)
        self._start_commit = head.id
        self._target_head = head.id   # publication CAS expects this head
        self.branch = f"txn/{self.run_id}"
        # step 1: system-created transactional branch
        self.catalog.create_branch(
            self.branch, self.target, visibility=Visibility.TXN,
            owner_run=self.run_id)
        # chaos: dying here abandons a fresh TXN branch (GC's problem)
        fault_point("txn.begin.post_branch", run_id=self.run_id,
                    branch=self.branch)
        self._status = "running"
        rec = get_recorder()
        if rec.enabled:
            self._rec = rec
            self._run_span = rec.start_span(
                "run", run_id=self.run_id, target=self.target,
                txn_branch=self.branch, start_commit=self._start_commit,
                code_hash=self.code_hash)
        self._record()
        return self

    # step 2: writes — sandboxed on the transactional branch
    def write_table(self, table: str, snapshot: str, *,
                    message: str = "") -> Commit:
        self._require_running()
        return self.catalog.write_table(
            self.branch, table, snapshot, message=message,
            author=self.author, run_id=self.run_id, _system=True)

    def write_tables(self, tables: Mapping[str, str], *,
                     message: str = "") -> Commit:
        """Write a whole DAG's outputs as ONE multi-table atomic commit."""
        self._require_running()
        return self.catalog.write_tables(
            self.branch, tables, message=message,
            author=self.author, run_id=self.run_id, _system=True)

    def read_table(self, table: str) -> str:
        """Read within the transaction (sees own writes, snapshot reads)."""
        self._require_running()
        return self.catalog.read_table(self.branch, table)

    # step 3: verifiers — run on B' before publication
    def verify(self, fn: Callable[[Callable[[str], str]], Any]) -> None:
        """Register (and immediately run) a verifier against B'.

        ``fn`` receives a reader ``read(table) -> snapshot`` bound to the
        transactional branch. Any exception aborts the run. The branch
        head the verifier observed is recorded; ``commit()`` re-runs
        every verifier whose observation is stale (writes after
        verification, or a rebase onto a moved target) so that no state
        is ever published unvalidated.
        """
        self._require_running()
        observed = self.catalog.head(self.branch).id
        self._verifiers.append(fn)
        self._verifier_heads.append(None)
        rec = get_recorder()
        try:
            if rec.enabled:
                with rec.span("verifier", fn=_verifier_name(fn),
                              head=observed, phase="initial") as sp:
                    fn(self.read_table)
                    sp.set(outcome="passed")
            else:
                fn(self.read_table)
        except Exception as e:
            self.abort(e)
            raise TransactionAborted(
                f"verifier failed: {e}", branch=self.branch, cause=e) from e
        self._verifier_heads[-1] = observed

    @property
    def verifier_heads(self) -> tuple[str | None, ...]:
        """Branch head each registered verifier last validated."""
        return tuple(self._verifier_heads)

    def set_executor(self, fn: Callable[
            [Callable[[str], str], Callable[..., Any]], Any]) -> None:
        """Register a re-execution hook run after every rebase.

        ``fn(read, write_tables)`` re-derives the run's outputs from the
        *rebased* branch state — with the engine's content-addressed
        cache, only nodes whose input snapshots actually moved execute
        (O(changed subgraph), not O(full DAG)) — and writes back only
        the snapshots that changed. It runs in :meth:`_revalidate`
        BEFORE the verifiers, so the verifier set always validates the
        recomputed state that will be published. Without it, a rebase
        past a concurrent update of a *source* table would publish
        outputs computed from the pre-rebase inputs.
        """
        self._require_running()
        self._executor = fn

    def _revalidate(self) -> str:
        """Re-run the registered executor (if a rebase made inputs
        stale) and then EVERY registered verifier against the current
        branch state; returns the branch head they all validated."""
        rec = get_recorder()
        reval_ctx = (rec.span("revalidate",
                              reexecute=bool(self._executor is not None
                                             and self._needs_reexecution),
                              verifiers=len(self._verifiers))
                     if rec.enabled else _NOOP_CTX)
        with reval_ctx:
            if self._executor is not None and self._needs_reexecution:
                try:
                    if rec.enabled:
                        with rec.span("reexecute"):
                            self._executor(self.read_table,
                                           self.write_tables)
                    else:
                        self._executor(self.read_table, self.write_tables)
                except TransactionAborted:
                    raise
                except Exception as e:
                    self.abort(e)
                    raise TransactionAborted(
                        f"re-execution after rebase failed: {e}",
                        branch=self.branch, cause=e) from e
            self._needs_reexecution = False
            observed = self.catalog.head(self.branch).id
            for fn in self._verifiers:
                try:
                    if rec.enabled:
                        with rec.span("verifier", fn=_verifier_name(fn),
                                      head=observed,
                                      phase="revalidate") as sp:
                            fn(self.read_table)
                            sp.set(outcome="passed")
                    else:
                        fn(self.read_table)
                except Exception as e:
                    self.abort(e)
                    raise TransactionAborted(
                        f"verifier failed on revalidation against "
                        f"{observed[:8]}: {e}",
                        branch=self.branch, cause=e) from e
            self._verifier_heads = [observed] * len(self._verifiers)
            return observed

    def _backoff_delay(self, attempt: int) -> float:
        """Next publication-retry sleep (DESIGN.md §15).

        ``decorrelated`` (default): seeded decorrelated-jitter
        exponential backoff — ``min(cap, U[base, 3·prev])`` — so
        conflicting publishers spread apart instead of re-colliding in
        lockstep; the sequence is replayable from the run's seed.
        ``linear`` keeps the old ``base · attempt`` schedule (the
        contended-publication benchmark's baseline).
        """
        base = self.publish_backoff_s
        if not base:
            return 0.0
        if self.backoff == "linear":
            return base * attempt
        prev = self._prev_backoff if self._prev_backoff else base
        delay = min(self.publish_backoff_cap_s,
                    self._backoff_rng.uniform(base, prev * 3.0))
        self._prev_backoff = delay
        return delay

    # step 4: atomic publication — CAS + rebase-and-revalidate
    def commit(self) -> Commit:
        self._require_running()
        rec = self._rec if self._rec is not None else get_recorder()
        attempt = 0
        while True:
            attempt += 1
            self.publish_attempts = attempt
            att_ctx = (rec.span("publication_attempt", attempt=attempt,
                                expected_head=self._target_head)
                       if rec.enabled else _NOOP_CTX)
            with att_ctx as att_span:
                # Never publish state the full verifier set did not
                # validate: if any verifier's observation is stale (a
                # write or a rebase happened after it ran), or a rebase
                # left the run's outputs possibly computed from moved
                # inputs, re-derive and re-run them all first.
                branch_head = self.catalog.head(self.branch).id
                if self._needs_reexecution or (
                        self._verifiers and any(
                            h != branch_head
                            for h in self._verifier_heads)):
                    branch_head = self._revalidate()
                # chaos: the CAS boundary — a delay here preempts this
                # publisher between verification and merge; a crash
                # abandons a fully-verified, unpublished TXN branch.
                fault_point("txn.commit.pre_merge", run_id=self.run_id,
                            attempt=attempt,
                            expected_head=self._target_head)
                try:
                    merged = self.catalog.merge(
                        self.branch, into=self.target, run_id=self.run_id,
                        message=f"txn commit {self.run_id}",
                        expected_head=self._target_head, _system=True)
                    # chaos: published but not yet acknowledged — a
                    # crash here is the lost-ack window: the commit is
                    # on the target, the TXN branch is orphaned, the
                    # registry still says "running". Recovery = GC.
                    fault_point("txn.commit.post_merge",
                                run_id=self.run_id, commit=merged.id)
                    if att_span is not None:
                        att_span.set(outcome="published",
                                     commit=merged.id)
                    break
                except RefConflict as e:
                    if rec.enabled:
                        actual = self.catalog.head(self.target).id
                        rec.event("ref_conflict", attempt=attempt,
                                  expected_head=self._target_head,
                                  actual_head=actual, target=self.target)
                        rec.metrics.counter(
                            "txn.publication.conflicts").inc()
                        if att_span is not None:
                            att_span.set(outcome="conflict")
                    if attempt >= self.max_publish_attempts:
                        self.abort(e)
                        raise PublicationConflict(
                            f"run {self.run_id}: target {self.target!r} "
                            f"kept moving; gave up after {attempt} "
                            f"publication attempts",
                            branch=self.branch, cause=e) from e
                    delay = self._backoff_delay(attempt)
                    if (self.publish_retry_budget_s is not None
                            and self.backoff_spent_s + delay
                            > self.publish_retry_budget_s):
                        self.abort(e)
                        raise PublicationConflict(
                            f"run {self.run_id}: publication retry "
                            f"budget "
                            f"({self.publish_retry_budget_s:g}s) "
                            f"exhausted after {attempt} attempts",
                            branch=self.branch, cause=e) from e
                    if delay:
                        self.backoff_spent_s += delay
                        if rec.enabled:
                            rec.event("backoff", attempt=attempt,
                                      delay_s=round(delay, 6),
                                      kind=self.backoff)
                        self._sleep(delay)
                    # Rebase onto the head we just observed — an
                    # immutable commit id, so the subsequent CAS
                    # publishes exactly the (re-verified) rebased state
                    # or conflicts again.
                    fault_point("txn.commit.pre_rebase",
                                run_id=self.run_id, attempt=attempt)
                    try:
                        new_head = self.catalog.head(self.target).id
                        if rec.enabled:
                            with rec.span("rebase",
                                          from_head=self._target_head,
                                          onto=new_head):
                                self.catalog.rebase(
                                    self.branch, new_head,
                                    run_id=self.run_id, _system=True)
                            rec.metrics.counter("txn.rebases").inc()
                        else:
                            self.catalog.rebase(
                                self.branch, new_head,
                                run_id=self.run_id, _system=True)
                        self._target_head = new_head
                        # the rebase may have moved this run's INPUT
                        # tables: the executor must re-derive before
                        # revalidation.
                        self._needs_reexecution = True
                    except Exception as e2:
                        self.abort(e2)
                        raise TransactionAborted(
                            f"publication failed: {e2}",
                            branch=self.branch, cause=e2) from e2
                    fault_point("txn.commit.post_rebase",
                                run_id=self.run_id, attempt=attempt,
                                onto=self._target_head)
                except Exception as e:
                    self.abort(e)
                    raise TransactionAborted(
                        f"publication failed: {e}", branch=self.branch,
                        cause=e) from e
        self._status = "committed"
        self.final_commit = merged
        if not self.keep_branch_on_success:
            self.catalog.delete_branch(self.branch, _system=True)
        else:
            # the branch's state is now published: release it to users
            self.catalog.mark(self.branch, Visibility.USER, _system=True)
        self._record(final_commit=merged.id)
        self._finish_trace(merged)
        return merged

    def abort(self, error: BaseException | str | None = None) -> None:
        """Mark the transactional branch ABORTED; keep it for triage."""
        if self._status != "running":
            return
        self._status = "aborted"
        # the branch stays: "reachable by any user for debugging and
        # inspection" — but Visibility.ABORTED means it can never merge.
        self.catalog.mark(self.branch, Visibility.ABORTED, _system=True)
        self._record(error=str(error) if error else None)
        # Close the run span (aborted runs leave NO manifest: the
        # anchoring rule keys manifests by published commit id, and an
        # aborted run published nothing — the trace stays inspectable
        # on the recorder itself).
        if self._run_span is not None:
            self._run_span.set(status="aborted",
                               publish_attempts=self.publish_attempts,
                               error=str(error) if error else None)
            self._rec.end_span(self._run_span)
            self._run_span = None

    def _finish_trace(self, merged: Commit) -> None:
        """Seal the run span and anchor its subtree to ``merged``.

        The manifest is written to the catalog's own object store and
        named ``runmanifest/<commit_id>`` (see ``repro.obs.manifest``),
        so ``Catalog.run_manifest(commit_id)`` can audit any published
        state post-hoc. Purely observational: written AFTER the merge
        ref moved, never read by commit resolution or cache keys.
        """
        if self._run_span is None:
            return
        rec, span = self._rec, self._run_span
        self._run_span = None
        span.set(status="committed", commit=merged.id,
                 publish_attempts=self.publish_attempts)
        rec.end_span(span)
        subtree = getattr(rec, "subtree", None)
        if subtree is None:     # custom recorder without introspection
            return
        doc = build_manifest(
            span, subtree(span), commit_id=merged.id, run_id=self.run_id,
            metrics=rec.metrics.snapshot(),
            orphan_events=rec.orphan_events())
        try:
            store_manifest(self.catalog.store, merged.id, doc)
        except Exception:
            # observational means observational: the commit is already
            # published, and a failed audit write must not turn a
            # successful run into a dead one. The commit simply reads
            # back as untraced (run_manifest -> None).
            rec.event("manifest_write_failed", commit=merged.id,
                      run_id=self.run_id)

    # ------------------------------------------------------------------
    def __enter__(self) -> "TransactionalRun":
        return self.begin()

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.commit()
            return False
        # Only ordinary Exceptions abort (mark the branch for triage).
        # BaseExceptions — InjectedCrash, KeyboardInterrupt, SystemExit
        # — model process death: a dead process runs no cleanup, and
        # the dangling TXN branch is exactly what Catalog.gc collects.
        if not isinstance(exc, TransactionAborted) \
                and isinstance(exc, Exception):
            self.abort(exc)
        return False  # propagate

    # ------------------------------------------------------------------
    def _require_running(self) -> None:
        if self._status != "running":
            raise TransactionError(
                f"run {self.run_id} is {self._status}, not running")

    def _record(self, final_commit: str | None = None,
                error: str | None = None) -> None:
        if self.registry is None:
            return
        heads = {h for h in self._verifier_heads if h is not None}
        self.registry.record(RunState(
            run_id=self.run_id, ref=self._start_commit or "",
            code_hash=self.code_hash, target_branch=self.target,
            txn_branch=self.branch or "", status=self._status,
            final_commit=final_commit, error=error,
            started_at=self._started_at,
            finished_at=(time.time()
                         if self._status in ("committed", "aborted")
                         else None),
            verified_head=(heads.pop() if len(heads) == 1 else None),
            publish_attempts=self.publish_attempts,
            base_commit=self._target_head))


def run_transaction(
    catalog: Catalog,
    target: str,
    writes: Mapping[str, str] | Sequence[tuple[str, str]],
    *,
    verifiers: Sequence[Callable[[Callable[[str], str]], Any]] = (),
    code: bytes | str = b"",
    registry: RunRegistry | None = None,
) -> Commit:
    """One-shot functional form of the protocol.

    Returns the actual merged :class:`Commit` from ``txn.commit()`` —
    NOT ``catalog.head(target)`` after the fact, which may already
    reflect a later concurrent run.
    """
    items = writes.items() if isinstance(writes, Mapping) else writes
    with TransactionalRun(catalog, target, code=code,
                          registry=registry) as txn:
        txn.write_tables(dict(items), message=f"txn {txn.run_id}")
        for v in verifiers:
            txn.verify(v)
    assert txn.final_commit is not None
    return txn.final_commit
