"""Git-for-data catalog (paper §3.2 and §4).

Implements the Alloy model's signatures executably:

- a **Commit** is an immutable mapping ``{table -> snapshot}`` plus a
  parent set (merge commits have two parents) — "an immutable, unique
  reference to the state of all table snapshots at that moment";
- a **Branch** is a movable reference to the HEAD of a commit chain;
- a **Tag** is an immutable reference;
- ``create_table``/``write_table`` is the only state-changing operation:
  it allocates a fresh commit and advances the branch head (Listing 8);
- **merge** applies changes atomically from source to destination
  (three-way over the merge base, fast-forward when possible).

Branch heads move via optimistic compare-and-swap (the paper's substrate
guarantees this via a relational database; here a lock + expected-head
check), so concurrent writers conflict instead of silently interleaving.
Every head-moving operation (``write_table``/``write_tables``, ``merge``,
``rebase``) accepts ``expected_head``; a whole pipeline's outputs can be
committed as **one** multi-table atomic commit via :meth:`write_tables`,
and :meth:`rebase` replays a branch's table changes onto a new base so a
transactional run can re-verify exactly the state it is about to publish
(the rebase-and-revalidate protocol, DESIGN.md §7).

**Visibility classes** (the Fig. 4 guardrail — see DESIGN.md §6): branches
carry a :class:`Visibility`; transactional branches are system-owned;
*aborted* branches are readable but not mergeable, and branching off one
requires ``allow_reuse=True`` and yields a ``QUARANTINED`` branch that can
only be merged after explicit re-verification. This makes the Alloy
counterexample unrepresentable while preserving the paper's idempotent
re-run optimization.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import itertools
import threading
import time
from typing import Any, Callable, Iterator, Mapping, Sequence

from repro.core.errors import (
    BranchExists,
    BranchNotFound,
    CatalogError,
    MergeConflict,
    RefConflict,
    VisibilityError,
)
from repro.core.store import MemoryStore, ObjectStore

__all__ = ["Visibility", "Commit", "BranchInfo", "GCReport", "Catalog"]


class Visibility(enum.Enum):
    USER = "user"                # normal branch: read/write/merge
    TXN = "txn"                  # live transactional branch (system-owned)
    ABORTED = "aborted"          # failed txn branch: read-only, not mergeable
    QUARANTINED = "quarantined"  # reuse of an aborted branch: merge gated
    TAG = "tag"                  # immutable


@dataclasses.dataclass(frozen=True)
class Commit:
    """Immutable lake state: {table -> snapshot id} + parent commit ids."""

    id: str
    tables: Mapping[str, str]
    parents: tuple[str, ...]
    message: str = ""
    author: str = ""
    run_id: str | None = None
    timestamp: float = 0.0

    def snapshot_of(self, table: str) -> str | None:
        return self.tables.get(table)


@dataclasses.dataclass
class BranchInfo:
    name: str
    head: str
    visibility: Visibility = Visibility.USER
    owner_run: str | None = None   # for TXN branches: the owning run id
    verified: bool = False         # for QUARANTINED: re-verification flag
    updated_at: float = 0.0        # last head move / visibility change


@dataclasses.dataclass(frozen=True)
class GCReport:
    """What one :meth:`Catalog.gc` pass did (DESIGN.md §15).

    ``collected``/``kept`` list GC *candidates* (TXN and ABORTED
    branches) as ``(branch, reason)`` pairs; branches that are not
    candidates (USER, QUARANTINED, tags) appear in neither. Commits are
    never deleted — GC removes branch refs and observational
    ``runmanifest/`` store refs only, so a pinned commit's ancestry is
    intact by construction.
    """

    collected: tuple[tuple[str, str], ...] = ()
    kept: tuple[tuple[str, str], ...] = ()
    swept_manifests: tuple[str, ...] = ()   # commit ids unanchored
    swept_tmp: int = 0                      # leaked store temp files


def _commit_id(tables: Mapping[str, str], parents: tuple[str, ...],
               message: str, salt: str) -> str:
    h = hashlib.sha256()
    for t in sorted(tables):
        h.update(f"{t}={tables[t]};".encode())
    h.update(("|".join(parents) + "|" + message + "|" + salt).encode())
    return h.hexdigest()[:24]


class Catalog:
    """The versioning control plane. All public methods are atomic."""

    def __init__(self, store: ObjectStore | None = None,
                 main: str = "main"):
        self.store = store if store is not None else MemoryStore()
        self._lock = threading.RLock()
        self._commits: dict[str, Commit] = {}
        self._branches: dict[str, BranchInfo] = {}
        self._tags: dict[str, str] = {}
        self._counter = itertools.count()
        # The system starts with a single branch Main and a root commit
        # (Init) — paper Listing 7.
        root = Commit(id=_commit_id({}, (), "init", "0"), tables={},
                      parents=(), message="init", timestamp=time.time())
        self._commits[root.id] = root
        self._branches[main] = BranchInfo(name=main, head=root.id,
                                          updated_at=time.time())
        self.main = main
        self._pins: dict[str, int] = {}   # commit id -> pin count

    # ------------------------------------------------------------------
    # refs
    # ------------------------------------------------------------------
    def branch_info(self, name: str) -> BranchInfo:
        with self._lock:
            try:
                return dataclasses.replace(self._branches[name])
            except KeyError:
                raise BranchNotFound(f"branch {name!r} does not exist") \
                    from None

    def head(self, ref: str) -> Commit:
        """Resolve a ref (branch, tag, or commit id) to its Commit."""
        with self._lock:
            if ref in self._branches:
                return self._commits[self._branches[ref].head]
            if ref in self._tags:
                return self._commits[self._tags[ref]]
            if ref in self._commits:
                return self._commits[ref]
            raise BranchNotFound(f"unknown ref {ref!r}")

    def branches(self) -> list[str]:
        with self._lock:
            return sorted(self._branches)

    def run_manifest(self, ref: str) -> dict | None:
        """The audit manifest anchored to a published commit, or None.

        DESIGN.md §14: a traced :class:`~repro.core.transactions.
        TransactionalRun` stores its finished span tree in this
        catalog's object store under ``runmanifest/<commit_id>`` at
        publication. ``ref`` may be any resolvable ref (branch, tag, or
        commit id); ``None`` means the commit exists but the run that
        produced it was not traced — a normal state, since tracing is
        opt-in and manifests are observational, never load-bearing.
        """
        from repro.obs import load_manifest
        return load_manifest(self.store, self.head(ref).id)

    def commit(self, cid: str) -> Commit:
        with self._lock:
            try:
                return self._commits[cid]
            except KeyError:
                raise CatalogError(f"unknown commit {cid!r}") from None

    # ------------------------------------------------------------------
    # branch lifecycle
    # ------------------------------------------------------------------
    def create_branch(self, name: str, from_ref: str, *,
                      visibility: Visibility = Visibility.USER,
                      owner_run: str | None = None,
                      allow_reuse: bool = False) -> BranchInfo:
        """Zero-copy branch: only a new movable ref is created (paper §3.2).

        Branching off an ABORTED branch is refused unless
        ``allow_reuse=True``, in which case the new branch is QUARANTINED
        (the Fig. 4 guardrail).
        """
        with self._lock:
            if name in self._branches or name in self._tags:
                raise BranchExists(f"ref {name!r} already exists")
            src_vis = (self._branches[from_ref].visibility
                       if from_ref in self._branches else Visibility.USER)
            vis = visibility
            # ABORTED: the paper's Fig. 4 counterexample. TXN: a SECOND
            # counterexample our hypothesis search found (test_model_check):
            # branching from a LIVE transactional branch and merging
            # launders the uncommitted state of a still-running run into
            # main. Both require allow_reuse and yield QUARANTINED.
            if src_vis in (Visibility.ABORTED, Visibility.QUARANTINED,
                           Visibility.TXN) and vis is not Visibility.TXN:
                if not allow_reuse:
                    raise VisibilityError(
                        f"cannot branch from {src_vis.value} branch "
                        f"{from_ref!r} without allow_reuse=True "
                        f"(see DESIGN.md §6 / paper Fig. 4)")
                vis = Visibility.QUARANTINED
            head = self.head(from_ref)
            info = BranchInfo(name=name, head=head.id, visibility=vis,
                              owner_run=owner_run,
                              updated_at=time.time())
            self._branches[name] = info
            return dataclasses.replace(info)

    def delete_branch(self, name: str, *, _system: bool = False) -> None:
        """Delete a branch ref.

        Live transactional branches belong to their run, and aborted
        branches are preserved for triage (§3.3) — deleting either
        requires the owning system (``_system=True``).
        """
        with self._lock:
            if name == self.main:
                raise CatalogError("cannot delete the main branch")
            info = self._branches.get(name)
            if info is None:
                raise BranchNotFound(name)
            if not _system and info.visibility is Visibility.TXN:
                raise VisibilityError(
                    f"branch {name!r} is a live transactional branch owned "
                    f"by run {info.owner_run!r}: deleting it mid-run would "
                    f"strand the run")
            if not _system and info.visibility is Visibility.ABORTED:
                raise VisibilityError(
                    f"branch {name!r} is aborted and preserved for triage "
                    f"(§3.3); deletion requires the owning system")
            del self._branches[name]

    def tag(self, name: str, ref: str) -> str:
        with self._lock:
            if name in self._tags or name in self._branches:
                raise BranchExists(f"ref {name!r} already exists")
            cid = self.head(ref).id
            self._tags[name] = cid
            return cid

    def mark(self, name: str, visibility: Visibility, *,
             verified: bool | None = None, _system: bool = False) -> None:
        """Change a branch's visibility class.

        Two transitions are privileged (``_system=True``): any change to a
        live TXN branch (it is owned by its run), and un-marking an
        ABORTED branch (flipping it back to USER would let the Fig. 4
        laundering through the front door). The one user-facing
        transition is re-verifying a QUARANTINED branch
        (``verified=True``) — the sanctioned reuse path of DESIGN.md §6.
        """
        with self._lock:
            info = self._branches.get(name)
            if info is None:
                raise BranchNotFound(name)
            if not _system:
                if info.visibility is Visibility.TXN:
                    raise VisibilityError(
                        f"branch {name!r} is a live transactional branch "
                        f"owned by run {info.owner_run!r}: only the owning "
                        f"system may change its visibility")
                if (info.visibility is Visibility.ABORTED
                        and visibility is not Visibility.ABORTED):
                    raise VisibilityError(
                        f"branch {name!r} is aborted: un-marking it would "
                        f"republish a partial run (paper Fig. 4); use "
                        f"allow_reuse branching + re-verification instead")
                if (info.visibility is Visibility.QUARANTINED
                        and visibility is not Visibility.QUARANTINED
                        and not info.verified and not verified):
                    raise VisibilityError(
                        f"branch {name!r} is quarantined and unverified: "
                        f"re-verify first (mark(..., verified=True)) — "
                        f"releasing it to {visibility.value} would skip "
                        f"the merge gate")
            info.visibility = visibility
            if verified is not None:
                info.verified = verified
            info.updated_at = time.time()

    # ------------------------------------------------------------------
    # the only state-changing write (paper Listing 8)
    # ------------------------------------------------------------------
    def _writable_info(self, branch: str, expected_head: str | None,
                       _system: bool) -> BranchInfo:
        """Shared write guards: existence, visibility, optimistic CAS."""
        info = self._branches.get(branch)
        if info is None:
            raise BranchNotFound(branch)
        if info.visibility in (Visibility.ABORTED, Visibility.TAG):
            raise VisibilityError(
                f"branch {branch!r} is {info.visibility.value}: "
                f"read-only")
        if info.visibility is Visibility.TXN and not _system:
            raise VisibilityError(
                f"branch {branch!r} is a live transactional branch "
                f"owned by run {info.owner_run!r}")
        if expected_head is not None and info.head != expected_head:
            raise RefConflict(
                f"branch {branch!r} moved: expected {expected_head[:8]} "
                f"found {info.head[:8]}")
        return info

    def write_table(self, branch: str, table: str, snapshot: str, *,
                    message: str = "", author: str = "",
                    run_id: str | None = None,
                    expected_head: str | None = None,
                    _system: bool = False) -> Commit:
        """Commit a new snapshot of ``table`` and advance the branch head.

        Atomic w.r.t. concurrent writers: if ``expected_head`` is given and
        the branch has moved, raises :class:`RefConflict` (optimistic CAS —
        the paper's "optimistic locks guaranteed by a relational database").
        """
        return self.write_tables(
            branch, {table: snapshot}, message=message or f"write {table}",
            author=author, run_id=run_id, expected_head=expected_head,
            _system=_system)

    def write_tables(self, branch: str, tables: Mapping[str, str], *,
                     message: str = "", author: str = "",
                     run_id: str | None = None,
                     expected_head: str | None = None,
                     _system: bool = False) -> Commit:
        """Commit N table snapshots as ONE atomic commit.

        This is how a whole pipeline run publishes: all of the DAG's
        outputs land in a single commit, so ``log()`` reflects *runs*,
        not nodes, and readers can never observe a prefix of a run.
        An empty mapping is a no-op returning the current head.
        """
        with self._lock:
            info = self._writable_info(branch, expected_head, _system)
            parent = self._commits[info.head]
            if not tables:
                return parent
            merged = dict(parent.tables)
            merged.update(tables)
            cid = _commit_id(merged, (parent.id,), message,
                             str(next(self._counter)))
            commit = Commit(id=cid, tables=merged, parents=(parent.id,),
                            message=message or f"write {sorted(tables)}",
                            author=author, run_id=run_id,
                            timestamp=time.time())
            self._commits[cid] = commit
            info.head = cid
            info.updated_at = commit.timestamp
            return commit

    # ------------------------------------------------------------------
    # reads
    # ------------------------------------------------------------------
    def read_table(self, ref: str, table: str) -> str:
        snap = self.head(ref).snapshot_of(table)
        if snap is None:
            raise CatalogError(f"table {table!r} not found at ref {ref!r}")
        return snap

    def read_tables(self, ref: str, tables: Sequence[str]
                    ) -> dict[str, str]:
        """Resolve several tables against ONE commit (a consistent
        multi-table snapshot read under a single lock acquisition) —
        how the engine pins a run's source set before scheduling waves.
        """
        with self._lock:
            head = self.head(ref)
        out: dict[str, str] = {}
        for t in tables:
            snap = head.snapshot_of(t)
            if snap is None:
                raise CatalogError(
                    f"table {t!r} not found at ref {ref!r}")
            out[t] = snap
        return out

    def tables(self, ref: str) -> Mapping[str, str]:
        return dict(self.head(ref).tables)

    def log(self, ref: str, limit: int = 50) -> list[Commit]:
        with self._lock:
            out, cur = [], self.head(ref)
            while cur is not None and len(out) < limit:
                out.append(cur)
                cur = (self._commits[cur.parents[0]] if cur.parents
                       else None)
            return out

    # ------------------------------------------------------------------
    # merge (paper §3.2/§3.3: logical, atomic)
    # ------------------------------------------------------------------
    def _ancestors(self, cid: str) -> set[str]:
        seen, stack = set(), [cid]
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            seen.add(c)
            stack.extend(self._commits[c].parents)
        return seen

    def merge_base(self, a: str, b: str) -> Commit:
        with self._lock:
            anc_a = self._ancestors(self.head(a).id)
            cur = [self.head(b).id]
            seen = set()
            while cur:
                nxt = []
                for cid in cur:
                    if cid in seen:
                        continue
                    seen.add(cid)
                    if cid in anc_a:
                        return self._commits[cid]
                    nxt.extend(self._commits[cid].parents)
                cur = nxt
        raise CatalogError(f"no common ancestor of {a!r} and {b!r}")

    def rebase(self, branch: str, onto: str, *,
               run_id: str | None = None,
               _system: bool = False) -> Commit:
        """Replay ``branch``'s table changes since the merge base onto
        ``onto``'s head, as ONE new commit; move the branch head to it.

        ``onto`` may be (and, for race-free publication, should be) a raw
        commit id — an immutable base, so the caller knows exactly which
        head the rebased state extends and can CAS its merge against it.
        Raises :class:`MergeConflict` when a table changed on both sides
        since the base. A branch with no changes fast-forwards.
        """
        with self._lock:
            info = self._writable_info(branch, None, _system)
            br_head = self._commits[info.head]
            onto_head = self.head(onto)
            base = self.merge_base(onto, branch)
            if br_head.id == onto_head.id or onto_head.id == base.id:
                return br_head            # already based on onto
            if br_head.id == base.id:
                info.head = onto_head.id  # no local changes: fast-forward
                info.updated_at = time.time()
                return onto_head
            changed_br = {t for t in set(br_head.tables) | set(base.tables)
                          if br_head.tables.get(t) != base.tables.get(t)}
            changed_onto = {
                t for t in set(onto_head.tables) | set(base.tables)
                if onto_head.tables.get(t) != base.tables.get(t)}
            conflicts = {
                t for t in changed_br & changed_onto
                if br_head.tables.get(t) != onto_head.tables.get(t)}
            if conflicts:
                raise MergeConflict(
                    f"cannot rebase {branch!r} onto {onto!r}: tables "
                    f"changed on both sides since base: {sorted(conflicts)}")
            tables = dict(onto_head.tables)
            for t in changed_br:
                if t in br_head.tables:
                    tables[t] = br_head.tables[t]
                else:
                    tables.pop(t, None)
            cid = _commit_id(tables, (onto_head.id,), br_head.message,
                             str(next(self._counter)))
            commit = Commit(
                id=cid, tables=tables, parents=(onto_head.id,),
                message=br_head.message or f"rebase {branch}",
                author=br_head.author, run_id=run_id or br_head.run_id,
                timestamp=time.time())
            self._commits[cid] = commit
            info.head = cid
            info.updated_at = commit.timestamp
            return commit

    def _is_published(self, cid: str) -> bool:
        """Is ``cid`` reachable from a mergeable (USER / verified-
        QUARANTINED) branch head?

        Only published commits may be merged by raw commit id or tag:
        anything else — an ABORTED/TXN-only commit, or one whose owning
        branch was deleted and survives only behind a tag — would
        launder unverified state past the visibility rules. One early-
        exiting walk over the union of good histories (no full-closure
        materialization under the lock).
        """
        seen: set[str] = set()
        stack = [info.head for info in self._branches.values()
                 if info.visibility is Visibility.USER
                 or (info.visibility is Visibility.QUARANTINED
                     and info.verified)]
        while stack:
            c = stack.pop()
            if c in seen:
                continue
            if c == cid:
                return True
            seen.add(c)
            stack.extend(self._commits[c].parents)
        return False

    def merge(self, source: str, into: str, *,
              message: str = "", run_id: str | None = None,
              expected_head: str | None = None,
              _system: bool = False) -> Commit:
        """Atomically apply changes from ``source`` to ``into``.

        Fast-forward when ``into`` has not moved since the merge base,
        else a three-way merge creating a two-parent commit; conflicting
        table updates (both sides changed the same table since base)
        raise :class:`MergeConflict`. Merging is purely logical — no
        snapshot (physical data) is copied.
        """
        with self._lock:
            src_info = self._branches.get(source)
            if src_info is None and not _system:
                # source is a raw commit id or a tag: the branch-level
                # visibility checks below cannot see it, so resolve the
                # commit's provenance instead (closes the laundering
                # hole where merging an ABORTED head by its commit id
                # republished a partial run).
                src_cid = self.head(source).id
                if not self._is_published(src_cid):
                    raise VisibilityError(
                        f"ref {source!r} resolves to commit "
                        f"{src_cid[:8]}, which is not reachable from "
                        f"any publishable branch: merging it would "
                        f"republish a partial, unverified run "
                        f"(paper Fig. 4)")
            if src_info is not None:
                if src_info.visibility is Visibility.ABORTED:
                    raise VisibilityError(
                        f"branch {source!r} was aborted by run "
                        f"{src_info.owner_run!r}: merging an aborted "
                        f"transactional branch would republish a partial "
                        f"run (paper Fig. 4)")
                if (src_info.visibility is Visibility.QUARANTINED
                        and not src_info.verified):
                    raise VisibilityError(
                        f"branch {source!r} is quarantined (built on an "
                        f"aborted run) and has not been re-verified")
                if src_info.visibility is Visibility.TXN and not _system:
                    raise VisibilityError(
                        f"branch {source!r} is a live transactional branch")
            dst_info = self._branches.get(into)
            if dst_info is None:
                raise BranchNotFound(into)
            if dst_info.visibility in (Visibility.ABORTED, Visibility.TAG):
                raise VisibilityError(f"branch {into!r} is read-only")
            if expected_head is not None and dst_info.head != expected_head:
                raise RefConflict(
                    f"branch {into!r} moved: expected {expected_head[:8]}")

            src_head = self.head(source)
            dst_head = self.head(into)
            base = self.merge_base(source, into)

            if src_head.id == base.id:
                return dst_head  # nothing to merge
            if dst_head.id == base.id:
                # fast-forward: move the ref (zero-copy)
                dst_info.head = src_head.id
                dst_info.updated_at = time.time()
                return src_head

            # three-way: detect table-level conflicts
            changed_src = {t for t in set(src_head.tables) | set(base.tables)
                           if src_head.tables.get(t) != base.tables.get(t)}
            changed_dst = {t for t in set(dst_head.tables) | set(base.tables)
                           if dst_head.tables.get(t) != base.tables.get(t)}
            conflicts = {
                t for t in changed_src & changed_dst
                if src_head.tables.get(t) != dst_head.tables.get(t)}
            if conflicts:
                raise MergeConflict(
                    f"tables changed on both branches since base: "
                    f"{sorted(conflicts)}")
            tables = dict(dst_head.tables)
            for t in changed_src:
                if t in src_head.tables:
                    tables[t] = src_head.tables[t]
                else:
                    tables.pop(t, None)
            cid = _commit_id(tables, (dst_head.id, src_head.id),
                             message, str(next(self._counter)))
            commit = Commit(
                id=cid, tables=tables, parents=(dst_head.id, src_head.id),
                message=message or f"merge {source} into {into}",
                run_id=run_id, timestamp=time.time())
            self._commits[cid] = commit
            dst_info.head = cid
            dst_info.updated_at = commit.timestamp
            return commit

    # ------------------------------------------------------------------
    # pinned readers (serve_pinned_commit + GC protection, DESIGN.md §15)
    # ------------------------------------------------------------------
    def pin(self, ref: str) -> str:
        """Pin the commit ``ref`` resolves to; returns its id.

        A pinned commit marks an active reader (a serving session, a
        triage investigation): GC keeps any candidate branch whose head
        is pinned and never unanchors the pinned commit's manifest.
        Commits themselves are immortal metadata — pinning guards the
        *refs* that make them discoverable. Refcounted: pin twice,
        unpin twice.
        """
        with self._lock:
            cid = self.head(ref).id
            self._pins[cid] = self._pins.get(cid, 0) + 1
            return cid

    def unpin(self, commit_id: str) -> None:
        with self._lock:
            n = self._pins.get(commit_id, 0)
            if n <= 1:
                self._pins.pop(commit_id, None)
            else:
                self._pins[commit_id] = n - 1

    def pinned(self) -> frozenset[str]:
        with self._lock:
            return frozenset(self._pins)

    # ------------------------------------------------------------------
    # quarantine release (DESIGN.md §6/§15: QUARANTINED -> re-verified
    # -> mergeable)
    # ------------------------------------------------------------------
    def release_quarantined(
            self, name: str,
            verifier: Callable[[Callable[[str], str]], Any]) -> Commit:
        """Re-verify a QUARANTINED branch and release it to USER.

        The sanctioned exit from quarantine: ``verifier(read)`` runs
        against the branch head captured at entry — ``read(table)``
        resolves snapshots at that *immutable commit*, not the live
        head — and the release CASes on the same head. If the branch
        moved during verification (the concurrent-reuse race on the
        Fig. 4 counterexample), :class:`RefConflict` is raised and the
        branch stays quarantined: no state is ever released that the
        verifier did not see. A verifier exception propagates and
        leaves the branch quarantined.
        """
        with self._lock:
            info = self._branches.get(name)
            if info is None:
                raise BranchNotFound(name)
            if info.visibility is not Visibility.QUARANTINED:
                raise VisibilityError(
                    f"branch {name!r} is {info.visibility.value}, not "
                    f"quarantined: nothing to release")
            head = self._commits[info.head]

        def read(table: str) -> str:
            snap = head.snapshot_of(table)
            if snap is None:
                raise CatalogError(
                    f"table {table!r} not found at quarantined head "
                    f"{head.id[:8]}")
            return snap

        verifier(read)   # outside the lock: may read data, take time

        with self._lock:
            info = self._branches.get(name)
            if info is None:
                raise BranchNotFound(
                    f"branch {name!r} was deleted during re-verification")
            if info.head != head.id:
                raise RefConflict(
                    f"branch {name!r} moved during re-verification: "
                    f"verified {head.id[:8]}, head is now "
                    f"{info.head[:8]} — re-verify the new state")
            info.verified = True
            info.visibility = Visibility.USER
            info.updated_at = time.time()
            return head

    # ------------------------------------------------------------------
    # branch garbage collection (DESIGN.md §15)
    # ------------------------------------------------------------------
    def gc(self, *, live_runs: Sequence[str] | frozenset[str] = (),
           grace_s: float = 0.0, now: float | None = None,
           sweep_manifests: bool = True, sweep_store_tmp: bool = True,
           dry_run: bool = False) -> GCReport:
        """Collect dead transactional debris so the catalog survives
        unbounded agent churn.

        Candidates and liveness rules (each kept branch carries its
        reason in the report):

        - **TXN** branches: kept while ``owner_run`` is in
          ``live_runs`` (the run still owns it — collecting it would
          strand a live publication) or younger than ``grace_s``
          (a run that exists but has not registered yet, or liveness
          information lagging the catalog). Otherwise the owner is
          dead — crashed or abandoned — and the branch is collected.
        - **ABORTED** branches: preserved for triage (§3.3), but not
          forever — collected after ``grace_s`` unless their head is
          pinned (a reader is actively triaging).
        - **QUARANTINED** branches: never collected. Unverified ones
          are awaiting re-verification (collecting would break the
          sanctioned reuse path); verified ones are user-domain.
        - **USER** branches and tags: never candidates.

        Commits are never deleted, so a pinned commit's ancestry — and
        every published commit — survives any GC schedule by
        construction. The ``runmanifest/`` sweep removes the
        observational audit-manifest refs of commits no longer
        reachable from any surviving branch, tag, or pin (safe by
        construction: nothing load-bearing reads manifests), and
        ``sweep_store_tmp`` collects temp files leaked by crashed
        :class:`~repro.core.store.FileStore` writes.
        """
        t = time.time() if now is None else now
        collected: list[tuple[str, str]] = []
        kept: list[tuple[str, str]] = []
        with self._lock:
            # Snapshot liveness AFTER taking the lock: a run registers
            # itself live BEFORE its begin() creates the TXN branch
            # (which needs this lock), so every branch visible in the
            # scan below has an owner that had already registered when
            # this snapshot was taken — passing a live view (the swarm
            # janitor does) can never observe branch-without-owner.
            live = frozenset(live_runs)
            for name, info in list(self._branches.items()):
                if info.visibility is Visibility.TXN:
                    if info.owner_run is not None \
                            and info.owner_run in live:
                        kept.append((name, "live txn: owner run "
                                     f"{info.owner_run!r} is running"))
                        continue
                    if t - info.updated_at < grace_s:
                        kept.append((name, "txn within grace period"))
                        continue
                    if info.head in self._pins:
                        kept.append((name, "txn head pinned by reader"))
                        continue
                    collected.append(
                        (name, f"abandoned txn: owner run "
                               f"{info.owner_run!r} is not live"))
                elif info.visibility is Visibility.ABORTED:
                    if info.head in self._pins:
                        kept.append((name, "aborted head pinned "
                                           "(triage in progress)"))
                        continue
                    if t - info.updated_at < grace_s:
                        kept.append((name, "aborted within grace "
                                           "period (triage window)"))
                        continue
                    collected.append((name, "aborted past grace period"))
                elif info.visibility is Visibility.QUARANTINED:
                    kept.append((name,
                                 "quarantined awaiting re-verification"
                                 if not info.verified else
                                 "quarantined (re-verified, user-domain)"))
            if not dry_run:
                for name, _reason in collected:
                    del self._branches[name]
            # manifest sweep: reachability from every SURVIVING ref.
            # The ref listing happens UNDER the catalog lock: a
            # publication merges (moves a head, under this lock) before
            # it anchors its manifest, so any manifest ref visible here
            # belongs to a commit already in the reachability snapshot —
            # a racing publication's manifest can never be swept.
            swept: list[str] = []
            reachable: set[str] = set()
            manifest_refs: list[str] = []
            if sweep_manifests and not dry_run:
                stack = [i.head for i in self._branches.values()]
                stack += list(self._tags.values())
                stack += list(self._pins)
                while stack:
                    c = stack.pop()
                    if c in reachable:
                        continue
                    reachable.add(c)
                    stack.extend(self._commits[c].parents)
                from repro.obs import MANIFEST_REF_PREFIX
                manifest_refs = list(
                    self.store.refs(MANIFEST_REF_PREFIX))
        swept_tmp = 0
        if not dry_run:
            from repro.obs import MANIFEST_REF_PREFIX
            for ref in manifest_refs:
                cid = ref[len(MANIFEST_REF_PREFIX):]
                if cid not in reachable:
                    self.store.delete_ref(ref)
                    swept.append(cid)
            if sweep_store_tmp and hasattr(self.store, "sweep_tmp"):
                swept_tmp = self.store.sweep_tmp()
        return GCReport(collected=tuple(collected), kept=tuple(kept),
                        swept_manifests=tuple(swept),
                        swept_tmp=swept_tmp)

    # ------------------------------------------------------------------
    # introspection for tests / tooling
    # ------------------------------------------------------------------
    def diff(self, a: str, b: str) -> dict[str, tuple[str | None, str | None]]:
        """Table-level diff {table: (snap@a, snap@b)} where they differ.

        Both refs are resolved under one lock acquisition so the pair is
        a consistent snapshot even under concurrent writers.
        """
        with self._lock:
            ta, tb = self.tables(a), self.tables(b)
        out = {}
        for t in set(ta) | set(tb):
            if ta.get(t) != tb.get(t):
                out[t] = (ta.get(t), tb.get(t))
        return out

    def with_retry(self, fn: Callable[[], Any], *, attempts: int = 5,
                   backoff_s: float = 0.0) -> Any:
        """Retry an optimistic operation on :class:`RefConflict`."""
        last: Exception | None = None
        for _ in range(attempts):
            try:
                return fn()
            except RefConflict as e:
                last = e
                if backoff_s:
                    time.sleep(backoff_s)
        raise last  # type: ignore[misc]
