"""Executable port of the paper's Alloy model (§4, Appendix B).

The Alloy signatures map 1:1 onto the real implementation, so model
checking here exercises the *actual* catalog code rather than a toy:

=============  =====================================================
Alloy          here
=============  =====================================================
``Table``      table name (str)
``Snapshot``   snapshot id (str) — fresh per write, tagged by run
``Commit``     :class:`repro.core.catalog.Commit` (tables, parents)
``Branch``     catalog branch (movable head)
``createTable``:meth:`Catalog.write_table` (the only mutating op)
``Run``        :class:`ModelRun` (pipeline plan, idx, lastCommit)
=============  =====================================================

Two system variants:

- ``guarded=True``  — the shipped system: aborted transactional branches
  get :class:`Visibility.ABORTED` (not mergeable, reuse quarantined).
- ``guarded=False`` — the pre-fix system of Fig. 4: an aborted branch is
  left as an ordinary USER branch, so other actors can branch off it and
  merge back.

The **global consistency** predicate formalizes Fig. 3/4: a ref is *torn
with respect to run r* iff it exposes a strict, non-empty subset of r's
published tables (partial publication), or any table of an aborted run.
Hypothesis stateful tests in ``tests/test_model_check.py`` search traces:
the unguarded model reaches torn states (the paper's counterexample);
the guarded model must never.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Literal, Sequence

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import CatalogError, ReproError, VisibilityError

__all__ = ["ModelRun", "LakehouseModel"]


@dataclasses.dataclass
class ModelRun:
    """Alloy's ``Run``: a pipeline (seq Table) + progress counter."""

    run_id: str
    plan: tuple[str, ...]              # sequence of tables to write
    mode: Literal["direct", "txn"]
    target: str
    idx: int = 0                       # next step to execute
    status: str = "running"            # running | committed | aborted
    branch: str | None = None          # txn branch (txn mode)
    written: dict[str, str] = dataclasses.field(default_factory=dict)

    @property
    def done(self) -> bool:
        return self.idx >= len(self.plan)


class LakehouseModel:
    """Driveable state machine over the real catalog."""

    def __init__(self, *, guarded: bool = True):
        self.catalog = Catalog()
        self.guarded = guarded
        self._runs: dict[str, ModelRun] = {}
        self._fresh = itertools.count()
        self._branch_counter = itertools.count()

    # ------------------------------------------------------------------
    # Run lifecycle (Alloy: begin / step / finish / fail)
    # ------------------------------------------------------------------
    def begin_run(self, plan: Sequence[str], *, target: str = "main",
                  mode: Literal["direct", "txn"] = "txn") -> ModelRun:
        rid = f"r{next(self._fresh)}"
        run = ModelRun(run_id=rid, plan=tuple(plan), mode=mode,
                       target=target)
        if mode == "txn":
            run.branch = f"txn/{rid}"
            self.catalog.create_branch(run.branch, target,
                                       visibility=Visibility.TXN,
                                       owner_run=rid)
        self._runs[rid] = run
        return run

    def step_run(self, run: ModelRun) -> None:
        """Alloy: apply ``createTable`` to the next planned table."""
        assert run.status == "running" and not run.done
        table = run.plan[run.idx]
        snap = f"{table}@{run.run_id}#{run.idx}"
        branch = run.branch if run.mode == "txn" else run.target
        self.catalog.write_table(branch, table, snap, run_id=run.run_id,
                                 _system=(run.mode == "txn"))
        run.written[table] = snap
        run.idx += 1

    def finish_run(self, run: ModelRun) -> None:
        assert run.status == "running" and run.done
        if run.mode == "txn":
            self.catalog.merge(run.branch, into=run.target,
                               run_id=run.run_id, _system=True)
            self.catalog.delete_branch(run.branch)
        run.status = "committed"

    def fail_run(self, run: ModelRun) -> None:
        """Mid-run failure. Direct mode just stops (torn!); txn aborts."""
        assert run.status == "running"
        run.status = "aborted"
        if run.mode == "txn":
            if self.guarded:
                self.catalog.mark(run.branch, Visibility.ABORTED)
            else:
                # pre-fix system: the dangling branch looks like any other
                # branch (the Fig. 4 hazard).
                self.catalog.mark(run.branch, Visibility.USER)

    # ------------------------------------------------------------------
    # Arbitrary-actor operations (the agent in Fig. 4)
    # ------------------------------------------------------------------
    def actor_branch(self, from_ref: str, *,
                     allow_reuse: bool = False) -> str:
        name = f"b{next(self._branch_counter)}"
        self.catalog.create_branch(name, from_ref, allow_reuse=allow_reuse)
        return name

    def actor_write(self, branch: str, table: str) -> str:
        snap = f"{table}@actor#{next(self._fresh)}"
        self.catalog.write_table(branch, table, snap)
        return snap

    def actor_merge(self, source: str, into: str = "main") -> None:
        self.catalog.merge(source, into=into)

    # ------------------------------------------------------------------
    # Global consistency predicate (Fig. 3/4)
    # ------------------------------------------------------------------
    def torn_runs(self, ref: str = "main") -> list[str]:
        """Runs w.r.t. which ``ref`` is globally inconsistent."""
        tables = self.catalog.tables(ref)
        torn = []
        for run in self._runs.values():
            if not run.written:
                continue
            visible = {t for t, s in run.written.items()
                       if tables.get(t) == s}
            if run.status == "committed":
                continue  # committed runs may be partially overwritten later
            # aborted / still-running runs: NO table of theirs may be
            # visible on a published ref; partial visibility = torn.
            if visible:
                torn.append(run.run_id)
        return torn

    def is_consistent(self, ref: str = "main") -> bool:
        return not self.torn_runs(ref)
