"""Executable port of the paper's Alloy model (§4, Appendix B).

The Alloy signatures map 1:1 onto the real implementation, so model
checking here exercises the *actual* catalog code rather than a toy:

=============  =====================================================
Alloy          here
=============  =====================================================
``Table``      table name (str)
``Snapshot``   snapshot id (str) — fresh per write, tagged by run
``Commit``     :class:`repro.core.catalog.Commit` (tables, parents)
``Branch``     catalog branch (movable head)
``createTable``:meth:`Catalog.write_table` (the only mutating op)
``Run``        :class:`ModelRun` (pipeline plan, idx, lastCommit)
=============  =====================================================

Two system variants:

- ``guarded=True``  — the shipped system: aborted transactional branches
  get :class:`Visibility.ABORTED` (not mergeable, reuse quarantined).
- ``guarded=False`` — the pre-fix system of Fig. 4: an aborted branch is
  left as an ordinary USER branch, so other actors can branch off it and
  merge back.

and two publication variants:

- ``publication="rebase"`` — the shipped CAS + rebase-and-revalidate
  protocol (DESIGN.md §7): a run publishes with ``expected_head``; on
  conflict it rebases its branch onto the new head and *re-verifies*
  before retrying.
- ``publication="stale"``  — the pre-fix protocol: a plain three-way
  merge with no CAS, which can silently publish a combined state no
  verifier ever observed when the target moved after ``begin``.

The **global consistency** predicate formalizes Fig. 3/4: a ref is *torn
with respect to run r* iff it exposes a strict, non-empty subset of r's
published tables (partial publication), or any table of an aborted run.
The **verified publication** predicate (:meth:`stale_publications`)
formalizes the §3.3 concurrency invariant: the commit a run publishes
must carry exactly the table state its verifiers last validated.
Hypothesis stateful tests in ``tests/test_model_check.py`` search traces:
the unguarded/stale models reach bad states (which makes the model
adequate); the guarded/rebase models must never.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Literal, Sequence

from repro.core.catalog import Catalog, Visibility
from repro.core.errors import (CatalogError, RefConflict, ReproError,
                               VisibilityError)

__all__ = ["ModelRun", "LakehouseModel"]


@dataclasses.dataclass
class ModelRun:
    """Alloy's ``Run``: a pipeline (seq Table) + progress counter."""

    run_id: str
    plan: tuple[str, ...]              # sequence of tables to write
    mode: Literal["direct", "txn"]
    target: str
    idx: int = 0                       # next step to execute
    status: str = "running"            # running | committed | aborted
    branch: str | None = None          # txn branch (txn mode)
    written: dict[str, str] = dataclasses.field(default_factory=dict)
    start_head: str | None = None      # target head at begin (CAS token)
    verified_tables: dict[str, str] | None = None  # state verifiers saw
    published_commit: str | None = None            # commit the merge made

    @property
    def done(self) -> bool:
        return self.idx >= len(self.plan)


class LakehouseModel:
    """Driveable state machine over the real catalog."""

    def __init__(self, *, guarded: bool = True,
                 publication: Literal["rebase", "stale"] = "rebase"):
        self.catalog = Catalog()
        self.guarded = guarded
        self.publication = publication
        self._runs: dict[str, ModelRun] = {}
        self._fresh = itertools.count()
        self._branch_counter = itertools.count()
        self._gc_violations: list[tuple[str, str]] = []

    # ------------------------------------------------------------------
    # Run lifecycle (Alloy: begin / step / finish / fail)
    # ------------------------------------------------------------------
    def begin_run(self, plan: Sequence[str], *, target: str = "main",
                  mode: Literal["direct", "txn"] = "txn") -> ModelRun:
        rid = f"r{next(self._fresh)}"
        run = ModelRun(run_id=rid, plan=tuple(plan), mode=mode,
                       target=target)
        run.start_head = self.catalog.head(target).id
        if mode == "txn":
            run.branch = f"txn/{rid}"
            self.catalog.create_branch(run.branch, target,
                                       visibility=Visibility.TXN,
                                       owner_run=rid)
        self._runs[rid] = run
        return run

    def step_run(self, run: ModelRun) -> None:
        """Alloy: apply ``createTable`` to the next planned table."""
        assert run.status == "running" and not run.done
        table = run.plan[run.idx]
        snap = f"{table}@{run.run_id}#{run.idx}"
        branch = run.branch if run.mode == "txn" else run.target
        self.catalog.write_table(branch, table, snap, run_id=run.run_id,
                                 _system=(run.mode == "txn"))
        run.written[table] = snap
        run.idx += 1

    def finish_run(self, run: ModelRun) -> None:
        assert run.status == "running" and run.done
        if run.mode == "txn":
            # Alloy's `verify`: record the exact table state the run's
            # verifiers observed on B' at publication time.
            run.verified_tables = dict(self.catalog.tables(run.branch))
            if self.publication == "stale":
                # pre-fix: a plain merge — if the target moved after
                # begin, this silently three-way-merges a combined state
                # NO verifier ever saw.
                merged = self.catalog.merge(run.branch, into=run.target,
                                            run_id=run.run_id,
                                            _system=True)
            else:
                merged = self._publish_rebase(run)
            run.published_commit = merged.id
            self.catalog.delete_branch(run.branch, _system=True)
        run.status = "committed"

    def _publish_rebase(self, run: ModelRun):
        """The shipped protocol: CAS merge; on conflict rebase onto the
        observed head and re-verify before retrying."""
        expected = run.start_head
        while True:
            try:
                return self.catalog.merge(
                    run.branch, into=run.target, run_id=run.run_id,
                    expected_head=expected, _system=True)
            except RefConflict:
                new_head = self.catalog.head(run.target).id
                self.catalog.rebase(run.branch, new_head,
                                    run_id=run.run_id, _system=True)
                # re-verify: the verifiers now validate the rebased state
                run.verified_tables = dict(
                    self.catalog.tables(run.branch))
                expected = new_head

    def fail_run(self, run: ModelRun) -> None:
        """Mid-run failure. Direct mode just stops (torn!); txn aborts."""
        assert run.status == "running"
        run.status = "aborted"
        if run.mode == "txn":
            if self.guarded:
                self.catalog.mark(run.branch, Visibility.ABORTED,
                                  _system=True)
            else:
                # pre-fix system: the dangling branch looks like any other
                # branch (the Fig. 4 hazard).
                self.catalog.mark(run.branch, Visibility.USER,
                                  _system=True)

    def abandon_run(self, run: ModelRun) -> None:
        """The owning agent walks away (or dies) mid-run: no commit, no
        abort — the TXN branch dangles with its owner gone. This is the
        debris :meth:`gc` exists to collect."""
        assert run.status == "running"
        run.status = "abandoned"

    # ------------------------------------------------------------------
    # Garbage collection (DESIGN.md §15)
    # ------------------------------------------------------------------
    def live_run_ids(self) -> frozenset[str]:
        """Alloy's liveness relation: runs still executing own their
        transactional branches."""
        return frozenset(r.run_id for r in self._runs.values()
                         if r.status == "running")

    def gc(self, *, unsafe: bool = False) -> list[str]:
        """Collect transactional debris; returns collected branch names.

        The safe variant is the shipped :meth:`Catalog.gc` driven by
        the model's liveness relation. The ``unsafe`` variant is the
        pre-fix janitor the adequacy tests need: it deletes EVERY
        TXN/ABORTED branch with no liveness or pin check — the
        "cron job that cleans old branches" a naive lakehouse grows.
        Either way, any collection of a branch whose owner is still
        running, or whose head a reader has pinned, is recorded and
        surfaced by :meth:`collected_live_branches`.
        """
        heads: dict[str, tuple[str, str | None]] = {}
        vis_of: dict[str, Visibility] = {}
        for name in self.catalog.branches():
            info = self.catalog.branch_info(name)
            heads[name] = (info.head, info.owner_run)
            vis_of[name] = info.visibility
        if unsafe:
            collected = []
            for name in heads:
                if vis_of[name] in (Visibility.TXN, Visibility.ABORTED):
                    self.catalog.delete_branch(name, _system=True)
                    collected.append(name)
        else:
            report = self.catalog.gc(live_runs=self.live_run_ids(),
                                     grace_s=0.0)
            collected = [name for name, _reason in report.collected]
        live = self.live_run_ids()
        pinned = self.catalog.pinned()
        for name in collected:
            head, owner = heads[name]
            if owner is not None and owner in live:
                self._gc_violations.append(
                    (name, f"collected while owner {owner!r} was live"))
            if head in pinned:
                self._gc_violations.append(
                    (name, "collected while its head was pinned"))
        return collected

    def pin_branch(self, ref: str) -> str:
        """A reader pins the state it is serving/triaging from."""
        return self.catalog.pin(ref)

    def collected_live_branches(self) -> list[tuple[str, str]]:
        """The GC safety predicate: collections that destroyed state a
        live run or a pinned reader still owned. Must stay empty for
        the shipped GC under every schedule; the unsafe janitor
        populates it (adequacy)."""
        return list(self._gc_violations)

    # ------------------------------------------------------------------
    # Arbitrary-actor operations (the agent in Fig. 4)
    # ------------------------------------------------------------------
    def actor_branch(self, from_ref: str, *,
                     allow_reuse: bool = False) -> str:
        name = f"b{next(self._branch_counter)}"
        self.catalog.create_branch(name, from_ref, allow_reuse=allow_reuse)
        return name

    def actor_write(self, branch: str, table: str) -> str:
        snap = f"{table}@actor#{next(self._fresh)}"
        self.catalog.write_table(branch, table, snap)
        return snap

    def actor_merge(self, source: str, into: str = "main") -> None:
        self.catalog.merge(source, into=into)

    # ------------------------------------------------------------------
    # Global consistency predicate (Fig. 3/4)
    # ------------------------------------------------------------------
    def torn_runs(self, ref: str = "main") -> list[str]:
        """Runs w.r.t. which ``ref`` is globally inconsistent."""
        tables = self.catalog.tables(ref)
        torn = []
        for run in self._runs.values():
            if not run.written:
                continue
            visible = {t for t, s in run.written.items()
                       if tables.get(t) == s}
            if run.status == "committed":
                continue  # committed runs may be partially overwritten later
            # aborted / still-running runs: NO table of theirs may be
            # visible on a published ref; partial visibility = torn.
            if visible:
                torn.append(run.run_id)
        return torn

    def is_consistent(self, ref: str = "main") -> bool:
        return not self.torn_runs(ref)

    # ------------------------------------------------------------------
    # Concurrent-publication predicate (DESIGN.md §7)
    # ------------------------------------------------------------------
    def stale_publications(self) -> list[str]:
        """Runs whose published commit carries table state their
        verifiers never validated.

        This is the §3.3 concurrency invariant: the commit a run's merge
        creates (or fast-forwards to) must equal, table for table, the
        state of the transactional branch at the last verifier pass.
        A plain three-way merge against a moved target violates it; the
        rebase-and-revalidate protocol makes it unfalsifiable.
        """
        out = []
        for run in self._runs.values():
            if run.published_commit is None or run.verified_tables is None:
                continue
            published = dict(
                self.catalog.commit(run.published_commit).tables)
            if published != run.verified_tables:
                out.append(run.run_id)
        return out

    def publications_verified(self) -> bool:
        return not self.stale_publications()
