"""Content-addressed immutable object store (paper §3.2, physical layer).

The paper's physical substrate is parquet + snapshot files immutably
stored in object storage; branching and merging are purely *logical*
(zero-copy). We reproduce that split: this module stores immutable,
content-addressed blobs; :mod:`repro.core.catalog` stores only references.

Two backends:

- :class:`MemoryStore` — in-process dict, used by tests and the planner.
- :class:`FileStore`   — a directory of ``objects/<aa>/<hash>`` files with
  atomic single-blob put (write-temp + rename), the "S3 put" the paper
  assumes. Used by checkpointing so restarts survive process death.

Snapshots of structured artifacts (tables, pytrees) are serialized via
:func:`put_pytree` / :func:`get_pytree`: leaves go in as raw array blobs,
the tree-structure goes in as a JSON manifest — so two snapshots sharing
leaves (e.g. a merge, or an unchanged optimizer slot) share physical blobs,
which is exactly the paper's copy-on-write story.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
import threading
import time
from typing import Any, Iterator, Mapping

import numpy as np

from repro.core.hooks import fault_point

__all__ = ["ObjectStore", "MemoryStore", "FileStore", "put_pytree",
           "get_pytree", "content_hash"]


def content_hash(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


class ObjectStore:
    """Abstract immutable blob store keyed by content hash.

    Besides immutable blobs, a store exposes a small *named-ref* surface
    (``put_ref``/``get_ref``): mutable name → blob-key pointers, the
    only mutable state in the physical layer. The engine's
    content-addressed function cache persists through it (a cache entry
    is ``fncache/<cache-key> -> output snapshot key``), so a
    :class:`FileStore`-backed cache survives process restarts.
    """

    def put(self, data: bytes) -> str:
        raise NotImplementedError

    def get(self, key: str) -> bytes:
        raise NotImplementedError

    def __contains__(self, key: str) -> bool:
        raise NotImplementedError

    def keys(self) -> Iterator[str]:
        raise NotImplementedError

    # -- named refs (mutable pointers into the immutable blob space) ---
    def put_ref(self, name: str, key: str) -> None:
        raise NotImplementedError

    def get_ref(self, name: str) -> str | None:
        raise NotImplementedError

    def refs(self, prefix: str = "") -> Iterator[str]:
        """Iterate ref names (optionally under ``prefix``) — the
        enumeration surface ``Catalog.gc``'s manifest sweep walks."""
        raise NotImplementedError

    def delete_ref(self, name: str) -> bool:
        """Remove a named ref; returns whether it existed. The blob it
        pointed at stays (immutable space; content GC is out of scope)."""
        raise NotImplementedError

    # -- structured helpers -------------------------------------------
    def put_json(self, obj: Any) -> str:
        return self.put(json.dumps(obj, sort_keys=True).encode())

    def get_json(self, key: str) -> Any:
        return json.loads(self.get(key).decode())

    def put_array(self, arr) -> str:
        arr = np.asarray(arr)
        # ml_dtypes (bfloat16 etc.) are not .npy-native: store the raw
        # bits viewed as uint and a one-line dtype header.
        dtype_name = arr.dtype.name
        if arr.dtype.kind not in ("U", "S") and (
                arr.dtype.kind == "V" or dtype_name not in np.sctypeDict):
            raw = arr.view(np.uint8 if arr.dtype.itemsize == 1 else
                           np.uint16 if arr.dtype.itemsize == 2 else
                           np.uint32)
        else:
            raw = arr
        buf = io.BytesIO()
        buf.write(f"{dtype_name}\n".encode())
        np.save(buf, raw, allow_pickle=False)
        return self.put(buf.getvalue())

    def get_array(self, key: str) -> np.ndarray:
        buf = io.BytesIO(self.get(key))
        dtype_name = buf.readline().decode().strip()
        raw = np.load(buf, allow_pickle=False)
        if raw.dtype.name != dtype_name:
            import ml_dtypes  # shipped with jax
            raw = raw.view(np.dtype(getattr(ml_dtypes, dtype_name)))
        return raw


class MemoryStore(ObjectStore):
    """Thread-safe: concurrent transactional runs share one store, so
    every dict access goes through the lock."""

    def __init__(self):
        self._blobs: dict[str, bytes] = {}
        self._refs: dict[str, str] = {}
        self._lock = threading.Lock()

    def put(self, data: bytes) -> str:
        key = content_hash(data)
        with self._lock:
            # immutable: put of existing key is a no-op (dedup)
            self._blobs.setdefault(key, bytes(data))
        return key

    def get(self, key: str) -> bytes:
        with self._lock:
            try:
                return self._blobs[key]
            except KeyError:
                raise KeyError(f"object {key!r} not in store") from None

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._blobs

    def keys(self) -> Iterator[str]:
        with self._lock:
            return iter(list(self._blobs))

    def put_ref(self, name: str, key: str) -> None:
        with self._lock:
            self._refs[name] = key

    def get_ref(self, name: str) -> str | None:
        with self._lock:
            return self._refs.get(name)

    def refs(self, prefix: str = "") -> Iterator[str]:
        with self._lock:
            return iter([n for n in self._refs if n.startswith(prefix)])

    def delete_ref(self, name: str) -> bool:
        with self._lock:
            return self._refs.pop(name, None) is not None

    def __len__(self) -> int:
        with self._lock:
            return len(self._blobs)


class FileStore(ObjectStore):
    """Filesystem-backed store with atomic single-blob put.

    Layout: ``<root>/objects/<first2>/<hash>``. Put is write-to-temp then
    ``os.replace`` (atomic on POSIX) — the single-object atomicity the
    paper assumes of S3/Iceberg and builds on top of.

    **Crash consistency** (DESIGN.md §15): temp files are dot-prefixed
    (``.tmp-*``) so a crash between write and replace can never be
    mistaken for an object or a ref — ``keys()``/``refs()``/``get_ref``
    skip them by construction (ref-name validation already rejects
    dot-leading components). Cleanup of an *errored* write runs on
    ``Exception`` only: an :class:`~repro.core.hooks.InjectedCrash`
    (``BaseException``, simulated process death) leaks the temp file
    exactly as a killed process would, and :meth:`sweep_tmp` is the
    GC that recovers the leak.
    """

    _TMP_PREFIX = ".tmp-"

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)

    def _path(self, key: str) -> str:
        return os.path.join(self.root, "objects", key[:2], key)

    def put(self, data: bytes) -> str:
        key = content_hash(data)
        path = self._path(key)
        if os.path.exists(path):
            return key  # dedup
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=self._TMP_PREFIX)
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            fault_point("filestore.put.pre_replace", tmp=tmp, path=path,
                        key=key)
            os.replace(tmp, path)  # atomic publish
        except Exception:
            # recoverable error: clean our temp. A crash (BaseException)
            # skips this, leaking the temp like real process death —
            # sweep_tmp() collects it.
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
        return key

    def get(self, key: str) -> bytes:
        path = self._path(key)
        if not os.path.exists(path):
            raise KeyError(f"object {key!r} not in store")
        with open(path, "rb") as f:
            return f.read()

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self) -> Iterator[str]:
        objdir = os.path.join(self.root, "objects")
        for d in os.listdir(objdir):
            sub = os.path.join(objdir, d)
            if not os.path.isdir(sub):
                continue
            for k in os.listdir(sub):
                if not k.startswith("."):   # leaked .tmp-* are not keys
                    yield k

    def _ref_path(self, name: str) -> str:
        parts = name.split("/")
        if not all(p and all(c.isalnum() or c in "._-" for c in p)
                   and not p.startswith(".") for p in parts):
            raise ValueError(f"invalid ref name {name!r}")
        return os.path.join(self.root, "refs", *parts)

    def put_ref(self, name: str, key: str) -> None:
        path = self._ref_path(name)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   prefix=self._TMP_PREFIX)
        try:
            with os.fdopen(fd, "w") as f:
                f.write(key)
            # the torn-write window a naive open(path,"w").write() would
            # have: a crash here leaves the OLD ref intact (the temp is
            # invisible to readers) — regression-tested crash-at-every-
            # byte in tests/test_chaos_faults.py.
            fault_point("filestore.put_ref.pre_replace", tmp=tmp,
                        path=path, name=name, key=key)
            os.replace(tmp, path)  # atomic, like blob put
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def get_ref(self, name: str) -> str | None:
        path = self._ref_path(name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return f.read().strip()

    def refs(self, prefix: str = "") -> Iterator[str]:
        refdir = os.path.join(self.root, "refs")
        if not os.path.isdir(refdir):
            return
        for dirpath, _dirs, files in os.walk(refdir):
            rel = os.path.relpath(dirpath, refdir)
            for fn in files:
                if fn.startswith("."):      # leaked temp, not a ref
                    continue
                name = fn if rel == "." else "/".join(
                    rel.split(os.sep) + [fn])
                if name.startswith(prefix):
                    yield name

    def delete_ref(self, name: str) -> bool:
        path = self._ref_path(name)
        if not os.path.exists(path):
            return False
        os.unlink(path)
        return True

    def sweep_tmp(self, min_age_s: float = 0.0) -> int:
        """GC leaked ``.tmp-*`` files (crashed writes). ``min_age_s``
        guards in-flight writers by mtime; returns files removed."""
        removed = 0
        now = time.time()
        for top in ("objects", "refs"):
            base = os.path.join(self.root, top)
            if not os.path.isdir(base):
                continue
            for dirpath, _dirs, files in os.walk(base):
                for fn in files:
                    if not fn.startswith(self._TMP_PREFIX):
                        continue
                    p = os.path.join(dirpath, fn)
                    try:
                        if now - os.path.getmtime(p) >= min_age_s:
                            os.unlink(p)
                            removed += 1
                    except OSError:  # pragma: no cover - racing writer
                        pass
        return removed


# ---------------------------------------------------------------------------
# Pytree snapshots (copy-on-write structured artifacts)
# ---------------------------------------------------------------------------

def put_pytree(store: ObjectStore, tree: Any) -> str:
    """Store a pytree; returns the manifest key (the snapshot id).

    Leaves are stored as individual array blobs, so snapshots that share
    leaves share storage — logical copies are zero-copy, as in the paper.
    """
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    leaf_keys = [store.put_array(leaf) for leaf in leaves]
    manifest = {"treedef": str(treedef), "leaves": leaf_keys,
                "kind": "pytree"}
    return store.put_json(manifest)


def get_pytree(store: ObjectStore, key: str, like: Any) -> Any:
    """Load a pytree snapshot; ``like`` provides the tree structure."""
    import jax

    manifest = store.get_json(key)
    leaves = [store.get_array(k) for k in manifest["leaves"]]
    _, treedef = jax.tree_util.tree_flatten(like)
    if str(treedef) != manifest["treedef"]:
        raise ValueError(
            "snapshot treedef mismatch: stored structure differs from "
            "`like` structure (elastic reshard should go through "
            "repro.distributed.elastic)")
    return jax.tree_util.tree_unflatten(treedef, leaves)


def get_pytree_leaves(store: ObjectStore, key: str) -> list[np.ndarray]:
    manifest = store.get_json(key)
    return [store.get_array(k) for k in manifest["leaves"]]
