"""Contract composition and validation (paper §3.1 + Appendix A).

Three checking *moments* (Figure 1):

1. **Authoring** (:func:`check_wellformed`) — a schema must be internally
   consistent; lineage references must resolve.
2. **Control plane** (:func:`check_edge`, :func:`check_node`) — *before*
   any distributed execution, every edge of the DAG must compose: each
   column a consumer declares as inherited must exist upstream with a
   compatible type; *narrowing* (float→int, nullable→not-null) is legal
   only when the node explicitly declares the cast/filter.
3. **Worker** (:func:`validate_table`) — the physical data must conform
   to the declared output schema before any result is persisted.

"Dafny-style" pre/post-condition propagation (Appendix A): the planner
calls :func:`provable_postconditions` to decide which worker-side checks
are statically discharged and can be elided.
"""
from __future__ import annotations

import dataclasses
from typing import Collection, Iterable, Mapping

from repro.core import schema as S
from repro.core.errors import (
    ContractAuthoringError,
    ContractCompositionError,
    ContractRuntimeError,
)

__all__ = [
    "CastDecl", "check_wellformed", "check_edge", "check_node",
    "validate_table", "provable_postconditions", "EdgeReport",
]


@dataclasses.dataclass(frozen=True)
class CastDecl:
    """An explicit cast declared by a node (``arrow_cast`` in Listing 5)."""

    column: str
    to: S.DType


@dataclasses.dataclass(frozen=True)
class EdgeReport:
    """Result of composing one (upstream → downstream) edge."""

    upstream: str
    downstream: str
    inherited: tuple[str, ...]
    narrowed: tuple[str, ...]
    fresh: tuple[str, ...]

    def describe(self) -> str:
        return (f"{self.upstream} -> {self.downstream}: "
                f"inherited={list(self.inherited)} "
                f"narrowed={list(self.narrowed)} fresh={list(self.fresh)}")


# ---------------------------------------------------------------------------
# Moment 1: authoring
# ---------------------------------------------------------------------------

def check_wellformed(schema: type[S.Schema]) -> None:
    """Raise :class:`ContractAuthoringError` if the schema is ill-formed."""
    seen: set[str] = set()
    for name, col in schema.columns().items():
        if not name.isidentifier():
            raise ContractAuthoringError(
                f"{schema.__name__}.{name}: not a valid column identifier")
        if name in seen:  # pragma: no cover - dict keys are unique
            raise ContractAuthoringError(
                f"{schema.__name__}: duplicate column {name}")
        seen.add(name)
        if col.inherited_from is not None and "." not in col.inherited_from:
            raise ContractAuthoringError(
                f"{schema.__name__}.{name}: malformed lineage "
                f"{col.inherited_from!r}")


# ---------------------------------------------------------------------------
# Moment 2: control plane
# ---------------------------------------------------------------------------

def _resolve_upstream(
    col: S.Column,
    inputs: Mapping[str, type[S.Schema]],
) -> tuple[str, S.Column] | None:
    """Find the upstream column this output column flows from.

    Resolution order: explicit lineage ("Schema.col"), then by-name match
    across inputs (the paper's "col2 is propagated as-is" convention).
    Returns (input schema name, column) or None for fresh columns.

    By-name resolution across MULTIPLE inputs is legal only when every
    candidate declares the same (dtype, nullability) — otherwise the
    composition verdict would depend on input dict ordering (binding
    ``x`` to ``A(x: int32)`` vs ``B(x: int64)`` flips widening into
    narrowing). Ambiguous candidates raise
    :class:`ContractCompositionError`; declare explicit lineage
    (``col = A.x``) to disambiguate.
    """
    if col.inherited_from is not None:
        sname, cname = col.inherited_from.rsplit(".", 1)
        for iname, ischema in inputs.items():
            if ischema.__name__ == sname and cname in ischema.columns():
                return iname, ischema.columns()[cname]
        # lineage names a schema that is not an input: composition error.
        raise ContractCompositionError(
            f"column {col.name!r} declares lineage {col.inherited_from!r} "
            f"but no input provides it (inputs: "
            f"{[s.__name__ for s in inputs.values()]})")
    candidates = [(iname, ischema.columns()[col.name])
                  for iname, ischema in inputs.items()
                  if col.name in ischema.columns()]
    if not candidates:
        return None
    decls = {(c.dtype, c.nullable) for _, c in candidates}
    if len(decls) > 1:
        raise ContractCompositionError(
            f"column {col.name!r} resolves by name against multiple "
            f"inputs with conflicting declarations "
            f"({', '.join(sorted(f'{i}: {c.dtype.name}' + ('?' if c.nullable else '') for i, c in candidates))}): "
            f"declare explicit lineage (e.g. `{col.name} = "
            f"SchemaName.{col.name}`) to disambiguate")
    return candidates[0]


def referenced_columns(
    inputs: Mapping[str, type[S.Schema]],
    output: type[S.Schema],
    computed: Collection[str] = (),
) -> dict[str, set[str]]:
    """Per-input sets of upstream columns the output contract references.

    The elision-soundness input for the optimizer (Appendix A): a source
    column may be dropped from a scan only when it is outside BOTH the
    step's own expression/key references AND this set — contract
    verifiers (``validate_table``) check declared columns of the output,
    and each declared column resolves to at most one upstream column per
    :func:`_resolve_upstream` (explicit lineage first, then by-name).
    Fresh columns (computed, no upstream) reference nothing. Keys are
    the input names used in ``inputs``; every input appears, possibly
    with an empty set.

    ``computed`` names output columns the node *manufactures* — an
    aggregate node's output columns (``agg_specs`` outs) — which must
    not resolve by name: a spec output that happens to reuse an input
    column's name carries aggregated values, not a pass-through, so a
    by-name resolution would anchor an input column the verifier never
    actually reaches (blocking its elision for nothing).
    """
    out: dict[str, set[str]] = {iname: set() for iname in inputs}
    for name, column in output.columns().items():
        if name in computed and column.inherited_from is None:
            continue
        src = _resolve_upstream(column, inputs)
        if src is not None:
            out[src[0]].add(src[1].name)
    return out


def check_edge(
    upstream: type[S.Schema],
    downstream: type[S.Schema],
    casts: Iterable[CastDecl] = (),
) -> EdgeReport:
    """Check that a single edge composes (convenience over check_node)."""
    return check_node({upstream.__name__: upstream}, downstream, casts)


def check_node(
    inputs: Mapping[str, type[S.Schema]],
    output: type[S.Schema],
    casts: Iterable[CastDecl] = (),
) -> EdgeReport:
    """Control-plane composition check for one DAG node.

    For every output column that is inherited (explicitly via lineage, or
    implicitly by name), the upstream type must flow into the declared
    type: identical or widenable with no cast; narrowable only with an
    explicit :class:`CastDecl`; anything else is a composition error.
    Nullability may only be narrowed (nullable → not-null) when declared
    via ``[NotNull]`` lineage or a cast — widening (not-null → nullable)
    is always safe.
    """
    for s in (*inputs.values(), output):
        check_wellformed(s)
    cast_by_col = {c.column: c for c in casts}
    inherited, narrowed, fresh = [], [], []

    for name, col in output.columns().items():
        src = _resolve_upstream(col, inputs)
        if src is None:
            fresh.append(name)
            continue
        _, upcol = src
        inherited.append(name)
        # --- type flow ---
        if S.widenable(upcol.dtype, col.dtype):
            pass  # identity or implicit widening: always legal
        elif S.narrowable(upcol.dtype, col.dtype):
            cast = cast_by_col.get(name)
            if cast is None:
                raise ContractCompositionError(
                    f"{output.__name__}.{name}: narrows {upcol.dtype.name} "
                    f"-> {col.dtype.name} without an explicit cast "
                    f"(paper §3.1: narrowing requires a declared cast)")
            if cast.to != col.dtype:
                raise ContractCompositionError(
                    f"{output.__name__}.{name}: cast target "
                    f"{cast.to.name} != declared type {col.dtype.name}")
            narrowed.append(name)
        else:
            raise ContractCompositionError(
                f"{output.__name__}.{name}: incompatible types "
                f"{upcol.dtype.name} -> {col.dtype.name}")
        # --- nullability flow ---
        if upcol.nullable and not col.nullable:
            # legal only when declared: [NotNull] lineage (inherited_from
            # set and nullability narrowed) or an explicit cast.
            declared = (col.inherited_from is not None) or (name in cast_by_col)
            if not declared:
                raise ContractCompositionError(
                    f"{output.__name__}.{name}: narrows nullability without "
                    f"an explicit [NotNull] declaration")
            if name not in narrowed:
                narrowed.append(name)

    return EdgeReport(
        upstream="+".join(s.__name__ for s in inputs.values()),
        downstream=output.__name__,
        inherited=tuple(inherited),
        narrowed=tuple(narrowed),
        fresh=tuple(fresh),
    )


# ---------------------------------------------------------------------------
# Moment 3: worker
# ---------------------------------------------------------------------------

def validate_table(table, schema: type[S.Schema], *,
                   elide: frozenset[str] = frozenset(),
                   name: str = "<table>") -> None:
    """Validate physical data against its declared schema (worker moment).

    ``table`` is a :class:`repro.data.tables.Table`. ``elide`` contains
    column names whose null-check was statically discharged by the planner
    (:func:`provable_postconditions`) and can be skipped.
    """
    cols = schema.columns()
    missing = set(cols) - set(table.column_names())
    if missing:
        raise ContractRuntimeError(
            f"{name}: missing columns {sorted(missing)} required by "
            f"{schema.__name__}")
    for cname, col in cols.items():
        physical = table.logical_dtype(cname)
        if physical != col.dtype.name:
            raise ContractRuntimeError(
                f"{name}.{cname}: physical dtype {physical} != declared "
                f"{col.dtype.name}")
        if not col.nullable and cname not in elide:
            if table.has_nulls(cname):
                raise ContractRuntimeError(
                    f"{name}.{cname}: contract declares NOT NULL but data "
                    f"contains nulls (paper §3.1: unexpected nulls are "
                    f"contract violations)")


# ---------------------------------------------------------------------------
# "Dafny-style" static discharge (Appendix A)
# ---------------------------------------------------------------------------

def provable_postconditions(
    inputs: Mapping[str, type[S.Schema]],
    output: type[S.Schema],
    *,
    inspectable: bool,
    null_preserving: bool,
) -> frozenset[str]:
    """Columns of ``output`` whose NOT-NULL check is statically provable.

    Per Appendix A, the worker-side null check for an output column can be
    elided when (1) the output schema is trusted/defined, (2) the node's
    transformation language is inspectable (e.g. declarative select), and
    (3) the transformation provably maintains nullability — here summarised
    by ``null_preserving`` (our declarative ``Table.select`` without outer
    joins is null-preserving for inherited columns).
    """
    if not (inspectable and null_preserving):
        return frozenset()
    provable = set()
    for name, col in output.columns().items():
        if col.nullable:
            continue
        src = _resolve_upstream(col, inputs)
        if src is None:
            continue  # fresh column: must be checked physically
        _, upcol = src
        if not upcol.nullable:
            # upstream guarantees not-null, transformation preserves it.
            provable.add(name)
    return frozenset(provable)
