"""The control plane: validate a DAG *before* any distributed execution.

Paper §3/Figure 1, moment (2): "before scheduling any distributed
execution, the control plane can parse the DAG metadata and validate that
adjacent nodes compose (every referenced column exists with a compatible
type, and — if the transformation language allows inspection — casts are
present when necessary)".

:func:`plan` performs, in order:
  1. structural validation (acyclicity, resolvable inputs, unique outputs);
  2. per-node contract composition (:func:`repro.core.contracts.check_node`)
     including cast/narrowing legality;
  3. Appendix-A static discharge: computes, per node, the set of NOT-NULL
     checks that are provable and can be elided at the worker;
  4. logical lowering: inspectable declarative nodes carry their
     :mod:`repro.core.logical` tree on the step, which is what the
     optimizer (:mod:`repro.optimizer`) rewrites and the engine
     executes.

The result is an immutable :class:`Plan`; :mod:`repro.core.runner`
executes plans, never raw pipelines — so an invalid DAG can never reach
a worker ("ill-typed pipelines should not be planned"). Optimizer
passes produce *new* Plans through :func:`rebuild` (waves are
recomputed — a pushdown can change the critical path) and stamp their
provenance onto the steps they touched; ``Plan.describe()`` renders
that trail as the EXPLAIN section.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.core import schema as S
from repro.core.contracts import (EdgeReport, check_node,
                                  provable_postconditions)
from repro.core.dag import Node, Pipeline
from repro.core.errors import PlanError

__all__ = ["PlanStep", "Plan", "plan", "rebuild"]

# stat entries rendered per step in describe() before truncation —
# agents parse this output, and one unbounded sorted line per step made
# wide pipelines unreadable (and unparseable past terminal limits).
_DESCRIBE_STATS_MAX = 3


@dataclasses.dataclass(frozen=True)
class PlanStep:
    node: Node
    report: EdgeReport
    elided_null_checks: frozenset[str]  # statically discharged (App. A)
    wave: int = 0                       # dependency level (DESIGN.md §8)
    # per-input table statistics (repro.exec.stats.TableStats), keyed
    # by table name — recorded when the caller supplies stats for the
    # tables it can see (sources; intermediate outputs are unknown at
    # the control-plane moment). Feeds observability and the ``auto``
    # execution backend's decision table (DESIGN.md §10); absence means
    # "unknown", never "empty".
    input_stats: "Mapping[str, object] | None" = None
    # logical IR (repro.core.logical.LogicalOp) for inspectable
    # declarative nodes — what the optimizer rewrites and the engine
    # executes; None = opaque node, run through node.run().
    logical: "object | None" = None
    # False for optimizer-materialized auxiliary steps (e.g. a shared
    # filter hoisted out of two consumers): they execute and cache like
    # any step but are not published pipeline outputs — the runner must
    # not commit them to the catalog.
    published: bool = True
    # human-readable rewrite trail ("why this tree looks like this"),
    # appended by each optimizer pass that touched this step. Folded
    # into the engine cache key: a step whose tree was rewritten
    # differently must never share a cache entry.
    provenance: tuple[str, ...] = ()
    # the active optimizer pass list (stamped on every step of an
    # optimized plan, touched or not) — cache-key material so flipping
    # a pass on/off can never serve a stale cross-plan hit.
    opt_passes: tuple[str, ...] = ()

    def execute(self, tables) -> "object":
        """Run this step's transformation: the (possibly rewritten)
        logical tree when present, the node body otherwise."""
        if self.logical is not None:
            return self.logical.execute(tables,
                                        stats=self.input_stats)
        return self.node.run(tables)

    def cache_material(self) -> str | None:
        """Static cache-key half for this step (see
        ``Node.cache_material``). A rewritten logical tree replaces the
        node's source in the material — two steps executing different
        trees must key differently — but only when the tree is fully
        structural; otherwise the step is uncacheable, same rule as
        ``DeclarativeNode.cache_material``."""
        if self.logical is None:
            return self.node.cache_material()
        if not self.logical.is_structural():
            return None
        casts = ";".join(f"{c.column}->{c.to.name}"
                         for c in self.node.casts)
        return (f"<logical: {self.logical.describe()}>|"
                f"{self.node.output_schema.fingerprint()}|{casts}")


@dataclasses.dataclass(frozen=True)
class Plan:
    pipeline_name: str
    code_hash: str
    steps: tuple[PlanStep, ...]
    source_schemas: Mapping[str, type[S.Schema]]
    # the optimizer pass list this plan was produced by (empty =
    # unoptimized); mirrors PlanStep.opt_passes for plan-level display.
    optimizer_passes: tuple[str, ...] = ()

    @property
    def output_tables(self) -> tuple[str, ...]:
        """Published output tables — what the runner commits. Excludes
        optimizer-materialized auxiliary steps."""
        return tuple(s.node.name for s in self.steps if s.published)

    @property
    def waves(self) -> tuple[tuple[PlanStep, ...], ...]:
        """Steps grouped by dependency level (level scheduling): wave
        ``w`` holds every node whose longest path from a source is ``w``.
        All nodes of a wave depend only on sources and earlier waves, so
        a wave's nodes may execute concurrently; steps within a wave keep
        plan order, making wave execution deterministic."""
        grouped: dict[int, list[PlanStep]] = {}
        for s in self.steps:
            grouped.setdefault(s.wave, []).append(s)
        return tuple(tuple(grouped[w]) for w in sorted(grouped))

    def source_tables(self) -> tuple[str, ...]:
        """Source tables the plan's nodes actually read (auxiliary step
        outputs are plan-internal, not sources)."""
        outputs = {s.node.name for s in self.steps}
        seen: list[str] = []
        for s in self.steps:
            for t in s.node.inputs.values():
                if t not in outputs and t not in seen:
                    seen.append(t)
        return tuple(seen)

    def describe(self, *, analyze: bool = False) -> str:
        """EXPLAIN (and, with ``analyze=True``, EXPLAIN ANALYZE).

        ``analyze`` renders the actual per-step runtime the engine
        recorded on the last execution of THIS plan object — cache
        verdict, actual output rows (next to the TableStats
        *estimates*), and wall time — as a format-pinned ``[actual:
        cache=<verdict> rows=<n> time=<t>ms]`` suffix per step.
        Raises :class:`PlanError` if the plan has not been executed.
        """
        runtime: "Mapping[str, dict] | None" = None
        if analyze:
            runtime = getattr(self, "_runtime", None)
            if runtime is None:
                raise PlanError(
                    "describe(analyze=True) requires the plan to have "
                    "been executed (run it through PlanExecutor or "
                    "Client.run first)")
        lines = [f"plan {self.pipeline_name} (code={self.code_hash})"]
        # EXPLAIN header: nodes compiled from SQL carry their original
        # query text (display metadata only — never cache material).
        for s in self.steps:
            qtext = getattr(s.node, "query", "")
            if qtext:
                lines.append(
                    f"  query[{s.node.name}]: {' '.join(qtext.split())}")
        for s in self.steps:
            el = (f" [elided null-checks: {sorted(s.elided_null_checks)}]"
                  if s.elided_null_checks else "")
            st = ""
            if s.input_stats:
                entries = sorted(s.input_stats.items())
                shown = [
                    f"{t} {v.describe() if hasattr(v, 'describe') else v}"
                    for t, v in entries[:_DESCRIBE_STATS_MAX]]
                if len(entries) > _DESCRIBE_STATS_MAX:
                    shown.append(
                        f"+{len(entries) - _DESCRIBE_STATS_MAX} more "
                        f"(of {len(entries)})")
                st = " [stats: " + "; ".join(shown) + "]"
            an = ""
            if runtime is not None:
                rt = runtime.get(s.node.name)
                if rt is None:
                    an = " [actual: not executed]"
                else:
                    rows = rt["rows_out"]
                    an = (f" [actual: cache={rt['cache']} "
                          f"rows={'?' if rows is None else rows} "
                          f"time={rt['wall_s'] * 1000:.2f}ms]")
            aux = "" if s.published else "(aux) "
            lines.append(
                f"  [wave {s.wave}] {aux}{s.report.describe()}{el}{st}{an}")
        if self.optimizer_passes:
            rewrites = [(s.node.name, p) for s in self.steps
                        for p in s.provenance]
            lines.append(
                f"  optimizer: passes="
                f"[{', '.join(self.optimizer_passes)}]; "
                f"rewrites={len(rewrites)}")
            for name, msg in rewrites:
                lines.append(f"    - {name}: {msg}")
        return "\n".join(lines)


def rebuild(base: Plan, steps: Sequence[PlanStep], *,
            optimizer_passes: "tuple[str, ...] | None" = None) -> Plan:
    """A new Plan over rewritten ``steps`` with waves recomputed.

    Rewrites move work across the DAG (a pushdown can shorten a
    critical path; a materialized shared filter adds a level), so the
    dependency levels recorded at plan() time are stale the moment a
    pass touches an edge — recompute them from the rewritten inputs.
    ``steps`` must be topologically ordered (passes preserve plan
    order and insert auxiliary steps before their first consumer).
    """
    node_wave: dict[str, int] = {}
    rewaved: list[PlanStep] = []
    for s in steps:
        wave = max((node_wave[t] + 1 for t in s.node.inputs.values()
                    if t in node_wave), default=0)
        node_wave[s.node.name] = wave
        rewaved.append(dataclasses.replace(s, wave=wave))
    return dataclasses.replace(
        base, steps=tuple(rewaved),
        optimizer_passes=(optimizer_passes
                          if optimizer_passes is not None
                          else base.optimizer_passes))


def plan(pipeline: Pipeline,
         table_stats: "Mapping[str, object] | None" = None) -> Plan:
    """Validate and compile a pipeline into an executable Plan.

    Raises errors at Moment.CONTROL_PLANE; nothing here touches data.
    ``table_stats`` optionally maps table names to
    :class:`repro.exec.stats.TableStats` (e.g. collected from catalog
    snapshots): each step records the stats of the inputs it reads in
    ``PlanStep.input_stats`` — control-plane metadata for the scheduler
    and the ``auto`` execution backend, never a correctness input.
    """
    # 1. structure: topo sort raises on cycles / missing inputs.
    order = pipeline.topo_order()

    # map table name -> schema as published by sources and earlier nodes
    published: dict[str, type[S.Schema]] = dict(pipeline.source_schemas)

    steps: list[PlanStep] = []
    node_wave: dict[str, int] = {}
    for node in order:
        # 2. contract composition: inputs must exist with known schemas.
        input_schemas: dict[str, type[S.Schema]] = {}
        for param, table in node.inputs.items():
            if table not in published:
                raise PlanError(
                    f"node {node.name!r}: input table {table!r} has no "
                    f"published schema")
            declared = node.input_schemas[param]
            actual = published[table]
            if declared.fingerprint() != actual.fingerprint():
                raise PlanError(
                    f"node {node.name!r}: declares input {param}: "
                    f"{declared.__name__} but upstream {table!r} publishes "
                    f"{actual.__name__} "
                    f"(declared={declared.names()}, actual={actual.names()})")
            input_schemas[table] = actual
        report = check_node(input_schemas, node.output_schema,
                            casts=node.casts)
        # 3. static discharge (only for inspectable nodes).
        elided = provable_postconditions(
            input_schemas, node.output_schema,
            inspectable=node.inspectable,
            null_preserving=node.null_preserving)
        # level scheduling: a node runs one wave after its deepest
        # upstream node; source-only nodes form wave 0 (DESIGN.md §8).
        wave = max((node_wave[t] + 1 for t in node.inputs.values()
                    if t in node_wave), default=0)
        node_wave[node.name] = wave
        stats = None
        if table_stats:
            stats = {t: table_stats[t] for t in node.inputs.values()
                     if t in table_stats} or None
        # 4. logical lowering (inspectable declarative nodes only).
        logical = (node.logical_tree()
                   if hasattr(node, "logical_tree") else None)
        steps.append(PlanStep(node=node, report=report,
                              elided_null_checks=elided, wave=wave,
                              input_stats=stats, logical=logical))
        published[node.name] = node.output_schema

    return Plan(pipeline_name=pipeline.name,
                code_hash=pipeline.code_hash(),
                steps=tuple(steps),
                source_schemas=dict(pipeline.source_schemas))
