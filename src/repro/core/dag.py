"""Pipeline DAGs: ``Table(s) -> Table`` nodes with typed contracts.

Bauplan restricts DAG nodes to the signature *Table(s) -> Table* (paper
§3.3) but is agnostic about what happens inside. We model two node kinds,
mirroring the paper's SQL/Python split:

- :class:`PythonNode` — an *imperative* transformation (arbitrary Python
  over :class:`~repro.data.tables.Table`). Not inspectable: casts must be
  declared, and no worker-side checks can be statically elided.
- :class:`DeclarativeNode` — a *declarative* transformation (select /
  filter / join expression trees). Inspectable: the planner extracts
  casts from ``arrow_cast`` markers and determines null-preservation,
  enabling Appendix-A-style static discharge of runtime checks.

The paper's authoring syntax is preserved: a node's parameters are
annotated with input schemas and default to the upstream table name, the
return annotation is the output schema (Listing 5)::

    @pipeline.node()
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        ...
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Any, Callable, Mapping, Sequence

from repro.core import schema as S
from repro.core.contracts import CastDecl
from repro.core.errors import PlanError
from repro.data.tables import Expr, Table

__all__ = ["Node", "PythonNode", "DeclarativeNode", "Pipeline"]


def _code_fingerprint(co) -> str:
    """Hash a code object: bytecode + data consts + referenced names,
    recursing into nested code objects (lambdas, comprehensions)."""
    h = hashlib.sha256()

    def fold(c):
        h.update(c.co_code)
        consts = tuple(x for x in c.co_consts if not hasattr(x, "co_code"))
        h.update(repr((consts, c.co_names)).encode())
        for x in c.co_consts:
            if hasattr(x, "co_code"):
                fold(x)

    fold(co)
    return h.hexdigest()[:16]


def _names_read(co) -> set[str]:
    """All global names a code object reads, including inside nested
    code objects (a lambda's global read is still this function's)."""
    names = set(co.co_names)
    for c in co.co_consts:
        if hasattr(c, "co_code"):
            names |= _names_read(c)
    return names


def _fingerprint_function(fn, seen: set[int]) -> str | None:
    """Fingerprint a Python function as cache-key material: its code
    (recursively, see :func:`_code_fingerprint`), its captured closure
    cells, and every module-global *data* value its bytecode reads —
    referenced helper functions are fingerprinted the same way, so a
    constant or global change inside a helper moves the key too.
    ``None`` = not faithfully fingerprintable (caller must not cache).
    """
    if id(fn) in seen:                 # recursion cycle: code already
        return f"fnrec:{fn.__qualname__}"  # folded at first visit
    seen.add(id(fn))
    parts = [f"code={_code_fingerprint(fn.__code__)}"]
    if fn.__closure__:
        for var, cell in zip(fn.__code__.co_freevars, fn.__closure__):
            try:
                v = cell.cell_contents
            except ValueError:          # pragma: no cover - empty cell
                parts.append(f"{var}=<unbound>")
                continue
            fp = _fingerprint_value(v, seen)
            if fp is None:
                return None
            parts.append(f"{var}={fp}")
    for name in sorted(_names_read(fn.__code__)):
        if name not in fn.__globals__:
            continue                    # builtin or pure attribute name
        v = fn.__globals__[name]
        if isinstance(v, type) or inspect.ismodule(v):
            continue                    # import-stable (DESIGN.md §8)
        fp = _fingerprint_value(v, seen)
        if fp is None:
            return None                 # mutable global data read
        parts.append(f"g:{name}={fp}")
    return "fn(" + ",".join(parts) + ")"


def _fingerprint_value(v: Any, seen: set[int] | None = None) -> str | None:
    """A stable fingerprint for a runtime value, or None.

    Only values whose ``repr`` is total and value-determined qualify:
    scalars, strings, and containers thereof. Python functions are
    fingerprinted structurally (:func:`_fingerprint_function`); C-level
    builtins by qualified name. Everything else — arbitrary objects
    (default id-based repr), numpy arrays (repr truncates), open
    handles — returns None: such values can mutate between runs without
    changing any printable identity, so a cache key built from them
    could serve stale outputs.
    """
    seen = seen if seen is not None else set()
    if v is None or isinstance(v, (bool, int, float, complex,
                                   str, bytes)):
        return repr(v)
    if isinstance(v, (tuple, list)):
        parts = [_fingerprint_value(x, seen) for x in v]
        if any(p is None for p in parts):
            return None
        return f"{type(v).__name__}({','.join(parts)})"
    if isinstance(v, (set, frozenset)):
        parts = [_fingerprint_value(x, seen) for x in v]
        if any(p is None for p in parts):
            return None
        return f"{type(v).__name__}({','.join(sorted(parts))})"
    if isinstance(v, dict):
        items = [(_fingerprint_value(k, seen), _fingerprint_value(x, seen))
                 for k, x in v.items()]
        if any(k is None or x is None for k, x in items):
            return None
        return "dict(" + ",".join(f"{k}:{x}"
                                  for k, x in sorted(items)) + ")"
    if inspect.isfunction(v):
        return _fingerprint_function(v, seen)
    if inspect.isbuiltin(v):            # C function: code is the binary
        return f"builtin:{getattr(v, '__module__', '?')}.{v.__qualname__}"
    return None


@dataclasses.dataclass(frozen=True)
class Node:
    """Common node metadata."""

    name: str                           # output table name
    inputs: Mapping[str, str]           # param name -> upstream table name
    input_schemas: Mapping[str, type[S.Schema]]
    output_schema: type[S.Schema]
    casts: tuple[CastDecl, ...] = ()
    inspectable: bool = False
    null_preserving: bool = False

    def run(self, tables: Mapping[str, Table]) -> Table:
        raise NotImplementedError

    def source(self) -> str:
        return f"<node {self.name}>"

    def cache_material(self) -> str | None:
        """Static half of the engine's content-addressed cache key: the
        transformation source, the declared output contract, and the
        declared casts. The dynamic half (input snapshot keys) is bound
        by :func:`repro.core.engine.cache_key` at execution time, which
        also folds in the active execution-backend name (DESIGN.md §9)
        — backend choice is runtime state, not node identity, so it is
        deliberately absent here. The node *name* is likewise excluded
        — two nodes computing the same function over the same inputs
        share one cache entry.
        ``None`` marks the node as not content-addressable (the engine
        always executes it)."""
        casts = ";".join(f"{c.column}->{c.to.name}" for c in self.casts)
        return (f"{self.source()}|"
                f"{self.output_schema.fingerprint()}|{casts}")


@dataclasses.dataclass(frozen=True)
class PythonNode(Node):
    fn: Callable[..., Table] = None  # type: ignore[assignment]

    def run(self, tables: Mapping[str, Table]) -> Table:
        kwargs = {param: tables[t] for param, t in self.inputs.items()}
        out = self.fn(**kwargs)
        if not isinstance(out, Table):
            raise PlanError(
                f"node {self.name!r} must return a Table, got "
                f"{type(out).__name__} (DAG nodes are Table(s) -> Table)")
        return out

    def source(self) -> str:
        try:
            return inspect.getsource(self.fn)
        except (OSError, TypeError):
            return f"<python {self.name}>"

    def cache_material(self) -> str | None:
        # Source text alone under-identifies a Python function: two
        # closures over different values share identical text, and
        # inspect.getsource can fail entirely (exec'd/REPL-defined
        # functions), collapsing source() to a name-only fallback.
        # _fingerprint_function folds in the recursive bytecode+consts
        # fingerprint, the captured closure cells, and every
        # module-global data value the bytecode (incl. nested lambdas
        # and referenced helper functions) reads. Anything that cannot
        # be fingerprinted faithfully — arbitrary objects, numpy arrays
        # (whose repr truncates) — makes the node UNCACHEABLE rather
        # than risking a stale hit; modules and classes are assumed
        # import-stable (DESIGN.md §8).
        if self.fn is None:     # pragma: no cover - defensive
            return None
        fp = _fingerprint_function(self.fn, set())
        if fp is None:
            return None
        return super().cache_material() + "|" + fp


@dataclasses.dataclass(frozen=True)
class DeclarativeNode(Node):
    """select(exprs) [after optional filter / join(s)] — inspectable.

    Joins form a left-deep chain: ``joins`` lists ``(table, on)`` pairs
    folded in order onto the first input (``join_with``/``join_on`` are
    the single-join sugar, normalized into ``joins``). The body is a
    fixed join -> filter -> group-by -> select shape, which is exactly
    what lowers to the logical IR (:meth:`logical_tree`) — the
    optimizer rewrites the IR, never this node. ``group_keys`` +
    ``agg_specs`` (normalized ``(fn, value, out)`` triples; see
    ``repro.data.tables.resolve_agg_specs``) lower to the ``Aggregate``
    op; when set, ``exprs`` project over the aggregate's output."""

    exprs: tuple[Expr, ...] = ()
    filter_expr: Expr | None = None
    join_with: str | None = None        # second input table name
    join_on: tuple[str, ...] = ()
    joins: tuple[tuple[str, tuple[str, ...]], ...] = ()
    join_how: str = "inner"
    group_keys: tuple[str, ...] = ()
    agg_specs: tuple[tuple[str, str, str], ...] = ()

    def __post_init__(self):
        if not self.joins and self.join_with is not None:
            object.__setattr__(
                self, "joins",
                ((self.join_with, tuple(self.join_on)),))
        # extract casts from arrow_cast markers; mark inspectable.
        # Membership-checked so the extraction is idempotent —
        # dataclasses.replace() re-runs __post_init__ on already-
        # extracted casts.
        casts = list(self.casts)
        for e in self.exprs:
            target = getattr(e, "cast_target", None)
            if target is not None:
                decl = CastDecl(e.output_name(), S.as_dtype(target))
                if decl not in casts:
                    casts.append(decl)
        object.__setattr__(self, "casts", tuple(casts))
        object.__setattr__(self, "inspectable", True)
        # select/filter/inner-join cannot introduce nulls into inherited
        # columns -> null-preserving (Appendix A condition (2)+(3)).
        # This claim assumes SQL join semantics: Table.join drops
        # null-keyed rows (NULL matches nothing), so an inner join only
        # ever *selects* existing rows. tests/test_engine.py keeps the
        # elided checks honest against the physical implementation.
        # A LEFT join manufactures NULLs in unmatched right columns, so
        # it does not preserve. Aggregation likewise manufactures NULLs
        # (an all-NULL group's SUM/MIN/MAX/MEAN is NULL), so a grouped
        # node never preserves.
        object.__setattr__(self, "null_preserving",
                           self.join_how == "inner"
                           and not self.agg_specs)

    def logical_tree(self):
        """Lower to the logical IR
        (join(s) -> filter -> aggregate -> select)."""
        from repro.core import logical as L
        (_, first_table), *_rest = list(self.inputs.items())
        op: "L.LogicalOp" = L.Scan(first_table)
        for t, on in self.joins:
            op = L.Join(op, L.Scan(t), on=tuple(on), how=self.join_how)
        if self.filter_expr is not None:
            op = L.Filter(op, self.filter_expr)
        if self.agg_specs:
            op = L.Aggregate(op, keys=tuple(self.group_keys),
                             specs=tuple(self.agg_specs))
        if self.exprs:
            op = L.Project(op, tuple(self.exprs))
        return op

    def run(self, tables: Mapping[str, Table]) -> Table:
        # single execution path: the node body IS its logical tree, so
        # direct runs and engine runs (which may execute a rewritten
        # tree instead) can never drift semantically.
        return self.logical_tree().execute(tables)

    def source(self) -> str:
        # describe() (structural, alias-surviving) rather than
        # output_name(): `lit(0.25) AS x` and `lit(0.5) AS x` must not
        # collide in the content-addressed cache.
        parts = [f"select {[e.describe() for e in self.exprs]}"]
        if self.agg_specs:
            specs = [f"{fn}({value})->{out}"
                     for fn, value, out in self.agg_specs]
            parts.append(
                f"group by {list(self.group_keys)} agg {specs}")
        if self.filter_expr is not None:
            parts.append(f"filter {self.filter_expr.describe()}")
        for t, on in self.joins:
            if self.join_how == "inner":
                parts.append(f"join {t} on {list(on)}")
            else:
                parts.append(f"join[{self.join_how}] {t} on {list(on)}")
        # the node name is intentionally absent (Pipeline.code_hash mixes
        # it in separately): cache keys identify the *function*, not the
        # output table it happens to be bound to.
        return f"<declarative: {'; '.join(parts)}>"

    def cache_material(self) -> str | None:
        # source() describes exprs structurally — but only expressions
        # built through the library constructors (col/lit/operators/
        # arrow_cast) carry a faithful structural description. A
        # hand-rolled Expr(fn, name) is opaque: two different fns under
        # one name would collide, so such nodes are uncacheable.
        exprs = list(self.exprs)
        if self.filter_expr is not None:
            exprs.append(self.filter_expr)
        if any(not getattr(e, "_structural", False) for e in exprs):
            return None
        return super().cache_material()


class Pipeline:
    """A named collection of nodes forming a DAG."""

    def __init__(self, name: str):
        self.name = name
        self._nodes: dict[str, Node] = {}
        self._source_schemas: dict[str, type[S.Schema]] = {}

    # -- source tables (exist in the lake already) ----------------------
    def source(self, table: str, schema: type[S.Schema]) -> None:
        self._source_schemas[table] = schema

    # -- authoring API ---------------------------------------------------
    def node(self, *, name: str | None = None,
             casts: Sequence[CastDecl] = ()) -> Callable:
        """Decorator for imperative (Python) nodes, paper Listing 5 style."""

        def deco(fn: Callable[..., Table]) -> Callable[..., Table]:
            sig = inspect.signature(fn)
            hints = dict(fn.__annotations__)
            if any(isinstance(v, str) for v in hints.values()):
                # PEP 563 (`from __future__ import annotations`): resolve
                # string annotations against the caller's frame so Schema
                # classes defined in function scope still work.
                frame = inspect.currentframe().f_back
                ns = dict(fn.__globals__)
                if frame is not None:
                    ns.update(frame.f_locals)
                hints = {k: (eval(v, ns) if isinstance(v, str) else v)  # noqa: S307
                         for k, v in hints.items()}
            inputs: dict[str, str] = {}
            input_schemas: dict[str, type[S.Schema]] = {}
            for param in sig.parameters.values():
                ann = hints.get(param.name)
                if ann is None or not (isinstance(ann, type)
                                       and issubclass(ann, S.Schema)):
                    raise PlanError(
                        f"node {fn.__name__!r}: parameter {param.name!r} "
                        f"must be annotated with a Schema")
                upstream = (param.default
                            if param.default is not inspect.Parameter.empty
                            else param.name)
                if callable(upstream) and hasattr(upstream, "_node_name_"):
                    upstream = upstream._node_name_
                inputs[param.name] = str(upstream)
                input_schemas[param.name] = ann
            ret = hints.get("return")
            if ret is None or not (isinstance(ret, type)
                                   and issubclass(ret, S.Schema)):
                raise PlanError(
                    f"node {fn.__name__!r}: missing Schema return annotation")
            node = PythonNode(
                name=name or fn.__name__, inputs=inputs,
                input_schemas=input_schemas, output_schema=ret,
                casts=tuple(casts), fn=fn)
            self.add(node)
            fn._node_name_ = node.name  # allow `= other_fn` defaults
            return fn
        return deco

    def sql(self, *, name: str, inputs: Mapping[str, str],
            input_schemas: Mapping[str, type[S.Schema]],
            output_schema: type[S.Schema],
            exprs: Sequence[Expr] = (),
            filter_expr: Expr | None = None,
            join_with: str | None = None,
            join_on: Sequence[str] = (),
            joins: Sequence[tuple[str, Sequence[str]]] = (),
            join_how: str = "inner",
            group_keys: Sequence[str] = (),
            agg_specs: Sequence[tuple] = ()) -> DeclarativeNode:
        """Register a declarative node (paper Listing 4's annotated SQL).

        ``joins`` is the multi-join form (a left-deep ``(table, on)``
        chain); ``join_with``/``join_on`` remain the single-join sugar.
        ``group_keys``/``agg_specs`` express GROUP BY: specs are
        ``(fn, value)`` or ``(fn, value, out)`` tuples, normalized here
        through the same :func:`~repro.data.tables.resolve_agg_specs`
        as the eager ``Table.group_by().agg()`` path, so both spell
        identical output columns.
        """
        from repro.data.tables import resolve_agg_specs
        if joins and (join_with is not None or join_on):
            raise PlanError(
                f"node {name!r}: pass either the single-join sugar "
                f"(join_with/join_on) or the joins chain, not both — "
                f"the sugar is normalized into joins, so mixing them "
                f"would silently drop one spelling")
        if agg_specs and not group_keys:
            raise PlanError(
                f"node {name!r}: agg_specs requires group_keys")
        node = DeclarativeNode(
            name=name, inputs=dict(inputs),
            input_schemas=dict(input_schemas), output_schema=output_schema,
            exprs=tuple(exprs), filter_expr=filter_expr,
            join_with=join_with, join_on=tuple(join_on),
            joins=tuple((t, tuple(on)) for t, on in joins),
            join_how=join_how, group_keys=tuple(group_keys),
            agg_specs=(resolve_agg_specs(group_keys, agg_specs)
                       if agg_specs else ()))
        self.add(node)
        return node

    def sql_query(self, *, name: str, query: str):
        """Register a node authored as SQL text (DESIGN.md §13).

        The query is parsed and compiled against everything visible in
        this pipeline — declared sources plus every node output
        registered so far — into a :class:`DeclarativeNode` carrying
        its logical tree, with the output contract *inferred* from the
        input contracts. Unknown tables/columns are compile-time
        PlanErrors naming the pipeline, with a nearest-name suggestion.
        The node then plans, optimizes, caches, and runs exactly like
        any hand-built declarative node.
        """
        # local import: repro.sql depends on this module.
        from repro.sql.compiler import compile_query
        schemas: dict[str, type[S.Schema]] = dict(self._source_schemas)
        for n, other in self._nodes.items():
            schemas[n] = other.output_schema
        compiled = compile_query(
            query, name=name, schemas=schemas,
            context=f"pipeline {self.name!r}")
        self.add(compiled.node)
        return compiled.node

    def add(self, node: Node) -> None:
        if node.name in self._nodes or node.name in self._source_schemas:
            raise PlanError(f"duplicate table/node name {node.name!r}")
        self._nodes[node.name] = node

    # -- structure --------------------------------------------------------
    @property
    def nodes(self) -> Mapping[str, Node]:
        return dict(self._nodes)

    @property
    def source_schemas(self) -> Mapping[str, type[S.Schema]]:
        return dict(self._source_schemas)

    def topo_order(self) -> list[Node]:
        """Topologically sorted nodes; raises PlanError on cycle/missing."""
        order: list[Node] = []
        state: dict[str, int] = {}  # 0=unvisited 1=visiting 2=done

        def visit(name: str, chain: tuple[str, ...]) -> None:
            if name in self._source_schemas:
                return
            node = self._nodes.get(name)
            if node is None:
                raise PlanError(
                    f"node {chain[-1]!r} reads table {name!r} which is "
                    f"neither a node output nor a declared source")
            st = state.get(name, 0)
            if st == 1:
                raise PlanError(
                    f"cycle detected: {' -> '.join(chain + (name,))}")
            if st == 2:
                return
            state[name] = 1
            for upstream in node.inputs.values():
                visit(upstream, chain + (name,))
            state[name] = 2
            order.append(node)

        for name in self._nodes:
            visit(name, ())
        return order

    def code_hash(self) -> str:
        h = hashlib.sha256()
        for node in sorted(self._nodes.values(), key=lambda n: n.name):
            h.update(node.name.encode())
            h.update(node.source().encode())
            h.update(node.output_schema.fingerprint().encode())
        return h.hexdigest()[:16]
