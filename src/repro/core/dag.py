"""Pipeline DAGs: ``Table(s) -> Table`` nodes with typed contracts.

Bauplan restricts DAG nodes to the signature *Table(s) -> Table* (paper
§3.3) but is agnostic about what happens inside. We model two node kinds,
mirroring the paper's SQL/Python split:

- :class:`PythonNode` — an *imperative* transformation (arbitrary Python
  over :class:`~repro.data.tables.Table`). Not inspectable: casts must be
  declared, and no worker-side checks can be statically elided.
- :class:`DeclarativeNode` — a *declarative* transformation (select /
  filter / join expression trees). Inspectable: the planner extracts
  casts from ``arrow_cast`` markers and determines null-preservation,
  enabling Appendix-A-style static discharge of runtime checks.

The paper's authoring syntax is preserved: a node's parameters are
annotated with input schemas and default to the upstream table name, the
return annotation is the output schema (Listing 5)::

    @pipeline.node()
    def child_table(df: ParentSchema = "parent_table") -> ChildSchema:
        ...
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
from typing import Any, Callable, Mapping, Sequence

from repro.core import schema as S
from repro.core.contracts import CastDecl
from repro.core.errors import PlanError
from repro.data.tables import Expr, Table

__all__ = ["Node", "PythonNode", "DeclarativeNode", "Pipeline"]


@dataclasses.dataclass(frozen=True)
class Node:
    """Common node metadata."""

    name: str                           # output table name
    inputs: Mapping[str, str]           # param name -> upstream table name
    input_schemas: Mapping[str, type[S.Schema]]
    output_schema: type[S.Schema]
    casts: tuple[CastDecl, ...] = ()
    inspectable: bool = False
    null_preserving: bool = False

    def run(self, tables: Mapping[str, Table]) -> Table:
        raise NotImplementedError

    def source(self) -> str:
        return f"<node {self.name}>"


@dataclasses.dataclass(frozen=True)
class PythonNode(Node):
    fn: Callable[..., Table] = None  # type: ignore[assignment]

    def run(self, tables: Mapping[str, Table]) -> Table:
        kwargs = {param: tables[t] for param, t in self.inputs.items()}
        out = self.fn(**kwargs)
        if not isinstance(out, Table):
            raise PlanError(
                f"node {self.name!r} must return a Table, got "
                f"{type(out).__name__} (DAG nodes are Table(s) -> Table)")
        return out

    def source(self) -> str:
        try:
            return inspect.getsource(self.fn)
        except (OSError, TypeError):
            return f"<python {self.name}>"


@dataclasses.dataclass(frozen=True)
class DeclarativeNode(Node):
    """select(exprs) [after optional filter / join] — inspectable."""

    exprs: tuple[Expr, ...] = ()
    filter_expr: Expr | None = None
    join_with: str | None = None        # second input table name
    join_on: tuple[str, ...] = ()

    def __post_init__(self):
        # extract casts from arrow_cast markers; mark inspectable.
        casts = list(self.casts)
        for e in self.exprs:
            target = getattr(e, "cast_target", None)
            if target is not None:
                casts.append(CastDecl(e.output_name(),
                                      S.as_dtype(target)))
        object.__setattr__(self, "casts", tuple(casts))
        object.__setattr__(self, "inspectable", True)
        # select/filter/inner-join cannot introduce nulls into inherited
        # columns -> null-preserving (Appendix A condition (2)+(3)).
        object.__setattr__(self, "null_preserving", True)

    def run(self, tables: Mapping[str, Table]) -> Table:
        (first_param, first_table), *rest = list(self.inputs.items())
        t = tables[first_table]
        if self.join_with is not None:
            t = t.join(tables[self.join_with], on=list(self.join_on))
        if self.filter_expr is not None:
            t = t.filter(self.filter_expr)
        if self.exprs:
            t = t.select(list(self.exprs))
        return t

    def source(self) -> str:
        parts = [f"select {[e.output_name() for e in self.exprs]}"]
        if self.filter_expr is not None:
            parts.append(f"filter {self.filter_expr.output_name()}")
        if self.join_with:
            parts.append(f"join {self.join_with} on {list(self.join_on)}")
        return f"<declarative {self.name}: {'; '.join(parts)}>"


class Pipeline:
    """A named collection of nodes forming a DAG."""

    def __init__(self, name: str):
        self.name = name
        self._nodes: dict[str, Node] = {}
        self._source_schemas: dict[str, type[S.Schema]] = {}

    # -- source tables (exist in the lake already) ----------------------
    def source(self, table: str, schema: type[S.Schema]) -> None:
        self._source_schemas[table] = schema

    # -- authoring API ---------------------------------------------------
    def node(self, *, name: str | None = None,
             casts: Sequence[CastDecl] = ()) -> Callable:
        """Decorator for imperative (Python) nodes, paper Listing 5 style."""

        def deco(fn: Callable[..., Table]) -> Callable[..., Table]:
            sig = inspect.signature(fn)
            hints = dict(fn.__annotations__)
            if any(isinstance(v, str) for v in hints.values()):
                # PEP 563 (`from __future__ import annotations`): resolve
                # string annotations against the caller's frame so Schema
                # classes defined in function scope still work.
                frame = inspect.currentframe().f_back
                ns = dict(fn.__globals__)
                if frame is not None:
                    ns.update(frame.f_locals)
                hints = {k: (eval(v, ns) if isinstance(v, str) else v)  # noqa: S307
                         for k, v in hints.items()}
            inputs: dict[str, str] = {}
            input_schemas: dict[str, type[S.Schema]] = {}
            for param in sig.parameters.values():
                ann = hints.get(param.name)
                if ann is None or not (isinstance(ann, type)
                                       and issubclass(ann, S.Schema)):
                    raise PlanError(
                        f"node {fn.__name__!r}: parameter {param.name!r} "
                        f"must be annotated with a Schema")
                upstream = (param.default
                            if param.default is not inspect.Parameter.empty
                            else param.name)
                if callable(upstream) and hasattr(upstream, "_node_name_"):
                    upstream = upstream._node_name_
                inputs[param.name] = str(upstream)
                input_schemas[param.name] = ann
            ret = hints.get("return")
            if ret is None or not (isinstance(ret, type)
                                   and issubclass(ret, S.Schema)):
                raise PlanError(
                    f"node {fn.__name__!r}: missing Schema return annotation")
            node = PythonNode(
                name=name or fn.__name__, inputs=inputs,
                input_schemas=input_schemas, output_schema=ret,
                casts=tuple(casts), fn=fn)
            self.add(node)
            fn._node_name_ = node.name  # allow `= other_fn` defaults
            return fn
        return deco

    def sql(self, *, name: str, inputs: Mapping[str, str],
            input_schemas: Mapping[str, type[S.Schema]],
            output_schema: type[S.Schema],
            exprs: Sequence[Expr] = (),
            filter_expr: Expr | None = None,
            join_with: str | None = None,
            join_on: Sequence[str] = ()) -> DeclarativeNode:
        """Register a declarative node (paper Listing 4's annotated SQL)."""
        node = DeclarativeNode(
            name=name, inputs=dict(inputs),
            input_schemas=dict(input_schemas), output_schema=output_schema,
            exprs=tuple(exprs), filter_expr=filter_expr,
            join_with=join_with, join_on=tuple(join_on))
        self.add(node)
        return node

    def add(self, node: Node) -> None:
        if node.name in self._nodes or node.name in self._source_schemas:
            raise PlanError(f"duplicate table/node name {node.name!r}")
        self._nodes[node.name] = node

    # -- structure --------------------------------------------------------
    @property
    def nodes(self) -> Mapping[str, Node]:
        return dict(self._nodes)

    @property
    def source_schemas(self) -> Mapping[str, type[S.Schema]]:
        return dict(self._source_schemas)

    def topo_order(self) -> list[Node]:
        """Topologically sorted nodes; raises PlanError on cycle/missing."""
        order: list[Node] = []
        state: dict[str, int] = {}  # 0=unvisited 1=visiting 2=done

        def visit(name: str, chain: tuple[str, ...]) -> None:
            if name in self._source_schemas:
                return
            node = self._nodes.get(name)
            if node is None:
                raise PlanError(
                    f"node {chain[-1]!r} reads table {name!r} which is "
                    f"neither a node output nor a declared source")
            st = state.get(name, 0)
            if st == 1:
                raise PlanError(
                    f"cycle detected: {' -> '.join(chain + (name,))}")
            if st == 2:
                return
            state[name] = 1
            for upstream in node.inputs.values():
                visit(upstream, chain + (name,))
            state[name] = 2
            order.append(node)

        for name in self._nodes:
            visit(name, ())
        return order

    def code_hash(self) -> str:
        h = hashlib.sha256()
        for node in sorted(self._nodes.values(), key=lambda n: n.name):
            h.update(node.name.encode())
            h.update(node.source().encode())
            h.update(node.output_schema.fingerprint().encode())
        return h.hexdigest()[:16]
