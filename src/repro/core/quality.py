"""Data-quality verifiers ("expectations") run inside transactional runs.

Paper §3.1: "Types also give Bauplan a principled handle on data quality
checks without additional tools" — verifiers are plain functions over the
transactional branch, run at step (3) of the §3.3 protocol. Any raise
aborts the run before publication.
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.core.errors import QualityError
from repro.data.tables import Table

__all__ = ["expect_not_null", "expect_unique", "expect_in_range",
           "expect_row_count", "expect_no_nan", "Verifier"]

Verifier = Callable[[Table], None]


def expect_not_null(column: str) -> Verifier:
    def check(t: Table) -> None:
        if t.has_nulls(column):
            raise QualityError(f"expectation failed: {column!r} has nulls")
    return check


def expect_unique(column: str) -> Verifier:
    def check(t: Table) -> None:
        vals = t.column(column)
        if len(np.unique(vals)) != len(vals):
            raise QualityError(
                f"expectation failed: {column!r} is not unique")
    return check


def expect_in_range(column: str, lo: float, hi: float) -> Verifier:
    def check(t: Table) -> None:
        vals = t.column(column)[t.validity(column)]
        if len(vals) and (vals.min() < lo or vals.max() > hi):
            raise QualityError(
                f"expectation failed: {column!r} not in [{lo}, {hi}] "
                f"(saw [{vals.min()}, {vals.max()}])")
    return check


def expect_row_count(lo: int, hi: int | None = None) -> Verifier:
    def check(t: Table) -> None:
        n = len(t)
        if n < lo or (hi is not None and n > hi):
            raise QualityError(
                f"expectation failed: row count {n} outside "
                f"[{lo}, {hi if hi is not None else 'inf'}]")
    return check


def expect_no_nan(column: str) -> Verifier:
    def check(t: Table) -> None:
        vals = t.column(column)
        if np.issubdtype(vals.dtype, np.floating) and np.isnan(vals).any():
            raise QualityError(f"expectation failed: {column!r} has NaNs")
    return check


def all_of(*verifiers: Verifier) -> Verifier:
    def check(t: Table) -> None:
        for v in verifiers:
            v(t)
    return check
