"""Core: the paper's contribution — contracts, versioning, transactions.

Public API re-exports for the composable surface used by examples, the
training framework, and tests.
"""
from repro.core.catalog import Catalog, Commit, Visibility
from repro.core.contracts import CastDecl, check_edge, check_node, validate_table
from repro.core.dag import DeclarativeNode, Pipeline, PythonNode
from repro.core.errors import (
    ContractAuthoringError, ContractCompositionError, ContractError,
    ContractRuntimeError, MergeConflict, Moment, PlanError, QualityError,
    RefConflict, ReproError, TransactionAborted, VisibilityError,
)
from repro.core.planner import Plan, plan
from repro.core.runner import Client, RunResult
from repro.core.schema import (
    BOOL, DATETIME, FLOAT, FLOAT32, INT, INT32, INT64, STR, Nullable,
    NotNull, Schema, TensorContract,
)
from repro.core.store import FileStore, MemoryStore, ObjectStore
from repro.core.transactions import RunRegistry, RunState, TransactionalRun

__all__ = [k for k in dir() if not k.startswith("_")]
