"""The worker: execute a validated Plan inside a transactional run.

Paper Figure 1 moments (2)→(3): the control plane hands a :class:`Plan`
to a worker; the worker reads source tables *from the pinned start
commit* (snapshot reads), executes the plan's dependency **waves**
concurrently through :class:`repro.core.engine.PlanExecutor` — skipping
any node whose content-addressed cache entry already names its output —
validates each output against its declared schema **before** persisting
(moment 3), then writes the run's outputs to the transactional branch
as ONE multi-table atomic commit, registers user verifiers on the
transaction (step 3 of §3.3), and publishes via the CAS +
rebase-and-revalidate protocol — all outputs of the run or none, and
``log()`` shows one commit per run, not one per node. On a publication
rebase the engine re-executes ONLY the nodes whose input snapshots
moved (DESIGN.md §8). If the run fails mid-DAG, exactly the validated
outputs are flushed to the (then ABORTED) branch so they remain
queryable for triage.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.catalog import Catalog
from repro.core.engine import NodeCache, PlanExecutor
from repro.core.errors import ExecutionError, TransactionAborted
from repro.core.planner import Plan
from repro.core.quality import Verifier
from repro.core.transactions import RunRegistry, RunState, TransactionalRun
from repro.data.tables import Table

__all__ = ["RunResult", "QueryResult", "Client"]

_NOOP_CTX = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class RunResult:
    state: RunState
    tables: Mapping[str, str]  # table -> snapshot key written by this run
    executed: tuple[str, ...] = ()  # nodes actually run (cache misses)
    cached: tuple[str, ...] = ()    # nodes satisfied from the cache
    # nodes re-executed per publication rebase (empty: published on the
    # first CAS attempt). All zeros = every rebase was fully incremental.
    rebase_reexecutions: tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Result of one :meth:`Client.sql` query (read-only: no commit).

    ``executed``/``cached`` expose the engine's verdict — a repeated
    query at the same commit is a pure cache hit (``executed == ()``),
    because the content-addressed key binds the compiled logical tree
    to the pinned input snapshots, never to the query text.
    """

    table: Table
    plan: "object"                 # the optimized Plan (EXPLAIN source)
    schema: type                   # inferred output contract
    snapshot: str                  # content-addressed result snapshot
    commit_id: str                 # the pinned commit queried
    query: str
    executed: tuple[str, ...] = ()
    cached: tuple[str, ...] = ()

    def describe(self, *, analyze: bool = False) -> str:
        """EXPLAIN: the optimized plan with query text and rewrite
        provenance. ``analyze=True`` adds per-step actuals (the query
        already executed, so runtime is always present here)."""
        return self.plan.describe(analyze=analyze)

    def fingerprint(self) -> str:
        return self.table.fingerprint()


class Client:
    """The user-facing API of paper Listing 6.

    Wraps a catalog + object store + run registry and exposes
    ``create_branch`` / ``run`` / ``merge`` / ``get_run``.
    """

    def __init__(self, catalog: Catalog | None = None,
                 registry: RunRegistry | None = None):
        self.catalog = catalog if catalog is not None else Catalog()
        self.registry = registry if registry is not None else RunRegistry()
        self.store = self.catalog.store
        # shared across this client's runs; persisted via store refs so
        # clients over one (file-backed) store share entries too.
        self.node_cache = NodeCache(self.store)
        # SQL front door memos, keyed by snapshot: discovered contracts
        # (manifest-only reads) and row-count stats — so a repeated
        # query at an unchanged commit touches no column data at all.
        self._sql_schemas: dict[tuple[str, str], type] = {}
        self._sql_stats: dict[str, object] = {}

    # -- Git-for-data surface (Listing 6) --------------------------------
    def create_branch(self, name: str, from_ref: str = "main", **kw):
        return self.catalog.create_branch(name, from_ref, **kw)

    def merge(self, source: str, into: str = "main", **kw):
        return self.catalog.merge(source, into=into, **kw)

    def get_run(self, run_id: str) -> RunState:
        return self.registry.get_run(run_id)

    def tag(self, name: str, ref: str) -> str:
        return self.catalog.tag(name, ref)

    # -- data access -------------------------------------------------------
    def write_source_table(self, branch: str, name: str, table: Table,
                           message: str = "") -> str:
        snap = table.to_blobs(self.store)
        self.catalog.write_table(branch, name, snap, message=message)
        return snap

    def read_table(self, ref: str, name: str) -> Table:
        snap = self.catalog.read_table(ref, name)
        return Table.from_blobs(self.store, snap)

    # -- SQL front door (DESIGN.md §13) ------------------------------------
    def _discover_schema(self, table: str, snapshot: str) -> type:
        from repro.sql.discovery import schema_from_snapshot
        key = (table, snapshot)
        if key not in self._sql_schemas:
            self._sql_schemas[key] = schema_from_snapshot(
                self.store, snapshot, table)
        return self._sql_schemas[key]

    def _snapshot_stats(self, snapshot: str):
        """Row-count stats from one column blob (not the whole table),
        memoized by snapshot so repeated queries at an unchanged commit
        never touch column data."""
        from repro.exec.stats import TableStats
        if snapshot not in self._sql_stats:
            manifest = self.store.get_json(snapshot)
            n = 0
            for m in manifest["columns"].values():
                n = len(self.store.get_array(m["values"]))
                break
            self._sql_stats[snapshot] = TableStats(n_rows=n)
        return self._sql_stats[snapshot]

    def sql(self, query: str, ref: str = "main", *,
            optimizer_passes: "Sequence[str] | None" = None,
            cache: bool = True) -> QueryResult:
        """Compile and execute one SQL SELECT against a pinned ref.

        Table discovery happens at ``ref``'s head commit: every catalog
        table is visible, its contract inferred from the snapshot
        manifest (dtypes + nullability; no column data is read to
        compile). Unknown tables/columns are compile-time errors naming
        the ref, with a nearest-name suggestion. The compiled logical
        tree flows through the standard pipeline: ``plan()`` with
        row-count stats, ``optimize()`` (``optimizer_passes=()`` skips
        optimization; ``None`` = the default passes), the stats-driven
        ``auto`` backend, and the content-addressed :class:`NodeCache`
        — so re-running any spelling of the same query at the same
        commit executes zero nodes. Reads are snapshot-isolated against
        the resolved commit; nothing is committed.
        """
        from repro.core.dag import Pipeline
        from repro.core.planner import plan as plan_fn
        from repro.obs import get_recorder
        from repro.optimizer import optimize
        from repro.sql.compiler import compile_query

        rec = get_recorder()
        sql_ctx = (rec.span("sql", ref=ref, query=query)
                   if rec.enabled else _NOOP_CTX)
        with sql_ctx as sql_span:
            commit = self.catalog.head(ref)
            if sql_span is not None:
                sql_span.set(commit=commit.id)
            context = f"ref {ref!r} (commit {commit.id})"
            schemas = {t: self._discover_schema(t, snap)
                       for t, snap in commit.tables.items()}
            name = "query"
            while name in commit.tables:
                name += "_"
            compiled = compile_query(query, name=name, schemas=schemas,
                                     context=context)

            pipeline = Pipeline("sql")
            for t in compiled.tables:
                pipeline.source(t, schemas[t])
            pipeline.add(compiled.node)
            stats = {t: self._snapshot_stats(commit.tables[t])
                     for t in compiled.tables}
            pl = plan_fn(pipeline, table_stats=stats)
            if optimizer_passes is None:
                pl = optimize(pl)
            elif optimizer_passes:
                pl = optimize(pl, optimizer_passes)

            engine = PlanExecutor(pl, self.store,
                                  cache=self.node_cache if cache else None)
            outcome = engine.execute(commit.tables.__getitem__)
            snap = outcome.snapshots[name]
            result = QueryResult(
                table=Table.from_blobs(self.store, snap),
                plan=pl, schema=compiled.output_schema, snapshot=snap,
                commit_id=commit.id, query=query,
                executed=outcome.executed, cached=outcome.cached)
            if sql_span is not None:
                sql_span.set(rows_out=result.table.num_rows,
                             executed=len(outcome.executed),
                             cached=len(outcome.cached))
            return result

    def _table_verifier(self, table: str,
                        checks: Sequence[Verifier]
                        ) -> Callable[[Callable[[str], str]], None]:
        """Adapt table-level quality checks to a txn verifier: re-reads
        the table from the (possibly rebased) branch so revalidation
        after a rebase checks exactly the state being published."""
        def run_checks(read: Callable[[str], str]) -> None:
            t = Table.from_blobs(self.store, read(table))
            for check in checks:
                check(t)
        return run_checks

    # -- the run API (§3.3 protocol over a full DAG plan) --------------------
    def run(self, plan: Plan, ref: str = "main", *,
            verifiers: Mapping[str, Sequence[Verifier]] | None = None,
            dry_run: bool = False,
            fail_after: str | None = None,
            max_publish_attempts: int | None = None,
            max_workers: int | None = None,
            cache: bool = True) -> RunResult:
        """Execute ``plan`` transactionally against branch ``ref``.

        Waves of independent nodes run concurrently; nodes whose
        content-addressed cache key already names an output snapshot are
        skipped (their snapshot is reused after re-validating the
        contract). ``verifiers`` maps table name -> quality checks run
        at step (3); they are registered on the transaction so
        publication can re-run them against a rebased state (DESIGN.md
        §7), and a rebase additionally re-executes the nodes whose input
        snapshots moved (DESIGN.md §8). ``fail_after`` (testing hook)
        injects a failure after the named node completes, to exercise
        the abort path deterministically. ``max_publish_attempts``
        bounds the CAS retry loop under heavy concurrent publication
        (default: TransactionalRun's). ``max_workers`` caps wave
        concurrency (1 = sequential); ``cache=False`` forces every node
        to execute.
        """
        if dry_run:
            # plan is already validated; nothing to execute.
            return RunResult(
                state=RunState(run_id="dry", ref=self.catalog.head(ref).id,
                               code_hash=plan.code_hash, target_branch=ref,
                               txn_branch="", status="dry"),
                tables={})

        verifiers = dict(verifiers or {})
        txn_kw = {}
        if max_publish_attempts is not None:
            txn_kw["max_publish_attempts"] = max_publish_attempts
        txn = TransactionalRun(self.catalog, ref, code=plan.code_hash,
                               registry=self.registry, **txn_kw)
        txn.begin()
        engine = PlanExecutor(plan, self.store,
                              cache=self.node_cache if cache else None,
                              max_workers=max_workers)
        source_names = plan.source_tables()

        def branch_sources() -> Callable[[str], str]:
            # snapshot reads: ALL sources resolve against one commit of
            # the txn branch (forked from the start commit, rebased only
            # by this run) — a consistent read set even if `ref` moves.
            pinned = self.catalog.read_tables(txn.branch, source_names)
            return pinned.__getitem__

        written: dict[str, str] = {}
        rebase_reexecutions: list[int] = []
        try:
            outcome = engine.execute(branch_sources(),
                                     fail_after=fail_after)
            written.update(outcome.snapshots)
            # ONE atomic commit for the whole DAG (log reflects runs) —
            # writing only snapshots that differ from the branch state,
            # so a fully-cached re-run publishes no new commit at all.
            current = self.catalog.tables(txn.branch)
            changed = {t: s for t, s in written.items()
                       if current.get(t) != s}
            txn.write_tables(
                changed,
                message=f"run {plan.pipeline_name} "
                        f"({len(written)} tables)")
            # step (3): quality verifiers on B', re-run on rebase.
            for table, checks in verifiers.items():
                if table in written:
                    txn.verify(self._table_verifier(table, checks))

            def reexecute(read: Callable[[str], str],
                          write_tables: Callable[..., None]) -> None:
                # after a rebase: re-derive from the rebased branch.
                # Unchanged inputs hit the cache (0 node executions);
                # only the changed subgraph runs. Write back only moved
                # snapshots, keeping the branch delta minimal.
                oc = engine.execute(branch_sources())
                cur = self.catalog.tables(txn.branch)
                delta = {t: s for t, s in oc.snapshots.items()
                         if cur.get(t) != s}
                if delta:
                    write_tables(
                        delta,
                        message=f"recompute {sorted(delta)} after rebase")
                written.update(oc.snapshots)
                rebase_reexecutions.append(len(oc.executed))

            txn.set_executor(reexecute)
            txn.commit()
        except ExecutionError as e:
            # flush EXACTLY the validated outputs (earlier waves + the
            # failing wave's validated siblings, in plan order) so the
            # ABORTED branch holds them for triage (§3.3 "preserved").
            if e.partial:
                try:
                    txn.write_tables(
                        e.partial, message="partial outputs before abort")
                except Exception:      # pragma: no cover - abort anyway
                    pass
            cause = e.cause or e
            txn.abort(cause)
            raise TransactionAborted(
                f"run {txn.run_id} aborted: {cause}", branch=txn.branch,
                cause=cause) from e
        except TransactionAborted:
            raise
        except Exception as e:         # pragma: no cover - safety net
            txn.abort(e)
            raise TransactionAborted(
                f"run {txn.run_id} aborted: {e}", branch=txn.branch,
                cause=e) from e
        return RunResult(state=self.registry.get_run(txn.run_id),
                         tables=written,
                         executed=outcome.executed,
                         cached=outcome.cached,
                         rebase_reexecutions=tuple(rebase_reexecutions))
