"""The worker: execute a validated Plan inside a transactional run.

Paper Figure 1 moments (2)→(3): the control plane hands a :class:`Plan`
to a worker; the worker reads source tables *from the pinned start
commit* (snapshot reads), executes nodes, validates each output against
its declared schema **before** persisting (moment 3), then writes ALL of
the run's outputs to the transactional branch as ONE multi-table atomic
commit, registers user verifiers on the transaction (step 3 of §3.3),
and publishes via the CAS + rebase-and-revalidate protocol — all outputs
of the run or none, and ``log()`` shows one commit per run, not one per
node. If the run fails mid-DAG, the outputs computed so far are flushed
to the (then ABORTED) branch so they remain queryable for triage.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.catalog import Catalog
from repro.core.contracts import validate_table
from repro.core.errors import TransactionAborted
from repro.core.planner import Plan
from repro.core.quality import Verifier
from repro.core.transactions import RunRegistry, RunState, TransactionalRun
from repro.data.tables import Table

__all__ = ["RunResult", "Client"]


@dataclasses.dataclass(frozen=True)
class RunResult:
    state: RunState
    tables: Mapping[str, str]  # table -> snapshot key written by this run


class Client:
    """The user-facing API of paper Listing 6.

    Wraps a catalog + object store + run registry and exposes
    ``create_branch`` / ``run`` / ``merge`` / ``get_run``.
    """

    def __init__(self, catalog: Catalog | None = None,
                 registry: RunRegistry | None = None):
        self.catalog = catalog if catalog is not None else Catalog()
        self.registry = registry if registry is not None else RunRegistry()
        self.store = self.catalog.store

    # -- Git-for-data surface (Listing 6) --------------------------------
    def create_branch(self, name: str, from_ref: str = "main", **kw):
        return self.catalog.create_branch(name, from_ref, **kw)

    def merge(self, source: str, into: str = "main", **kw):
        return self.catalog.merge(source, into=into, **kw)

    def get_run(self, run_id: str) -> RunState:
        return self.registry.get_run(run_id)

    def tag(self, name: str, ref: str) -> str:
        return self.catalog.tag(name, ref)

    # -- data access -------------------------------------------------------
    def write_source_table(self, branch: str, name: str, table: Table,
                           message: str = "") -> str:
        snap = table.to_blobs(self.store)
        self.catalog.write_table(branch, name, snap, message=message)
        return snap

    def read_table(self, ref: str, name: str) -> Table:
        snap = self.catalog.read_table(ref, name)
        return Table.from_blobs(self.store, snap)

    def _table_verifier(self, table: str,
                        checks: Sequence[Verifier]
                        ) -> Callable[[Callable[[str], str]], None]:
        """Adapt table-level quality checks to a txn verifier: re-reads
        the table from the (possibly rebased) branch so revalidation
        after a rebase checks exactly the state being published."""
        def run_checks(read: Callable[[str], str]) -> None:
            t = Table.from_blobs(self.store, read(table))
            for check in checks:
                check(t)
        return run_checks

    # -- the run API (§3.3 protocol over a full DAG plan) --------------------
    def run(self, plan: Plan, ref: str = "main", *,
            verifiers: Mapping[str, Sequence[Verifier]] | None = None,
            dry_run: bool = False,
            fail_after: str | None = None,
            max_publish_attempts: int | None = None) -> RunResult:
        """Execute ``plan`` transactionally against branch ``ref``.

        ``verifiers`` maps table name -> quality checks run at step (3);
        they are registered on the transaction so publication can re-run
        them against a rebased state (DESIGN.md §7).
        ``fail_after`` (testing hook) injects a failure after the named
        node completes, to exercise the abort path deterministically.
        ``max_publish_attempts`` bounds the CAS retry loop under heavy
        concurrent publication (default: TransactionalRun's).
        """
        if dry_run:
            # plan is already validated; nothing to execute.
            return RunResult(
                state=RunState(run_id="dry", ref=self.catalog.head(ref).id,
                               code_hash=plan.code_hash, target_branch=ref,
                               txn_branch="", status="dry"),
                tables={})

        verifiers = dict(verifiers or {})
        written: dict[str, str] = {}
        txn_kw = {}
        if max_publish_attempts is not None:
            txn_kw["max_publish_attempts"] = max_publish_attempts
        txn = TransactionalRun(self.catalog, ref, code=plan.code_hash,
                               registry=self.registry, **txn_kw)
        txn.begin()
        # snapshot reads: sources resolve against the txn branch head,
        # which was forked from the start commit — reads are stable even
        # if `ref` moves concurrently.
        cache: dict[str, Table] = {}

        def load(table: str) -> Table:
            if table not in cache:
                snap = self.catalog.read_table(txn.branch, table)
                cache[table] = Table.from_blobs(self.store, snap)
            return cache[table]

        try:
            for step in plan.steps:
                node = step.node
                inputs = {t: load(t) for t in node.inputs.values()}
                out = node.run(inputs)
                # moment (3): validate physical data BEFORE persisting.
                validate_table(out, node.output_schema,
                               elide=step.elided_null_checks,
                               name=node.name)
                snap = out.to_blobs(self.store)
                written[node.name] = snap
                cache[node.name] = out
                if fail_after == node.name:
                    raise RuntimeError(
                        f"injected failure after node {node.name!r}")
            # ONE atomic commit for the whole DAG (log reflects runs).
            txn.write_tables(
                written,
                message=f"run {plan.pipeline_name} "
                        f"({len(written)} tables)")
            # step (3): quality verifiers on B', re-run on rebase.
            for table, checks in verifiers.items():
                if table in written:
                    txn.verify(self._table_verifier(table, checks))
            txn.commit()
        except TransactionAborted:
            raise
        except Exception as e:
            # flush the outputs computed so far onto the branch so the
            # ABORTED branch holds them for triage (§3.3 "preserved").
            if written:
                try:
                    txn.write_tables(
                        written, message="partial outputs before abort")
                except Exception:      # pragma: no cover - abort anyway
                    pass
            txn.abort(e)
            raise TransactionAborted(
                f"run {txn.run_id} aborted: {e}", branch=txn.branch,
                cause=e) from e
        return RunResult(state=self.registry.get_run(txn.run_id),
                         tables=written)
