"""Logical-plan IR: the rewrite target of the optimizer (DESIGN.md §11).

A :class:`DeclarativeNode` lowers to a small tree of relational ops —
``Scan`` / ``Filter`` / ``Project`` / ``Aggregate`` / ``Join`` /
``Reorder`` — that the
optimizer's ``Plan -> Plan`` passes restructure (pushdown, reordering,
pruning, probe fusion) and the engine executes in place of the node's
original body. The IR is deliberately tiny: it models exactly the
declarative subset whose semantics the contracts make checkable, which
is what keeps every rewrite *provable* (the differential suite pins
optimized against unoptimized execution bit for bit) instead of
hopeful.

Design rules:

- ops are frozen dataclasses; a rewrite builds new trees, never mutates;
- ``describe()`` is structural and total — it is cache-key material
  (``PlanStep.cache_material`` folds it), so two trees computing
  different results must never describe identically. That holds only
  when every embedded expression is ``_structural``;
  :meth:`LogicalOp.is_structural` gates caching exactly like
  ``DeclarativeNode.cache_material``;
- execution dispatches through the *active* execution backend
  (``repro.exec``), same as the Table layer — the IR adds no physical
  operator of its own except ``Reorder``'s row-order restoration;
- per-op stats: ``Scan`` forwards the planner-collected ``TableStats``
  of its table; every other op yields ``None`` — a downstream consumer
  (the ``auto`` backend via ``accepts_join_stats``) then measures the
  *post-rewrite* intermediate exactly once at dispatch, which is the
  honest input for backend selection after a rewrite changed the data.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro import exec as exec_backends
from repro.data.tables import Expr, Table, _ColumnData

__all__ = ["LogicalOp", "Scan", "Filter", "Project", "Aggregate",
           "Join", "Reorder", "Sort", "Limit"]


def _pred_mask(t: Table, pred: Expr | None) -> np.ndarray | None:
    if pred is None:
        return None
    mask, valid = pred.evaluate(t)
    mask = np.asarray(mask, dtype=bool)
    if valid is not None:
        mask = mask & valid      # SQL semantics: NULL predicate = drop
    return mask


class LogicalOp:
    """Base of the IR ops (frozen dataclasses; see module docstring)."""

    def children(self) -> tuple["LogicalOp", ...]:
        return ()

    def _own_exprs(self) -> tuple[Expr, ...]:
        return ()

    def describe(self) -> str:
        raise NotImplementedError

    def is_structural(self) -> bool:
        """True iff ``describe()`` faithfully identifies the computation
        — i.e. every expression anywhere in the tree was built through
        the library constructors. Mirrors the uncacheable-node rule of
        ``DeclarativeNode.cache_material``."""
        return (all(getattr(e, "_structural", False)
                    for e in self._own_exprs())
                and all(c.is_structural() for c in self.children()))

    def scan_tables(self) -> set[str]:
        out: set[str] = set()
        for c in self.children():
            out |= c.scan_tables()
        return out

    def execute(self, tables: Mapping[str, Table],
                stats: "Mapping[str, object] | None" = None) -> Table:
        return self._exec(tables, stats or {})[0]

    def _exec(self, tables, stats):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Scan(LogicalOp):
    """Read one input table, optionally keeping only ``columns``.

    Column pruning is zero-copy (the kept ``_ColumnData`` objects are
    shared) and order-preserving (physical column order of the source,
    filtered). ``columns=None`` means all."""

    table: str
    columns: tuple[str, ...] | None = None

    def describe(self) -> str:
        if self.columns is None:
            return f"scan({self.table})"
        return f"scan({self.table}, cols={sorted(self.columns)})"

    def scan_tables(self) -> set[str]:
        return {self.table}

    def _exec(self, tables, stats):
        t = tables[self.table]
        if self.columns is not None:
            keep = set(self.columns)
            t = Table(_data={n: t._data[n] for n in t.column_names()
                             if n in keep})
        return t, stats.get(self.table)


@dataclasses.dataclass(frozen=True)
class Filter(LogicalOp):
    child: LogicalOp
    pred: Expr

    def children(self):
        return (self.child,)

    def _own_exprs(self):
        return (self.pred,)

    def describe(self) -> str:
        return f"filter({self.pred.describe()}, {self.child.describe()})"

    def _exec(self, tables, stats):
        t, _ = self.child._exec(tables, stats)
        return t.filter(self.pred), None


@dataclasses.dataclass(frozen=True)
class Project(LogicalOp):
    child: LogicalOp
    exprs: tuple[Expr, ...]

    def children(self):
        return (self.child,)

    def _own_exprs(self):
        return self.exprs

    def describe(self) -> str:
        return (f"project({[e.describe() for e in self.exprs]}, "
                f"{self.child.describe()})")

    def _exec(self, tables, stats):
        t, _ = self.child._exec(tables, stats)
        return t.select(list(self.exprs)), None


@dataclasses.dataclass(frozen=True)
class Aggregate(LogicalOp):
    """Multi-function GROUP BY: one output row per distinct key tuple
    (first-appearance order), key columns first, then one column per
    ``(fn, value, out)`` spec. Semantics are the execution backends'
    ``group_by_agg`` contract (``repro.exec.base``): SQL NULL handling,
    the reference backend as the bit-for-bit oracle, float SUM/MEAN
    exact only up to summation order.

    ``strategy`` is physical routing, not semantics: ``"auto"`` (the
    default) dispatches through the active backend; ``"partial"`` — set
    only by the optimizer's ``partial_agg`` rewrite — requests the
    sharded backend's pre-exchange partial aggregation, degrading to
    the active backend when no mesh backend is available (every
    strategy computes the same table; only float summation order can
    differ, which is exactly why a non-default strategy is rendered in
    ``describe()`` and therefore moves the cache key)."""

    child: LogicalOp
    keys: tuple[str, ...]
    specs: tuple[tuple[str, str, str], ...]
    strategy: str = "auto"

    def children(self):
        return (self.child,)

    def describe(self) -> str:
        specs = [f"{fn}({value})->{out}" for fn, value, out in self.specs]
        strat = "" if self.strategy == "auto" \
            else f", strategy={self.strategy}"
        return (f"aggregate(keys={list(self.keys)}, specs={specs}"
                f"{strat}, {self.child.describe()})")

    def _exec(self, tables, stats):
        t, ts = self.child._exec(tables, stats)
        be = exec_backends.resolve(None)
        if self.strategy == "partial":
            try:
                be = exec_backends.get_backend("sharded")
            except (KeyError, exec_backends.BackendUnavailable):
                pass    # no mesh on this install; any backend is correct
        kwargs = {}
        if getattr(be, "accepts_group_stats", False):
            kwargs = {"stats": ts}
        cols = be.group_by_agg(t._to_cols(), self.keys, self.specs,
                               **kwargs)
        return Table._from_cols(cols), None


@dataclasses.dataclass(frozen=True)
class Join(LogicalOp):
    """Hash join; ``left_pred``/``right_pred`` are filter predicates
    fused into the probe (the probe-fusion rewrite's target) — the
    semantics are filter-each-side-then-join, realized through
    ``Backend.masked_hash_join`` so backends can skip the intermediate
    materialization."""

    left: LogicalOp
    right: LogicalOp
    on: tuple[str, ...]
    how: str = "inner"
    left_pred: Expr | None = None
    right_pred: Expr | None = None

    def children(self):
        return (self.left, self.right)

    def _own_exprs(self):
        return tuple(p for p in (self.left_pred, self.right_pred)
                     if p is not None)

    def describe(self) -> str:
        parts = [self.left.describe(), self.right.describe(),
                 f"on={sorted(self.on)}", f"how={self.how}"]
        if self.left_pred is not None:
            parts.append(f"lpred={self.left_pred.describe()}")
        if self.right_pred is not None:
            parts.append(f"rpred={self.right_pred.describe()}")
        return f"join({', '.join(parts)})"

    def _exec(self, tables, stats):
        lt, ls = self.left._exec(tables, stats)
        rt, rs = self.right._exec(tables, stats)
        be = exec_backends.resolve(None)
        kwargs = {}
        if getattr(be, "accepts_join_stats", False):
            kwargs = {"left_stats": ls, "right_stats": rs}
        if self.left_pred is None and self.right_pred is None:
            cols = be.hash_join(lt._to_cols(), rt._to_cols(),
                                tuple(self.on), self.how, **kwargs)
        else:
            cols = be.masked_hash_join(
                lt._to_cols(), rt._to_cols(), tuple(self.on), self.how,
                left_mask=_pred_mask(lt, self.left_pred),
                right_mask=_pred_mask(rt, self.right_pred), **kwargs)
        return Table._from_cols(cols), None


@dataclasses.dataclass(frozen=True)
class Sort(LogicalOp):
    """Stable multi-key sort (the SQL ORDER BY target).

    ``keys`` are ``(column, ascending)`` pairs, primary key first. SQL
    NULL placement: NULLs sort *last* under ASC and *first* under DESC
    (the larger-than-everything convention). Float NaN follows the same
    convention as a quasi-NULL payload: last under ASC, first under
    DESC (``np.unique`` orders NaN after every finite value). Ties keep
    the child's row order (stability via a final row-id tiebreak), so
    the output is a deterministic function of the child table alone —
    no backend dispatch, same as ``Reorder``'s restoration lexsort."""

    child: LogicalOp
    keys: tuple[tuple[str, bool], ...]

    def children(self):
        return (self.child,)

    def describe(self) -> str:
        keys = [f"{name} {'asc' if asc else 'desc'}"
                for name, asc in self.keys]
        return f"sort(keys={keys}, {self.child.describe()})"

    def _exec(self, tables, stats):
        t, _ = self.child._exec(tables, stats)
        n = len(t)
        # np.lexsort: LAST key is primary -> build (tiebreak, k_last,
        # ..., k_first). Per-key dense ranks via np.unique make object
        # (str) and datetime columns sortable uniformly and give NULLs
        # an explicit rank slot.
        lex: list[np.ndarray] = [np.arange(n, dtype=np.int64)]
        for name, asc in reversed(self.keys):
            c = t._data[name]
            ok = (c.valid if c.valid is not None
                  else np.ones(n, dtype=bool))
            rank = np.zeros(n, dtype=np.int64)
            if ok.any():
                _, inv = np.unique(c.values[ok], return_inverse=True)
                rank[ok] = inv
            k = int(rank.max()) + 1 if n else 0
            rank[~ok] = k            # NULLs above every value...
            if not asc:
                rank = -rank         # ...so DESC puts them first
            lex.append(rank)
        perm = np.lexsort(tuple(lex))
        data = {nm: _ColumnData(
            c.values[perm],
            None if c.valid is None else c.valid[perm])
            for nm, c in t._data.items()}
        return Table(_data=data), None


@dataclasses.dataclass(frozen=True)
class Limit(LogicalOp):
    """Keep the first ``n`` rows of the child (SQL LIMIT)."""

    child: LogicalOp
    n: int

    def children(self):
        return (self.child,)

    def describe(self) -> str:
        return f"limit({self.n}, {self.child.describe()})"

    def _exec(self, tables, stats):
        t, _ = self.child._exec(tables, stats)
        if len(t) <= self.n:
            return t, None
        data = {nm: _ColumnData(
            c.values[:self.n],
            None if c.valid is None else c.valid[:self.n])
            for nm, c in t._data.items()}
        return Table(_data=data), None


@dataclasses.dataclass(frozen=True)
class Reorder(LogicalOp):
    """An all-inner left-deep join chain executed in a cost-chosen
    ``order``, with the original row/column order restored afterwards.

    ``sides`` are ``(op, on)`` pairs as authored; ``order`` permutes
    their *execution*. Soundness (why bit-for-bit holds): the emitted
    match combinations form a duplicate-free set independent of join
    order; the canonical left-deep emission order is lexicographic in
    (base row, side-0 row, side-1 row, ...) because each inner join
    emits left rows in order with matches in right-occurrence order —
    so tagging every input with a row id, joining in the chosen order,
    and lexsorting on the ids reproduces the canonical order exactly.
    Column copies are order-independent because the rewrite requires
    pairwise-disjoint side column sets (base stays leftmost, so
    base-vs-side shadowing resolves to the base copy in every order).
    The restoration lexsort is the price of bit-for-bit; the win is
    probing small tables first."""

    base: LogicalOp
    sides: tuple[tuple[LogicalOp, tuple[str, ...]], ...]
    order: tuple[int, ...]

    def children(self):
        return (self.base,) + tuple(op for op, _ in self.sides)

    def describe(self) -> str:
        sides = ", ".join(f"({op.describe()}, on={sorted(on)})"
                          for op, on in self.sides)
        return (f"reorder(base={self.base.describe()}, "
                f"sides=[{sides}], order={list(self.order)})")

    def _exec(self, tables, stats):
        bt, _ = self.base._exec(tables, stats)
        side_tabs = [op._exec(tables, stats)[0] for op, _ in self.sides]

        # canonical output column order: base's, then each side's new
        # columns in *authored* side order (left-copy-wins).
        seen = set(bt.column_names())
        canon_cols = list(bt.column_names())
        for st in side_tabs:
            for n in st.column_names():
                if n not in seen:
                    seen.add(n)
                    canon_cols.append(n)

        rid = [f"__reorder_rowid{i}__" for i in range(len(side_tabs) + 1)]
        if any(r in seen for r in rid):
            # row-id name collision with a physical column: fall back
            # to the canonical fold (correct, just unoptimized).
            t = bt
            for (op, on), st in zip(self.sides, side_tabs):
                t = t.join(st, on=list(on), how="inner")
            return t, None

        def tag(t: Table, name: str) -> Table:
            data = dict(t._data)
            data[name] = _ColumnData(np.arange(len(t), dtype=np.int64))
            return Table(_data=data)

        acc = tag(bt, rid[0])
        for k in self.order:
            acc = acc.join(tag(side_tabs[k], rid[k + 1]),
                           on=list(self.sides[k][1]), how="inner")

        ids = tuple(acc.column(r) for r in rid)
        # np.lexsort: LAST key is primary -> reversed puts the base row
        # id first. Id tuples are unique (duplicate-free match set), so
        # stability never matters.
        perm = np.lexsort(tuple(reversed(ids)))
        data = {}
        for n in canon_cols:
            c = acc._data[n]
            data[n] = _ColumnData(
                c.values[perm],
                None if c.valid is None else c.valid[perm])
        return Table(_data=data), None
