"""Typed table contracts (paper §3.1, Listings 3–5, Appendix A).

A :class:`Schema` is an explicit, machine-checkable description of the
columns that flow across a pipeline boundary. Schemas are authored either
with the class syntax of the paper::

    class ParentSchema(Schema):
        col1: str
        col2: datetime
        _S:   int

    class ChildSchema(Schema):
        col2: datetime              # inherited type (checked by lineage)
        col4: float                 # fresh
        col5: Nullable[str]         # fresh, nullable (UNION(str, None))

    class FriendSchema(Schema):     # Appendix A: explicit inheritance
        col2 = ChildSchema.col2         # inherited
        col4 = Grand.col4               # inherited from a second input
        col5 = ChildSchema.col5[NotNull]  # inherited, null-ness *narrowed*

or programmatically (``Schema.of(col1=STR, ...)``). Columns carry a
logical type, nullability, and — when authored by reference — an explicit
*lineage* pointer to the (schema, column) they inherit from.

Type *narrowing* (e.g. ``float → int``) is legal across an edge only when
the consuming transformation declares an explicit cast (paper Listing 5);
the composition rules live in :mod:`repro.core.contracts`.

:class:`TensorContract` extends the same idea to array-valued pipeline
artifacts (parameter pytrees, activations): shape / dtype / sharding are
the "columns" of a tensor, checked with ``jax.eval_shape`` at the control
plane and against concrete arrays at the worker.
"""
from __future__ import annotations

import dataclasses
import datetime as _dt
from typing import Any, Mapping, Sequence

from repro.core.errors import ContractAuthoringError

__all__ = [
    "DType", "INT", "FLOAT", "STR", "BOOL", "DATETIME",
    "Nullable", "NotNull", "Column", "ColumnRef", "Schema",
    "TensorContract", "narrowable", "widenable",
]


# ---------------------------------------------------------------------------
# Logical column types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DType:
    """A logical column type with a total widening order within a family."""

    name: str
    family: str     # "int" | "float" | "str" | "bool" | "datetime"
    rank: int       # widening rank within the family (higher = wider)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return self.name


INT8 = DType("int8", "int", 0)
INT16 = DType("int16", "int", 1)
INT32 = DType("int32", "int", 2)
INT64 = DType("int64", "int", 3)
FLOAT16 = DType("float16", "float", 0)
BFLOAT16 = DType("bfloat16", "float", 0)
FLOAT32 = DType("float32", "float", 1)
FLOAT64 = DType("float64", "float", 2)
STR = DType("str", "str", 0)
BOOL = DType("bool", "bool", 0)
DATETIME = DType("datetime", "datetime", 0)

# Default ranks for Python annotation types (paper's class syntax).
INT = INT64
FLOAT = FLOAT64

_PY_TO_DTYPE: dict[Any, DType] = {
    int: INT, float: FLOAT, str: STR, bool: BOOL,
    _dt.datetime: DATETIME,
    "int": INT, "float": FLOAT, "str": STR, "bool": BOOL,
    "datetime": DATETIME,
}

_NAME_TO_DTYPE = {d.name: d for d in
                  (INT8, INT16, INT32, INT64, FLOAT16, BFLOAT16,
                   FLOAT32, FLOAT64, STR, BOOL, DATETIME)}


def as_dtype(t: Any) -> DType:
    if isinstance(t, DType):
        return t
    if isinstance(t, _NullableMarker):
        raise ContractAuthoringError(
            "Nullable[...] resolved outside of a column position")
    if t in _PY_TO_DTYPE:
        return _PY_TO_DTYPE[t]
    if isinstance(t, str) and t in _NAME_TO_DTYPE:
        return _NAME_TO_DTYPE[t]
    raise ContractAuthoringError(f"unsupported column type: {t!r}")


def narrowable(src: DType, dst: DType) -> bool:
    """True if ``src`` can be *narrowed* to ``dst`` via an explicit cast.

    Narrowing is only defined within or across numeric families
    (float→int, int with smaller rank, float with smaller rank).
    """
    if src == dst:
        return True
    if src.family == dst.family:
        return dst.rank < src.rank
    return src.family == "float" and dst.family == "int"


def widenable(src: DType, dst: DType) -> bool:
    """True if ``src`` flows to ``dst`` with *no* cast (identity or widening)."""
    if src == dst:
        return True
    if src.family == dst.family:
        return dst.rank > src.rank
    return src.family == "int" and dst.family == "float"


# ---------------------------------------------------------------------------
# Nullability markers
# ---------------------------------------------------------------------------

class _NullableMarker:
    """``Nullable[str]`` ≈ the paper's ``UNION(str, None)``."""

    def __init__(self, inner: Any):
        self.inner = inner

    def __class_getitem__(cls, inner: Any) -> "_NullableMarker":
        return cls(inner)


class Nullable(_NullableMarker):
    pass


class _NotNullTag:
    """``ChildSchema.col5[NotNull]`` — narrow nullability on inheritance."""

    def __repr__(self) -> str:  # pragma: no cover
        return "NotNull"


NotNull = _NotNullTag()


# ---------------------------------------------------------------------------
# Columns
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Column:
    """A single column contract."""

    name: str
    dtype: DType
    nullable: bool = False
    # lineage: fully-qualified "<SchemaName>.<col>" this column inherits from,
    # or None for a fresh column.
    inherited_from: str | None = None

    def with_name(self, name: str) -> "Column":
        return dataclasses.replace(self, name=name)

    def __getitem__(self, tag: Any) -> "Column":
        # Appendix A: `ChildSchema.col5[NotNull]` — explicit null filtering.
        if tag is NotNull or isinstance(tag, _NotNullTag):
            return dataclasses.replace(self, nullable=False)
        raise ContractAuthoringError(f"unknown column tag: {tag!r}")

    def describe(self) -> str:
        n = "?" if self.nullable else ""
        lin = f" <- {self.inherited_from}" if self.inherited_from else ""
        return f"{self.name}: {self.dtype.name}{n}{lin}"


class ColumnRef(Column):
    """Alias kept for API clarity: a Column obtained via ``Schema.col``."""


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------

class _SchemaMeta(type):
    """Metaclass implementing the paper's class-based schema syntax."""

    def __new__(mcls, name, bases, ns):
        cls = super().__new__(mcls, name, bases, ns)
        if ns.get("_abstract_", False):
            cls._columns_ = {}
            return cls
        columns: dict[str, Column] = {}
        # inherited (python-level) columns from base Schemas
        for base in bases:
            columns.update(getattr(base, "_columns_", {}))
        # 1) annotation syntax: `col: type`
        for cname, ann in ns.get("__annotations__", {}).items():
            if cname.startswith("__"):
                continue
            nullable = False
            t = ann
            if isinstance(t, _NullableMarker):
                nullable, t = True, t.inner
            columns[cname] = Column(cname, as_dtype(t), nullable=nullable)
        # 2) assignment syntax: `col = OtherSchema.other_col` (Appendix A)
        for cname, val in ns.items():
            if cname.startswith("_") or cname in columns:
                continue
            if isinstance(val, Column):
                # `val.inherited_from` was stamped with "<Owner>.<col>" when
                # the owning schema class re-exposed it as an attribute.
                columns[cname] = dataclasses.replace(val, name=cname)
        cls._columns_ = columns
        # re-expose columns as attributes carrying owner info so that
        # `MySchema.col` can be used for inheritance in *other* schemas.
        for cname, col in columns.items():
            owned = dataclasses.replace(
                col, inherited_from=col.inherited_from or f"{name}.{cname}")
            setattr(cls, cname, owned)
        return cls

    def __iter__(cls):
        return iter(cls._columns_.values())


class Schema(metaclass=_SchemaMeta):
    """Base class for table contracts (the paper's ``BauplanSchema``)."""

    _abstract_ = True
    _columns_: dict[str, Column] = {}

    # -- programmatic construction -------------------------------------
    @classmethod
    def of(cls, __name: str = "AnonymousSchema", **cols: Any) -> type["Schema"]:
        ns: dict[str, Any] = {"__annotations__": {}}
        for cname, t in cols.items():
            if isinstance(t, Column):
                ns[cname] = t
            else:
                ns["__annotations__"][cname] = t
        return _SchemaMeta(__name, (Schema,), ns)

    # -- introspection ---------------------------------------------------
    @classmethod
    def columns(cls) -> Mapping[str, Column]:
        return dict(cls._columns_)

    @classmethod
    def names(cls) -> Sequence[str]:
        return list(cls._columns_)

    @classmethod
    def describe(cls) -> str:
        body = "\n".join(f"  {c.describe()}" for c in cls._columns_.values())
        return f"{cls.__name__}:\n{body}"

    @classmethod
    def fingerprint(cls) -> str:
        import hashlib
        h = hashlib.sha256()
        for c in sorted(cls._columns_.values(), key=lambda c: c.name):
            h.update(c.describe().encode())
        return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# Tensor contracts (hardware adaptation: contracts for array artifacts)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TensorContract:
    """Contract for one array artifact crossing a pipeline boundary.

    ``shape`` entries may be ints or named symbolic dims (strings), which
    must bind consistently across all tensors validated together.
    ``spec`` optionally pins a :class:`jax.sharding.PartitionSpec`-like
    tuple so distribution intent is part of the contract.
    """

    shape: tuple[Any, ...]
    dtype: str
    spec: tuple[Any, ...] | None = None
    allow_nan: bool = False

    def validate_abstract(self, aval, bindings: dict[str, int],
                          name: str = "<tensor>") -> None:
        from repro.core.errors import ContractCompositionError
        if str(aval.dtype) != self.dtype:
            raise ContractCompositionError(
                f"{name}: dtype {aval.dtype} != contract {self.dtype}")
        if len(aval.shape) != len(self.shape):
            raise ContractCompositionError(
                f"{name}: rank {len(aval.shape)} != contract rank "
                f"{len(self.shape)}")
        for i, (got, want) in enumerate(zip(aval.shape, self.shape)):
            if isinstance(want, str):
                bound = bindings.setdefault(want, got)
                if bound != got:
                    raise ContractCompositionError(
                        f"{name}: dim {i} symbol {want!r} bound to {bound} "
                        f"but saw {got}")
            elif want != got:
                raise ContractCompositionError(
                    f"{name}: dim {i} is {got}, contract says {want}")

    def validate_concrete(self, arr, name: str = "<tensor>") -> None:
        import jax.numpy as jnp
        from repro.core.errors import ContractRuntimeError
        self_bindings: dict[str, int] = {}
        try:
            self.validate_abstract(arr, self_bindings, name)
        except Exception as e:  # re-raise at WORKER moment
            raise ContractRuntimeError(str(e)) from e
        if not self.allow_nan and jnp.issubdtype(arr.dtype, jnp.floating):
            if bool(jnp.isnan(arr).any()):
                raise ContractRuntimeError(f"{name}: contract forbids NaNs")
